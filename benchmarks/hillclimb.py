"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> measure.

Each iteration compiles one dry-run cell with a config/step override and
records the three roofline terms. Output: perf_log.jsonl (consumed by
EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m benchmarks.hillclimb --cell smollm_prefill
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys

import numpy as np


def log(path, rec):
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")
    r = rec["result"]
    if r.get("ok"):
        print(f"[{rec['cell']}] {rec['iter']}: "
              f"c={r['compute_s']*1e3:.1f}ms m={r['memory_s']*1e3:.1f}ms "
              f"coll={r['collective_s']*1e3:.1f}ms dom={r['dominant']} "
              f"useful={r['useful_fraction']:.2f} "
              f"mfu={r['mfu_bound']:.3f}")
    else:
        print(f"[{rec['cell']}] {rec['iter']}: FAILED "
              f"{r.get('error', '')[:100]}")


def run(arch, shape, hypothesis, cell, it, path, **kw):
    from repro.launch.dryrun import run_cell
    try:
        rec = run_cell(arch, shape, verbose=False, **kw)
    except Exception as e:  # noqa: BLE001
        import traceback
        traceback.print_exc()
        rec = {"ok": False, "error": f"{type(e).__name__}: {e}"}
    log(path, {"cell": cell, "iter": it, "hypothesis": hypothesis,
               "result": rec})
    return rec


def cell_smollm_prefill(path):
    """Worst useful-fraction cell: smollm-360m x prefill_32k (0.01).

    Within-worker-DP serving replicates params over the 16-way model
    axis -> every chip computes the full forward. Napkin: sequence
    parallelism over 'model' dedups compute+memory ~16x, costing
    per-layer K/V all-gathers (2 x S x kv x hd bytes/layer, ~16 GB/pod
    vs ~400 GB saved traffic)."""
    a, s, c = "smollm-360m", "prefill_32k", "smollm_prefill"
    run(a, s, "baseline (paper-faithful serving shardings)", c,
        "baseline", path)
    run(a, s, "H1: sequence parallelism over idle model axis; expect "
              "~16x memory/compute drop, small new collective term", c,
        "seq_shard", path, cfg_overrides={"serve_seq_shard": True})


def cell_olmoe_prefill(path):
    """Most collective-bound cell: olmoe-1b-7b x prefill_32k
    (coll 22.1s > mem 9.6s).

    The sort-based MoE pack scatters into a GLOBAL [E*C, d] buffer, so
    GSPMD gathers all 1M tokens to every chip each layer. Napkin:
    shard-local dispatch (G=16 groups aligned with data shards) keeps
    scatters local; dispatch becomes group-local collectives —
    expect the collective term to drop ~an order of magnitude."""
    a, s, c = "olmoe-1b-7b", "prefill_32k", "olmoe_prefill"
    run(a, s, "baseline (global-token dispatch)", c, "baseline", path)
    run(a, s, "H1: shard-local dispatch, G=16 groups", c,
        "local_dispatch_g16", path, cfg_overrides={"moe_shard_groups": 16})
    run(a, s, "H2: G=32 groups (one per data shard x 2 batch) — finer "
              "locality, capacity fragmentation grows", c,
        "local_dispatch_g32", path, cfg_overrides={"moe_shard_groups": 32})


def cell_train(path, arch="internlm2-20b"):
    """Paper-representative cell: train_4k with gossip matchings.

    Baseline = paper-faithful: ring round-topology (2 matchings, what the
    controller converges to under slow links), uniform mixing, tau=1,
    remat=nothing_saveable.
    H1 (paper's knob, denser topology): full graph -> W-1 matchings;
       collective term grows ~(W-1)/2 x — quantifies what the adaptive
       controller SAVES vs dense gossip.
    H2 (beyond paper): int8 error-feedback gossip — gossip bytes x0.25
       (f32-compiled) with scales side-channel.
    H3 (beyond paper): remat policy dots_saveable — backward stops
       recomputing matmuls; useful-FLOPs fraction rises, memory rises."""
    import numpy as np
    from repro.core import topology as topo
    c = f"{arch.split('-')[0]}_train"
    w = 16
    ring = topo.ring_topology(w)
    full = topo.full_topology(w)
    run(arch, "train_4k", "baseline: ring topology (controller-converged "
                          "sparse gossip), uniform mixing", c,
        "baseline_ring", path, train_kw={"adj": ring})
    run(arch, "train_4k", "H1: FULL gossip graph (15 matchings) — the "
                          "dense alternative the paper's controller "
                          "prunes", c,
        "full_graph", path, train_kw={"adj": full})
    run(arch, "train_4k", "H2: int8 error-feedback compressed gossip on "
                          "the ring", c,
        "ring_int8", path, train_kw={"adj": ring, "compressed": True})
    run(arch, "train_4k", "H3: remat policy dots_saveable (save matmul "
                          "outputs, stop recomputing them)", c,
        "remat_dots", path, train_kw={"adj": ring},
        cfg_overrides={"remat": "dots"})


CELLS = {
    "smollm_prefill": cell_smollm_prefill,
    "olmoe_prefill": cell_olmoe_prefill,
    "train": cell_train,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    ap.add_argument("--log", default="perf_log.jsonl")
    args = ap.parse_args()
    CELLS[args.cell](args.log)


if __name__ == "__main__":
    main()
