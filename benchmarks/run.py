"""Benchmark harness — one benchmark per paper table/figure (deliverable d).

  fig1   tau sweep: accuracy + completion time vs local updating frequency
  fig2_3 IID convergence + completion time to target accuracy, 5 algorithms
  fig4_5 non-IID (p=0.6 / 0.8) accuracy, 5 algorithms
  fig6   accuracy vs non-IID level
  fig7   average waiting time, 5 algorithms
  kernels  Pallas kernel micro-benches (interpret mode) vs jnp references
  collective  gossip-vs-allreduce wire bytes for the adapted topology
  fused    scan-based engine vs reference engine rounds/sec (D-PSGD shape)
  compressed  int8+error-feedback gossip vs uncompressed: wire bytes,
           accuracy parity, simulated-clock speedup (CI-gated via --smoke)
  sparse   top-k / rand-k sparsified gossip vs uncompressed: wire bytes,
           accuracy parity (CI-gated via --smoke: top-k >= 4x wire at
           <= 1% accuracy drift)
  sparse_gossip  edge-list gossip (cfg.gossip="sparse") vs the dense
           [W, W] matrix: small-W accuracy parity (<= 0.1%) plus a
           W=2048 ring leg the dense engine cannot reach (CI-gated via
           --smoke: wall-clock + memory budgets)
  sharded  sharded [W, P] execution (mesh=...) vs the single-device
           oracle: small-W accuracy parity (<= 0.1%) plus a W=4096
           sparse-ring leg with per-shard memory strictly below the
           whole-array footprint (CI-gated via --smoke on a forced
           8-device CPU)
  adpsgd   fused event-driven AD-PSGD vs the reference event loop:
           events/sec + accuracy parity (CI-gated via --smoke: >= 5x)

Run:  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig6]
Output: CSV lines  benchmark,metric,value  + a summary table.
Quick mode (default) shrinks workers/rounds to finish on one CPU core;
--full uses the paper's 30 workers / full rounds; --smoke shrinks the
fused bench further for CI, where a fused-slower-than-reference result
fails the run (exit 1).
"""
from __future__ import annotations

import argparse
import sys
import time
from dataclasses import replace

import numpy as np

from repro.configs.base import FedHPConfig

ALGOS = ("fedhp", "dpsgd", "ldsgd", "pens", "adpsgd")


SPREAD = 3.0          # class overlap: hard enough that accuracy separates


def base_cfg(full: bool) -> FedHPConfig:
    # paper setup: 30 workers; lr decay 0.993 (CIFAR/IMAGE-100 schedule).
    # quick mode shrinks the cluster so the suite finishes on one CPU core
    if full:
        return FedHPConfig(num_workers=30, rounds=300, tau_init=8,
                           tau_max=30, lr=0.15, lr_decay=0.993,
                           batch_size=32, seed=5)
    return FedHPConfig(num_workers=16, rounds=150, tau_init=8, tau_max=30,
                       lr=0.15, lr_decay=0.993, batch_size=32, seed=5)


def time_budget(full: bool) -> float:
    """Equal-simulated-time comparison (the paper's metric is completion
    TIME; rounds are not comparable across algorithms)."""
    return 300.0 if full else 80.0


def emit(rows, bench, metric, value):
    rows.append((bench, metric, value))
    print(f"{bench},{metric},{value}")


# ---------------------------------------------------------------------------

def bench_fig1(rows, full):
    """Pre-test (Fig. 1): model quality/completion time vs fixed tau."""
    from repro.core.experiment import run_algorithm
    cfg = base_cfg(full)
    taus = (2, 8, 16, 32) if not full else (2, 9, 18, 27, 36, 45)
    for tau in taus:
        c = replace(cfg, tau_init=tau, algorithm="dpsgd")
        h = run_algorithm("dpsgd", c, non_iid_p=0.4, rounds=cfg.rounds,
                          spread=SPREAD, time_budget=time_budget(full))
        emit(rows, "fig1", f"acc@tau={tau}", round(h.final_accuracy, 4))
        t90 = h.completion_time(0.80)
        emit(rows, "fig1", f"time_to_80%@tau={tau}",
             round(t90, 1) if t90 else "never")


def _histories(cfg, p, full):
    from repro.core.experiment import run_algorithm
    return {a: run_algorithm(a, cfg, non_iid_p=p, rounds=cfg.rounds,
                             spread=SPREAD, time_budget=time_budget(full))
            for a in ALGOS}


def bench_fig2_3(rows, full):
    """IID convergence + completion time to target accuracy (Figs. 2-3)."""
    cfg = base_cfg(full)
    hs = _histories(cfg, 0.1, full)                # p=0.1 == IID (paper)
    target = 0.97 * max(h.final_accuracy for h in hs.values())
    for a, h in hs.items():
        emit(rows, "fig2", f"final_acc[{a}]", round(h.final_accuracy, 4))
        t = h.completion_time(target)
        emit(rows, "fig3", f"time_to_{target:.2f}[{a}]",
             round(t, 1) if t else "never")
    t_f, t_d = (hs["fedhp"].completion_time(target),
                hs["dpsgd"].completion_time(target))
    if t_f and t_d:
        emit(rows, "fig3", "fedhp_vs_dpsgd_speedup", round(t_d / t_f, 2))
    bench_fig7(rows, hs)                            # waiting time: same runs


def bench_fig4_5(rows, full):
    """Non-IID convergence at p=0.6 and p=0.8 (Figs. 4-5)."""
    cfg = base_cfg(full)
    for p in ((0.6, 0.8) if full else (0.8,)):
        hs = _histories(cfg, p, full)
        for a, h in hs.items():
            emit(rows, "fig4_5", f"acc@p={p}[{a}]",
                 round(h.final_accuracy, 4))


def bench_fig6(rows, full):
    """Accuracy vs non-IID level (Fig. 6)."""
    cfg = base_cfg(full)
    levels = (0.1, 0.4) if not full else (0.1, 0.2, 0.4, 0.6, 0.8)
    for p in levels:
        hs = _histories(cfg, p, full)
        for a, h in hs.items():
            emit(rows, "fig6", f"acc@p={p}[{a}]",
                 round(h.final_accuracy, 4))


def bench_fig7(rows, hs):
    """Average waiting time (Fig. 7) — computed from the fig2 runs."""
    for a, h in hs.items():
        emit(rows, "fig7", f"avg_wait[{a}]", round(h.avg_waiting, 3))


def bench_churn(rows, full):
    """Dynamic membership (churn): completion time to a target accuracy for
    FedHP vs D-PSGD / AD-PSGD while 10-30% of the fleet joins/leaves/
    crashes/straggles on a seeded ChurnSchedule."""
    from repro.core.experiment import churn_from_config, run_algorithm
    cfg = base_cfg(full)
    target = 0.85
    for rate in ((0.1, 0.3) if full else (0.3,)):
        c = replace(cfg, churn_rate=rate)
        sched = churn_from_config(c)
        emit(rows, "churn", f"events@{rate}", len(sched.events))
        for a in ("fedhp", "dpsgd", "adpsgd"):
            h = run_algorithm(a, c, non_iid_p=0.4, rounds=cfg.rounds,
                              spread=SPREAD, churn=sched,
                              time_budget=time_budget(full))
            emit(rows, "churn", f"acc@{rate}[{a}]",
                 round(h.final_accuracy, 4))
            t = h.completion_time(target)
            emit(rows, "churn", f"time_to_{target}@{rate}[{a}]",
                 round(t, 1) if t else "never")


def bench_kernels(rows, full):
    """Pallas kernels vs jnp oracle, us/call (interpret mode on CPU —
    correctness substrate; TPU is the perf target)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    n = 2 ** 17
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    u = jax.random.normal(jax.random.PRNGKey(1), (4, n))
    w = jnp.full((4,), 0.2)

    def timeit(f):
        f()                                    # compile
        t0 = time.perf_counter()
        for _ in range(3):
            r = f()
        jax.tree.leaves(r)[0].block_until_ready()
        return (time.perf_counter() - t0) / 3 * 1e6

    emit(rows, "kernels", "gossip_mix_us",
         round(timeit(lambda: ops.gossip_mix(x, u, w))))
    emit(rows, "kernels", "consensus_dist_us",
         round(timeit(lambda: ops.consensus_dist(x, u))))
    emit(rows, "kernels", "quantize_us",
         round(timeit(lambda: ops.quantize(x))))
    ref = jnp.tensordot(w, u - x[None], axes=1) + x
    got = ops.gossip_mix(x, u, w)
    emit(rows, "kernels", "gossip_max_err",
         float(jnp.max(jnp.abs(ref - got))))


def bench_fused(rows, full):
    """Scan-based fused engine (core/fused.py) vs the reference round loop
    on the D-PSGD smoke shape: identical work, rounds/sec compared.

    Timed on the second run each (first run pays jit compilation for both
    engines); fresh cluster/strategy per run so RNG streams match, but
    data synthesis/sharding stays OUTSIDE the timer — only the engine
    loop is measured. In --smoke mode a speedup < 1 marks the whole
    benchmark run failed."""
    from repro.core import engine
    from repro.core.experiment import setup_experiment
    from repro.core.algorithms import make_strategy
    from repro.core.fused import run_dfl_fused
    from repro.core.topology import make_base_topology
    from repro.simulation.cluster import SimCluster

    cfg = base_cfg(full)
    rounds = 20 if SMOKE else (40 if not full else 80)
    if SMOKE:
        cfg = replace(cfg, num_workers=8)
    cfg = replace(cfg, algorithm="dpsgd")
    train, tx, ty, shards, cluster0 = setup_experiment(
        cfg, non_iid_p=0.4, spread=SPREAD, rounds=rounds)
    base = make_base_topology(cfg.num_workers, cfg.base_topology, cfg.seed)

    def timed(fused):
        # stateful inputs rebuilt per run so the RNG streams restart
        cluster = SimCluster(cfg.num_workers, model_bits=cluster0.model_bits,
                             seed=cfg.seed)
        strategy = make_strategy(cfg, base)
        fn = run_dfl_fused if fused else engine.run_dfl
        t0 = time.perf_counter()
        h = fn(train, tx, ty, shards, cluster, cfg, strategy, rounds=rounds)
        return time.perf_counter() - t0, h

    for fused in (False, True):               # warm the jit caches
        timed(fused)
    t_ref, h_ref = timed(False)
    t_fus, h_fus = timed(True)
    assert len(h_ref.records) == len(h_fus.records)
    emit(rows, "fused", "ref_rounds_per_s", round(rounds / t_ref, 2))
    emit(rows, "fused", "fused_rounds_per_s", round(rounds / t_fus, 2))
    speedup = t_ref / t_fus
    emit(rows, "fused", "speedup", round(speedup, 2))
    emit(rows, "fused", "final_acc_drift",
         round(abs(h_ref.final_accuracy - h_fus.final_accuracy), 6))
    if SMOKE and speedup < 1.0:
        FAILURES.append(f"fused engine slower than reference "
                        f"({speedup:.2f}x)")


def bench_compressed(rows, full):
    """Compressed gossip (int8 + error feedback, core/compression.py) vs
    uncompressed on the same shape: wire bits per transfer, final-accuracy
    parity, and the simulated-clock payoff of paying Eq. 10 comm time /
    wire_ratio. Runs on the fused engine (the CI-gated hot path). In
    --smoke mode a wire reduction < 2x or an accuracy drift > 1% vs the
    uncompressed run fails the whole benchmark (exit 1)."""
    from repro.core.compression import FP32_BITS, wire_bits, wire_ratio
    from repro.core.experiment import model_bits_for, run_algorithm

    cfg = base_cfg(full)
    rounds = 30 if SMOKE else (60 if not full else 150)
    if SMOKE:
        cfg = replace(cfg, num_workers=8)
    params = int(model_bits_for(cfg) // FP32_BITS)
    ratio = wire_ratio(params)
    emit(rows, "compressed", "wire_bits[f32]", wire_bits(params, "none"))
    emit(rows, "compressed", "wire_bits[int8]", wire_bits(params, "int8"))
    emit(rows, "compressed", "wire_reduction", round(ratio, 2))

    hs = {}
    for mode, ef in (("none", True), ("int8", True), ("int8_noef", False)):
        c = replace(cfg, compress=mode.split("_")[0], error_feedback=ef)
        hs[mode] = run_algorithm("dpsgd", c, non_iid_p=0.4, rounds=rounds,
                                 spread=SPREAD, fused=True)
        emit(rows, "compressed", f"final_acc[{mode}]",
             round(hs[mode].final_accuracy, 4))
        emit(rows, "compressed", f"sim_time[{mode}]",
             round(hs[mode].records[-1].cumulative_time, 1))
    drift = abs(hs["int8"].final_accuracy - hs["none"].final_accuracy)
    emit(rows, "compressed", "acc_drift_vs_uncompressed", round(drift, 4))
    emit(rows, "compressed", "sim_time_speedup",
         round(hs["none"].records[-1].cumulative_time /
               hs["int8"].records[-1].cumulative_time, 2))
    if SMOKE:
        if ratio < 2.0:
            FAILURES.append(f"compressed wire reduction {ratio:.2f}x < 2x")
        if drift > 0.01:
            FAILURES.append(f"compressed accuracy drift {drift:.4f} > 1%")


def bench_sparse(rows, full):
    """Sparsified gossip (top-k with x̂ tracking, shared-mask rand-k —
    core/compression.py) vs uncompressed on the fused engine: wire bits
    per transfer and final-accuracy parity at a 10% keep fraction. The
    planner/engines charge Eq. 10 comm / wire_ratio (5x top-k, ~10x
    rand-k — rand-k ships no indices). In --smoke mode the run fails
    (exit 1) if top-k saves < 4x wire bits or drifts > 1% final accuracy
    from the uncompressed run."""
    from repro.core.compression import wire_bits, wire_ratio
    from repro.core.experiment import model_bits_for, run_algorithm

    cfg = base_cfg(full)
    rounds = 30 if SMOKE else (60 if not full else 150)
    if SMOKE:
        cfg = replace(cfg, num_workers=8)
    params = int(model_bits_for(cfg) // 32)
    modes = ("none", "topk:0.1", "randk:0.1")
    for mode in modes[1:]:
        emit(rows, "sparse", f"wire_bits[{mode}]", wire_bits(params, mode))
        emit(rows, "sparse", f"wire_reduction[{mode}]",
             round(wire_ratio(params, mode), 2))

    hs = {}
    for mode in modes:
        c = replace(cfg, compress=mode)
        hs[mode] = run_algorithm("dpsgd", c, non_iid_p=0.4, rounds=rounds,
                                 spread=SPREAD, fused=True)
        emit(rows, "sparse", f"final_acc[{mode}]",
             round(hs[mode].final_accuracy, 4))
        emit(rows, "sparse", f"sim_time[{mode}]",
             round(hs[mode].records[-1].cumulative_time, 1))
    for mode in modes[1:]:
        emit(rows, "sparse", f"acc_drift[{mode}]",
             round(abs(hs[mode].final_accuracy
                       - hs["none"].final_accuracy), 4))
    if SMOKE:
        ratio = wire_ratio(params, "topk:0.1")
        drift = abs(hs["topk:0.1"].final_accuracy
                    - hs["none"].final_accuracy)
        if ratio < 4.0:
            FAILURES.append(f"top-k wire reduction {ratio:.2f}x < 4x")
        if drift > 0.01:
            FAILURES.append(f"top-k accuracy drift {drift:.4f} > 1%")


def bench_sparse_gossip(rows, full):
    """Edge-list gossip (cfg.gossip="sparse") vs the dense [W, W] mixing
    matrix: (1) small-W accuracy parity on the fused engine — the two
    representations must agree to <= 0.1% final accuracy; (2) a large-W
    scaling leg the dense path cannot reach — the dense fused engine
    materializes O(W^2 P) neighbor buffers (122 TB at W=2048 on the
    smoke model), while the sparse engine runs O(E P) through the
    gather-mix-scatter kernel. In --smoke mode the run fails (exit 1)
    if parity drifts > 0.1%, the W=2048 ring exceeds the per-round
    wall-clock budget, or peak RSS exceeds the memory budget."""
    import resource

    from repro.core import topology as topo
    from repro.core.experiment import run_algorithm

    # ---- small-W parity: dense vs sparse fused ---------------------------
    cfg = base_cfg(full)
    rounds = 30 if SMOKE else (60 if not full else 150)
    if SMOKE:
        cfg = replace(cfg, num_workers=8)
    cfg = replace(cfg, base_topology="ring")
    hs = {}
    for gossip in ("dense", "sparse"):
        c = replace(cfg, gossip=gossip)
        hs[gossip] = run_algorithm("dpsgd", c, non_iid_p=0.4, rounds=rounds,
                                   spread=SPREAD, fused=True)
        emit(rows, "sparse_gossip", f"final_acc[{gossip}]",
             round(hs[gossip].final_accuracy, 4))
    drift = abs(hs["sparse"].final_accuracy - hs["dense"].final_accuracy)
    emit(rows, "sparse_gossip", "acc_drift_sparse_vs_dense",
         round(drift, 5))

    # ---- large-W scaling: W where dense is out of reach ------------------
    big_w = 2048 if (SMOKE or full) else 512
    big_rounds = 3
    big = FedHPConfig(num_workers=big_w, rounds=big_rounds, tau_init=2,
                      tau_max=4, lr=0.1, batch_size=16, seed=5,
                      base_topology="ring", gossip="sparse")
    t0 = time.perf_counter()
    h_big = run_algorithm("dpsgd", big, non_iid_p=0.1, rounds=big_rounds,
                          fused=True, num_samples=32 * big_w)
    wall = time.perf_counter() - t0
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024
    edges = topo.edges_from_adj(topo.ring_topology(big_w)).shape[0]
    emit(rows, "sparse_gossip", "big_w", big_w)
    emit(rows, "sparse_gossip", "big_edges", edges)
    emit(rows, "sparse_gossip", "big_seconds_per_round",
         round(wall / big_rounds, 2))
    emit(rows, "sparse_gossip", "big_peak_rss_mb", round(rss_mb, 0))
    emit(rows, "sparse_gossip", "big_final_acc",
         round(h_big.final_accuracy, 4))
    # the dense fused path at this W would vmap a [W, R, C] neighbor
    # buffer per worker: O(W^2 P) f32 — emit the would-be footprint
    from repro.core import modelspec
    params = modelspec.get_adapter("mlp").param_count  # smoke MLP flat size
    emit(rows, "sparse_gossip", "dense_neighbor_buffer_gb",
         round(big_w * big_w * params * 4 / 2**30, 0))

    if SMOKE:
        if drift > 1e-3:
            FAILURES.append(
                f"sparse gossip accuracy drift {drift:.4f} > 0.1%")
        if wall / big_rounds > 60.0:
            FAILURES.append(
                f"sparse gossip W={big_w} at {wall / big_rounds:.1f}"
                " s/round > 60 s budget")
        if rss_mb > 6144:
            FAILURES.append(
                f"sparse gossip W={big_w} peak RSS {rss_mb:.0f} MB "
                "> 6 GB budget")
        if h_big.final_accuracy < 0.5:
            FAILURES.append(
                f"sparse gossip W={big_w} failed to learn "
                f"(acc {h_big.final_accuracy:.3f})")


def bench_adpsgd(rows, full):
    """Fused event-driven AD-PSGD (core/fused.run_adpsgd_fused) vs the
    reference event loop on the smoke shape: identical event schedule
    (engine.adpsgd_schedule), events/sec compared, min-of-3 timings per
    engine (the loop is host-dispatch bound, so wall-clock noise hits the
    reference hardest). In --smoke mode a speedup < 5x or any final-
    accuracy drift marks the whole benchmark run failed."""
    from repro.core import engine
    from repro.core.experiment import setup_experiment
    from repro.core.fused import run_adpsgd_fused
    from repro.simulation.cluster import SimCluster

    cfg = base_cfg(full)
    rounds = 20 if SMOKE else (40 if not full else 80)
    if SMOKE:
        # tiny cluster AND a small tau: the smoke gate measures the
        # dispatch-overhead elimination (the sequential tau-step grad
        # chain is identical device work in both engines and only
        # dilutes the ratio); the non-smoke leg keeps the compute-heavy
        # shape
        cfg = replace(cfg, num_workers=8, tau_init=2)
    cfg = replace(cfg, algorithm="adpsgd")
    train, tx, ty, shards, cluster0 = setup_experiment(
        cfg, non_iid_p=0.4, spread=SPREAD, rounds=rounds)
    n_events = rounds * cfg.num_workers

    def timed(fused):
        cluster = SimCluster(cfg.num_workers, model_bits=cluster0.model_bits,
                             seed=cfg.seed)
        fn = run_adpsgd_fused if fused else engine.run_adpsgd
        t0 = time.perf_counter()
        h = fn(train, tx, ty, shards, cluster, cfg, rounds=rounds)
        return time.perf_counter() - t0, h

    for fused in (False, True):               # warm the jit caches
        timed(fused)
    t_ref, h_ref = min((timed(False) for _ in range(3)),
                       key=lambda th: th[0])
    t_fus, h_fus = min((timed(True) for _ in range(3)),
                       key=lambda th: th[0])
    assert len(h_ref.records) == len(h_fus.records)
    emit(rows, "adpsgd", "ref_events_per_s", round(n_events / t_ref, 1))
    emit(rows, "adpsgd", "fused_events_per_s", round(n_events / t_fus, 1))
    speedup = t_ref / t_fus
    emit(rows, "adpsgd", "speedup", round(speedup, 2))
    drift = abs(h_ref.final_accuracy - h_fus.final_accuracy)
    emit(rows, "adpsgd", "final_acc_drift", round(drift, 6))
    emit(rows, "adpsgd", "mean_staleness",
         round(float(np.mean([r.staleness for r in h_fus.records])), 3))
    if SMOKE and speedup < 5.0:
        FAILURES.append(f"fused AD-PSGD below the 5x events/sec gate "
                        f"({speedup:.2f}x)")
    if SMOKE and drift > 1e-5:
        FAILURES.append(f"fused AD-PSGD accuracy drifted {drift:.2e} "
                        f"from the reference event loop")


def bench_sharded(rows, full):
    """Sharded [W, P] execution (``run_algorithm(mesh=...)``) vs the
    single-device oracle: (1) a small-W parity leg — the sharded fused
    engine must match the unsharded run to <= 0.1% final accuracy (the
    two paths differ only by the routed delta's summation order);
    (2) a W=4096 ring sparse-gossip leg run ONLY sharded, recording
    rounds/sec and peak RSS, with the per-round trajectory persisted to
    ``BENCH_sharded.json`` (the CI artifact). The large leg also checks
    the point of sharding: every final-params leaf must keep one shard
    per device, so the bytes addressed by a single device stay strictly
    below the whole-array footprint. Needs >= 2 devices (CI exports
    XLA_FLAGS=--xla_force_host_platform_device_count=8); on one device
    the bench emits a skip row (fatal in --smoke mode, where the lane
    guarantees the devices)."""
    import json
    import resource

    import jax

    from repro.core.experiment import run_algorithm
    from repro.launch.mesh import make_worker_mesh

    ndev = jax.device_count()
    if ndev < 2:
        emit(rows, "sharded", "skipped[devices]", ndev)
        if SMOKE:
            FAILURES.append(
                "sharded bench needs >= 2 devices (export XLA_FLAGS="
                "--xla_force_host_platform_device_count=8)")
        return
    n_shards = 4 if ndev >= 4 else 2
    mesh = make_worker_mesh(n_shards)
    emit(rows, "sharded", "n_shards", n_shards)

    # ---- small-W parity: sharded fused vs single-device fused ------------
    cfg = base_cfg(full)
    rounds = 30 if SMOKE else (60 if not full else 150)
    if SMOKE:
        cfg = replace(cfg, num_workers=8)
    cfg = replace(cfg, base_topology="ring", gossip="sparse")
    hs = {}
    for leg, m in (("oracle", None), ("sharded", mesh)):
        hs[leg] = run_algorithm("dpsgd", cfg, non_iid_p=0.4, rounds=rounds,
                                spread=SPREAD, fused=True, mesh=m)
        emit(rows, "sharded", f"final_acc[{leg}]",
             round(hs[leg].final_accuracy, 4))
    drift = abs(hs["sharded"].final_accuracy - hs["oracle"].final_accuracy)
    emit(rows, "sharded", "acc_drift_vs_oracle", round(drift, 5))

    # ---- large-W scaling: W=4096 sparse ring, sharded only ---------------
    big_w = 4096 if (SMOKE or full) else 1024
    big_rounds = 3
    big = FedHPConfig(num_workers=big_w, rounds=big_rounds, tau_init=2,
                      tau_max=4, lr=0.1, batch_size=16, seed=5,
                      base_topology="ring", gossip="sparse")
    t0 = time.perf_counter()
    h_big = run_algorithm("dpsgd", big, non_iid_p=0.1, rounds=big_rounds,
                          fused=True, mesh=mesh, num_samples=32 * big_w)
    wall = time.perf_counter() - t0
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024
    emit(rows, "sharded", "big_w", big_w)
    emit(rows, "sharded", "big_rounds_per_s",
         round(big_rounds / wall, 3))
    emit(rows, "sharded", "big_peak_rss_mb", round(rss_mb, 0))
    emit(rows, "sharded", "big_final_acc", round(h_big.final_accuracy, 4))

    # big_w divides n_shards, so unpad is an identity and the final
    # params stay sharded: each device must address a strict subset
    leaf = jax.tree.leaves(h_big.final_params)[0]
    shard_bytes = max(s.data.nbytes for s in leaf.addressable_shards)
    emit(rows, "sharded", "big_param_bytes", leaf.nbytes)
    emit(rows, "sharded", "big_per_shard_bytes", shard_bytes)

    a = h_big.as_arrays()
    with open("BENCH_sharded.json", "w") as f:
        json.dump({"mode": "smoke" if SMOKE else
                   ("full" if full else "quick"),
                   "n_shards": n_shards, "small_w": cfg.num_workers,
                   "small_drift": drift, "big_w": big_w,
                   "big_rounds_per_s": round(big_rounds / wall, 3),
                   "big_peak_rss_mb": round(rss_mb, 0),
                   "per_shard_bytes": shard_bytes,
                   "param_bytes": leaf.nbytes,
                   "trajectory": {k: a[k].tolist() for k in
                                  ("round", "accuracy", "loss", "consensus",
                                   "cumulative_time")}}, f)
    emit(rows, "sharded", "trajectory_file", "BENCH_sharded.json")

    if SMOKE:
        if drift > 1e-3:
            FAILURES.append(
                f"sharded accuracy drift {drift:.4f} > 0.1% vs the "
                "single-device oracle")
        if shard_bytes >= leaf.nbytes:
            FAILURES.append(
                f"sharded W={big_w} params not actually sharded: one "
                f"device addresses {shard_bytes} of {leaf.nbytes} bytes")
        if wall / big_rounds > 120.0:
            FAILURES.append(
                f"sharded W={big_w} at {wall / big_rounds:.1f} s/round "
                "> 120 s budget")
        if h_big.final_accuracy < 0.5:
            FAILURES.append(
                f"sharded W={big_w} failed to learn "
                f"(acc {h_big.final_accuracy:.3f})")


def bench_scenarios(rows, full):
    """Scenario-diversity benchmark: (1) FedHP's adaptive topology vs
    fixed complex-network graphs (BA / WS / geo) under correlated rack
    outages (``ChurnSchedule.generate_correlated``); (2) Byzantine
    sign-flip attackers vs trimmed-mean robust gossip (core/robust.py);
    (3) time-varying non-IID drift (``cfg.drift_every``). Per-leg final
    metrics are emitted as CSV rows and the full per-round trajectories
    are persisted to ``BENCH_scenarios.json`` (the CI artifact).

    In --smoke mode the Byzantine legs are gated: with 20% sign-flip
    attackers, trimmed-mean gossip must reach >= 90% of the clean run's
    final accuracy through BOTH the reference engine and the fused
    scan's gather-sort-trim kernel, plain uniform mixing must degrade
    measurably below clean, and AD-PSGD accept/reject screening
    (robust="screen:<z>") must recover >= 85% of its clean run — any
    failure exits 1."""
    import json

    from repro.core.experiment import run_algorithm
    from repro.simulation.cluster import ChurnSchedule

    cfg = base_cfg(full)
    rounds = 40 if SMOKE else (60 if not full else 150)
    if SMOKE:
        cfg = replace(cfg, num_workers=16)
    n = cfg.num_workers
    traj: dict[str, dict] = {}

    def record(leg, h):
        a = h.as_arrays()
        traj[leg] = {
            "final_accuracy": round(h.final_accuracy, 4),
            "trajectory": {k: a[k].tolist() for k in
                           ("round", "accuracy", "loss", "consensus",
                            "cumulative_time")},
        }

    # ---- (1) adaptive vs fixed complex-network graphs under outages ------
    racks = 4
    outages = ChurnSchedule.generate_correlated(
        n, rounds, racks=racks, outages=2, seed=cfg.churn_seed,
        min_alive=cfg.churn_min_alive)
    emit(rows, "scenarios", "outage_events", len(outages.events))
    # "base" = fixed given topology at tau_init (dpsgd always plans a
    # ring, so it can't exercise the complex-network graphs)
    topo_legs = [("fedhp", "full"), ("base", "ba:2"),
                 ("base", "ws:4:0.2"), ("base", f"geo:{racks}")]
    for algo, base in topo_legs:
        c = replace(cfg, base_topology=base)
        h = run_algorithm(algo, c, non_iid_p=0.4, rounds=rounds,
                          spread=SPREAD, churn=outages, fused=True)
        leg = f"outage[{algo}@{base}]"
        emit(rows, "scenarios", f"acc_{leg}", round(h.final_accuracy, 4))
        record(leg, h)

    # ---- (2) Byzantine fraction: clean vs plain vs trimmed ---------------
    nb = 10 if SMOKE else n            # 20% attackers on the gate shape
    byz = tuple(range(0, nb, 5))       # workers 0, 5, ... -> nb/5 = 20%
    byz_rounds = 30 if SMOKE else rounds
    bcfg = replace(cfg, num_workers=nb, tau_init=4,
                   byzantine_attack="signflip")
    trimmed_cfg = replace(bcfg, byzantine=byz,
                          robust=f"trimmed:{len(byz)}")
    legs = {"clean": (replace(bcfg, byzantine=(), robust="none"), False),
            "byz_plain": (replace(bcfg, byzantine=byz, robust="none"),
                          False),
            "byz_trimmed": (trimmed_cfg, False),
            # the LOWERED path: trimmed-mean through the fused scan's
            # gather-sort-trim kernel, not the reference mix
            "byz_trimmed_fused": (trimmed_cfg, True)}
    accs = {}
    for name, (c, fus) in legs.items():
        h = run_algorithm("dpsgd", c, non_iid_p=0.4, rounds=byz_rounds,
                          spread=SPREAD, fused=fus)
        accs[name] = h.final_accuracy
        emit(rows, "scenarios", f"acc_byz[{name}]",
             round(h.final_accuracy, 4))
        record(f"byz[{name}]", h)
    emit(rows, "scenarios", "byz_fraction", round(len(byz) / nb, 2))
    emit(rows, "scenarios", "trimmed_recovery",
         round(accs["byz_trimmed"] / max(accs["clean"], 1e-9), 3))
    emit(rows, "scenarios", "trimmed_fused_recovery",
         round(accs["byz_trimmed_fused"] / max(accs["clean"], 1e-9), 3))

    # ---- (2b) AD-PSGD lying wire: clean vs plain vs screened -------------
    # same 20% sign-flip fleet through the event-driven engine; the
    # defense is per-event accept/reject screening (robust="screen:<z>")
    # rather than a trim window (a pairwise exchange has only 2 samples)
    alegs = {"adpsgd_clean": replace(bcfg, byzantine=(), robust="none"),
             "adpsgd_byz": replace(bcfg, byzantine=byz, robust="none"),
             "adpsgd_screen": replace(bcfg, byzantine=byz,
                                      robust="screen:8")}
    for name, c in alegs.items():
        h = run_algorithm("adpsgd", c, non_iid_p=0.4, rounds=byz_rounds,
                          spread=SPREAD, fused=True)
        accs[name] = h.final_accuracy
        emit(rows, "scenarios", f"acc_byz[{name}]",
             round(h.final_accuracy, 4))
        record(f"byz[{name}]", h)
        if h.screen_rejects is not None:
            emit(rows, "scenarios", "screen_rejects",
                 int(sum(h.screen_rejects)))
    emit(rows, "scenarios", "screen_recovery",
         round(accs["adpsgd_screen"] / max(accs["adpsgd_clean"], 1e-9),
               3))

    # ---- (3) time-varying non-IID drift ----------------------------------
    for name, c in (("static", cfg),
                    ("drift", replace(cfg, drift_every=max(rounds // 8,
                                                           1)))):
        h = run_algorithm("dpsgd", c, non_iid_p=0.6, rounds=rounds,
                          spread=SPREAD, fused=True)
        emit(rows, "scenarios", f"acc_drift[{name}]",
             round(h.final_accuracy, 4))
        record(f"drift[{name}]", h)

    with open("BENCH_scenarios.json", "w") as f:
        json.dump({"mode": "smoke" if SMOKE else
                   ("full" if full else "quick"),
                   "workers": n, "rounds": rounds, "legs": traj}, f)
    emit(rows, "scenarios", "trajectory_file", "BENCH_scenarios.json")

    if SMOKE:
        if accs["byz_trimmed"] < 0.9 * accs["clean"]:
            FAILURES.append(
                f"trimmed-mean gossip under 20% sign-flip attackers "
                f"reached {accs['byz_trimmed']:.3f} < 90% of clean "
                f"({accs['clean']:.3f})")
        if accs["clean"] - accs["byz_plain"] < 0.02:
            FAILURES.append(
                f"plain uniform mixing under attack should degrade "
                f"measurably; clean {accs['clean']:.3f} vs attacked "
                f"{accs['byz_plain']:.3f}")
        if accs["byz_trimmed_fused"] < 0.9 * accs["clean"]:
            FAILURES.append(
                f"FUSED trimmed-mean gossip under 20% sign-flip "
                f"attackers reached {accs['byz_trimmed_fused']:.3f} "
                f"< 90% of clean ({accs['clean']:.3f})")
        if accs["adpsgd_screen"] < 0.85 * accs["adpsgd_clean"]:
            FAILURES.append(
                f"AD-PSGD screening under 20% sign-flip attackers "
                f"reached {accs['adpsgd_screen']:.3f} < 85% of clean "
                f"({accs['adpsgd_clean']:.3f})")


def bench_collective(rows, full):
    """Adapted-topology gossip vs all-reduce wire bytes (the roofline knob
    the paper's technique controls; DESIGN.md §3)."""
    from repro.core import topology as topo
    n, params = 32, 1.0                       # per-model payload = 1 unit
    full_t = topo.full_topology(n)
    ring = topo.ring_topology(n)
    for name, adj in (("full", full_t), ("ring", ring)):
        m = len(topo.matching_decomposition(adj))
        emit(rows, "collective", f"matchings[{name}]", m)
        emit(rows, "collective", f"gossip_bytes[{name}]", m * params)
    emit(rows, "collective", "allreduce_bytes",
         round(2 * (n - 1) / n * params, 3))


def bench_pytree(rows, full):
    """Registry pytree models through the DFL engines (core/modelspec.py):
    a tiny dense transformer LM trains under fedhp on BOTH the reference
    engine (core/engine.run_dfl) and the fused scan (run_dfl_fused), with
    a per-leaf codec map ("leafmap:embed=randk:...,ln=none,default=int8")
    compiled against the adapter's leaf-offset table. Emits the exact
    wire accounting of the leaf map vs uniform int8 and persists both
    trajectories to ``BENCH_pytree.json`` (the CI artifact).

    In --smoke mode the run fails (exit 1) if reference and fused final
    accuracy drift > 0.1% (the leafmap gossip payload is shared oracle
    math — the engines must agree), if the leaf map's wire reduction
    falls below uniform int8's, or if the LM's inverse perplexity does
    not improve >= 5% over the run (the smoke horizon is too short to
    cross the uniform-entropy floor; steady descent is the learning
    gate)."""
    import json

    from repro.core import compression, modelspec
    from repro.core.experiment import run_algorithm

    cfg = base_cfg(full)
    rounds = 10 if SMOKE else (20 if not full else 40)
    # transformer LM under plain SGD: smaller cluster than the MLP
    # smoke shape, and a leaf-mapped codec on the gossip wire. The
    # smoke model is deliberately tiny — fedhp replans every round, so
    # each distinct (adj, tau_cap) pair costs one scan compile of the
    # whole transformer
    model = ("dense:d=16,layers=1,ff=32,vocab=32,seq=8" if SMOKE
             else "dense")
    leafmap = "leafmap:embed=randk:0.05,ln=none,default=int8"
    cfg = replace(cfg, num_workers=6 if SMOKE else 8, tau_init=6,
                  tau_max=12, lr=0.25 if SMOKE else 0.05, model=model,
                  compress=leafmap)

    adapter = modelspec.get_adapter(cfg.model)
    lcodec = compression.parse_mode(leafmap).compile(adapter.leaf_offsets())
    int8_ratio = compression.wire_ratio(adapter.param_count, "int8")
    leaf_ratio = lcodec.wire_ratio()
    emit(rows, "pytree", "param_count", adapter.param_count)
    emit(rows, "pytree", "model_bits", int(adapter.model_bits))
    emit(rows, "pytree", "wire_reduction[int8]", round(int8_ratio, 2))
    emit(rows, "pytree", "wire_reduction[leafmap]", round(leaf_ratio, 2))
    emit(rows, "pytree", "leaf_segments", len(lcodec.segments))

    traj: dict[str, dict] = {}
    hs = {}
    for leg, fused in (("ref", False), ("fused", True)):
        h = run_algorithm("fedhp", cfg, non_iid_p=0.4, rounds=rounds,
                          spread=SPREAD, fused=fused)
        hs[leg] = h
        a = h.as_arrays()
        traj[leg] = {
            "final_accuracy": round(h.final_accuracy, 6),
            "trajectory": {k: a[k].tolist() for k in
                           ("round", "accuracy", "loss", "consensus",
                            "cumulative_time")},
        }
        emit(rows, "pytree", f"final_acc[{leg}]",
             round(h.final_accuracy, 4))
    drift = abs(hs["ref"].final_accuracy - hs["fused"].final_accuracy)
    emit(rows, "pytree", "acc_drift_ref_vs_fused", round(drift, 6))

    with open("BENCH_pytree.json", "w") as f:
        json.dump({"mode": "smoke" if SMOKE else
                   ("full" if full else "quick"),
                   "model": adapter.spec, "workers": cfg.num_workers,
                   "rounds": rounds, "compress": leafmap,
                   "param_count": adapter.param_count,
                   "wire_reduction": {"int8": int8_ratio,
                                      "leafmap": leaf_ratio},
                   "legs": traj}, f)
    emit(rows, "pytree", "trajectory_file", "BENCH_pytree.json")

    if SMOKE:
        if drift > 1e-3:
            FAILURES.append(
                f"pytree ref-vs-fused accuracy drift {drift:.5f} > 0.1%")
        if leaf_ratio < int8_ratio:
            FAILURES.append(
                f"leafmap wire reduction {leaf_ratio:.2f}x below uniform "
                f"int8 ({int8_ratio:.2f}x) — the per-leaf map should "
                "never pay more than its default codec alone")
        acc0 = hs["fused"].records[0].accuracy
        if hs["fused"].final_accuracy < 1.05 * acc0:
            FAILURES.append(
                f"pytree LM failed the 5% learning gate "
                f"({acc0:.4f} -> {hs['fused'].final_accuracy:.4f})")


BENCHES = {
    "fig1": bench_fig1,
    "fig2_3": bench_fig2_3,
    "fig4_5": bench_fig4_5,
    "fig6": bench_fig6,
    "churn": bench_churn,
    "kernels": bench_kernels,
    "collective": bench_collective,
    "fused": bench_fused,
    "compressed": bench_compressed,
    "sparse": bench_sparse,
    "sparse_gossip": bench_sparse_gossip,
    "sharded": bench_sharded,
    "adpsgd": bench_adpsgd,
    "scenarios": bench_scenarios,
    "pytree": bench_pytree,
}

SMOKE = False              # set by --smoke; bench_fused reads it
FAILURES: list[str] = []   # regressions collected during the run


def main(argv=None) -> int:
    global SMOKE
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper scale: 30 workers, full rounds")
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape: tiny cluster, perf regressions fatal")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    SMOKE = args.smoke

    rows: list = []
    print("benchmark,metric,value")
    todo = [args.only] if args.only else list(BENCHES)
    t0 = time.time()
    for name in todo:
        BENCHES[name](rows, args.full)
    print(f"\n# {len(rows)} metrics in {time.time() - t0:.0f}s "
          f"({'full' if args.full else 'quick'} mode)")
    for f in FAILURES:
        print(f"# FAIL: {f}")
    return 1 if FAILURES else 0


if __name__ == "__main__":
    sys.exit(main())
