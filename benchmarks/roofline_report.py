"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
the dry-run JSONL records.

    PYTHONPATH=src python -m benchmarks.roofline_report \
        --scan dryrun_scan.jsonl --roofline dryrun_roofline.jsonl
"""
from __future__ import annotations

import argparse
import json


def load(path):
    recs = []
    try:
        with open(path) as f:
            for line in f:
                recs.append(json.loads(line))
    except FileNotFoundError:
        pass
    # dedupe on (arch, shape, mesh), keep last
    out = {}
    for r in recs:
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return list(out.values())


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}T"
    if b >= 1e9:
        return f"{b/1e9:.2f}G"
    if b >= 1e6:
        return f"{b/1e6:.1f}M"
    return f"{b/1e3:.0f}K"


def dryrun_table(recs):
    print("| arch | shape | mesh | compile | peak GB/dev | HLO FLOPs "
          "| collectives |")
    print("|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        if not r["ok"]:
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL: "
                  f"{r.get('error', '')[:60]} | | | |")
            continue
        coll = ", ".join(f"{k}x{v}" for k, v in
                         sorted(r.get("collectives", {}).items()))
        peak = r.get("peak_bytes_per_device", 0) / 1e9
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK "
              f"{r['compile_s']:.0f}s | {peak:.1f} | "
              f"{r['hlo_flops']:.2e} | {coll} |")


def roofline_table(recs):
    print("| arch | shape | compute ms | memory ms | collective ms | "
          "dominant | MODEL/HLO flops | MFU bound |")
    print("|---|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if not r["ok"] or r["mesh"] != "16x16":
            continue
        if r.get("scan_mode"):
            # † scanned bodies costed once: FLOP-derived columns invalid
            print(f"| {r['arch']} † | {r['shape']} | — | "
                  f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} "
                  f"| {r['dominant']} | — | — |")
            continue
        tag = " ‡" if r.get("extrapolated") else ""
        print(f"| {r['arch']}{tag} | {r['shape']} | "
              f"{r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} | "
              f"{r['collective_s']*1e3:.2f} | {r['dominant']} | "
              f"{r['useful_fraction']:.2f} | {r['mfu_bound']:.3f} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scan", default="dryrun_scan.jsonl")
    ap.add_argument("--roofline", default="dryrun_roofline.jsonl")
    args = ap.parse_args()
    scan = load(args.scan)
    roof = load(args.roofline)
    print(f"## Dry-run ({len(scan)} cells)\n")
    dryrun_table(scan)
    print(f"\n## Roofline ({len(roof)} single-pod cells, unrolled "
          "cost accounting)\n")
    roofline_table(roof)


if __name__ == "__main__":
    main()
