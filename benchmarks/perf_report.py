"""Format perf_log.jsonl (hillclimb iterations) into the EXPERIMENTS.md
§Perf tables.

    PYTHONPATH=src python -m benchmarks.perf_report [--log perf_log.jsonl]
"""
from __future__ import annotations

import argparse
import json
from collections import OrderedDict


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--log", default="perf_log.jsonl")
    args = ap.parse_args()
    cells: "OrderedDict[str, list]" = OrderedDict()
    with open(args.log) as f:
        for line in f:
            rec = json.loads(line)
            cells.setdefault(rec["cell"], []).append(rec)

    for cell, recs in cells.items():
        # dedupe iterations (keep last occurrence)
        seen = OrderedDict()
        for r in recs:
            seen[r["iter"]] = r
        recs = list(seen.values())
        print(f"### {cell}\n")
        print("| iter | hypothesis | compute ms | memory ms | "
              "collective ms | dominant | useful | MFU bound | verdict |")
        print("|---|---|---|---|---|---|---|---|---|")
        base = None
        for r in recs:
            res = r["result"]
            if not res.get("ok"):
                print(f"| {r['iter']} | {r['hypothesis'][:60]} | "
                      f"FAIL: {res.get('error', '')[:40]} | | | | | | |")
                continue
            dom_val = {"compute": res["compute_s"],
                       "memory": res["memory_s"],
                       "collective": res["collective_s"]}[res["dominant"]]
            if base is None:
                base = res
                verdict = "baseline"
            else:
                prev_dom = {"compute": base["compute_s"],
                            "memory": base["memory_s"],
                            "collective": base["collective_s"]}[
                    base["dominant"]]
                new_on_that_term = {"compute": res["compute_s"],
                                    "memory": res["memory_s"],
                                    "collective": res["collective_s"]}[
                    base["dominant"]]
                ratio = prev_dom / max(new_on_that_term, 1e-12)
                verdict = (f"confirmed ({ratio:.1f}x on baseline-dominant "
                           f"term)" if ratio > 1.05 else
                           ("refuted" if ratio < 0.95 else "neutral"))
            print(f"| {r['iter']} | {r['hypothesis'][:70]} | "
                  f"{res['compute_s']*1e3:.1f} | {res['memory_s']*1e3:.1f} | "
                  f"{res['collective_s']*1e3:.1f} | {res['dominant']} | "
                  f"{res['useful_fraction']:.2f} | {res['mfu_bound']:.3f} | "
                  f"{verdict} |")
        print()


if __name__ == "__main__":
    main()
