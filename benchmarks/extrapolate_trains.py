"""Two-point depth extrapolation for the train cells whose full-depth
unrolled compile exceeds the container budget (gemma3/nemotron/kimi/
zamba2 x train_4k).

Per-layer costs are identical across depth, so every cost C is affine in
depth: C(L) = A + B*L. Compile unrolled at two reduced depths L1 < L2
(respecting each arch's group structure), solve for (A, B), extrapolate
to the full depth. Exact for FLOPs and collective bytes; 'bytes
accessed' inherits the same affine structure. Emits records with
extrapolated=True into dryrun_trains_extrap.jsonl (marked ‡ in the
roofline table).

    PYTHONPATH=src python -m benchmarks.extrapolate_trains
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json

from repro.launch import roofline as rl

# arch -> (L1, L2, full_depth_units, unit="layers", tail_units)
# zamba2: depth unit = one group (6 mamba + 1 shared invocation);
# 81 layers = 13 groups + 3-mamba tail counted as 0.5 group.
PLAN = {
    "gemma3-27b": dict(l1=12, l2=24, full=62, tail_extra=0.0, group=6),
    "nemotron-4-340b": dict(l1=8, l2=16, full=96, tail_extra=0.0, group=1),
    "kimi-k2-1t-a32b": dict(l1=8, l2=16, full=61, tail_extra=0.0, group=1),
    "zamba2-7b": dict(l1=12, l2=24, full=78, tail_extra=0.5 * 6, group=6),
}

FIELDS = ("hlo_flops", "hlo_bytes", "collective_bytes")


def measure(arch, layers):
    from repro.launch.dryrun import run_cell
    return run_cell(arch, "train_4k", verbose=False,
                    cfg_overrides={"num_layers": layers})


def main():
    out = open("dryrun_trains_extrap.jsonl", "a")
    for arch, p in PLAN.items():
        print(f"== {arch}: compiling depth {p['l1']} and {p['l2']}")
        r1 = measure(arch, p["l1"])
        r2 = measure(arch, p["l2"])
        rec = dict(r2)
        span = p["l2"] - p["l1"]
        eff_depth = p["full"] + p["tail_extra"]
        for f in FIELDS:
            slope = (r2[f] - r1[f]) / span
            const = r1[f] - slope * p["l1"]
            rec[f] = const + slope * eff_depth
        chips = rec["chips"]
        rec["compute_s"] = rec["hlo_flops"] / (chips * rl.PEAK_FLOPS)
        rec["memory_s"] = rec["hlo_bytes"] / (chips * rl.HBM_BW)
        rec["collective_s"] = rec["collective_bytes"] / (chips * rl.ICI_BW)
        terms = {"compute": rec["compute_s"], "memory": rec["memory_s"],
                 "collective": rec["collective_s"]}
        rec["dominant"] = max(terms, key=terms.get)
        from repro.configs import SHAPES, get_config
        mf = rl.model_flops(get_config(arch), SHAPES["train_4k"])
        rec["model_flops"] = mf
        rec["useful_fraction"] = mf / rec["hlo_flops"]
        rec["mfu_bound"] = mf / (chips * rl.PEAK_FLOPS *
                                 max(terms.values()))
        rec["extrapolated"] = True
        rec["extrap_from"] = [p["l1"], p["l2"]]
        out.write(json.dumps(rec) + "\n")
        out.flush()
        print(f"   -> c={rec['compute_s']*1e3:.1f}ms "
              f"m={rec['memory_s']*1e3:.1f}ms "
              f"coll={rec['collective_s']*1e3:.1f}ms "
              f"useful={rec['useful_fraction']:.2f} "
              f"mfu={rec['mfu_bound']:.3f}")
    out.close()


if __name__ == "__main__":
    main()
