"""Splice the generated §Dry-run/§Roofline tables into EXPERIMENTS.md
between the <!-- DRYRUN_TABLE --> / <!-- ROOFLINE_TABLE --> markers.

    PYTHONPATH=src python -m benchmarks.update_experiments
"""
from __future__ import annotations

import io
import json
import re
from contextlib import redirect_stdout

from benchmarks.roofline_report import dryrun_table, load, roofline_table


def capture(fn, *a):
    buf = io.StringIO()
    with redirect_stdout(buf):
        fn(*a)
    return buf.getvalue()


def load_perf_cells():
    out = []
    try:
        with open("perf_log.jsonl") as f:
            for line in f:
                rec = json.loads(line)
                r = rec.get("result", {})
                if rec.get("iter", "").startswith("baseline") and r.get("ok"):
                    out.append(r)
    except FileNotFoundError:
        pass
    return out


def merge_perf_baselines(roof):
    """internlm2 train baseline came from the hillclimb log."""
    have = {(r["arch"], r["shape"]) for r in roof}
    try:
        with open("perf_log.jsonl") as f:
            for line in f:
                rec = json.loads(line)
                r = rec.get("result", {})
                if rec.get("iter", "").startswith("baseline") and \
                        r.get("ok") and \
                        (r["arch"], r["shape"]) not in have:
                    roof.append(r)
                    have.add((r["arch"], r["shape"]))
    except FileNotFoundError:
        pass
    return roof


def main():
    # dry-run table: current-code records — single-pod from the roofline
    # sweep (+ scan-mode train fallback), multi-pod from dryrun_scan2
    scan = load("dryrun_trains_scanmode.jsonl") + \
        load("dryrun_roofline.jsonl") + load("dryrun_scan2.jsonl")
    scan = list({(r["arch"], r["shape"], r["mesh"]): r
                 for r in scan}.values())
    pl = load_perf_cells()
    have = {(r["arch"], r["shape"], r["mesh"]) for r in scan}
    scan += [r for r in pl if (r["arch"], r["shape"], r["mesh"]) not in have]
    roof = merge_perf_baselines(load("dryrun_roofline.jsonl"))
    extrap = [r for r in load("dryrun_trains_extrap.jsonl")
              if (r["arch"], r["shape"]) not in
              {(x["arch"], x["shape"]) for x in roof}]
    roof += extrap                # ‡ two-point depth extrapolation
    extra = [r for r in load("dryrun_trains_scanmode.jsonl")
             if (r["arch"], r["shape"]) not in
             {(x["arch"], x["shape"]) for x in roof}]
    for r in extra:
        r["scan_mode"] = True     # † costs of scanned bodies counted once
    roof += extra
    dr = (f"**{sum(r['ok'] for r in scan)}/{len(scan)} cells compiled "
          f"OK.**\n\n" + capture(dryrun_table, scan))
    rf = capture(roofline_table, roof)

    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = re.sub(r"<!-- DRYRUN_TABLE -->.*?(?=\n---)",
                  "<!-- DRYRUN_TABLE -->\n" + dr, text, flags=re.S)
    text = re.sub(r"<!-- ROOFLINE_TABLE -->.*?(?=\n† scan-mode|\n### Reading)",
                  "<!-- ROOFLINE_TABLE -->\n" + rf + "\n", text, flags=re.S)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print(f"spliced: {len(scan)} dry-run records, "
          f"{sum(1 for r in roof if r.get('ok'))} roofline rows")


if __name__ == "__main__":
    main()
