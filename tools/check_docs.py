#!/usr/bin/env python3
"""Docs CI lane (stdlib only — runs before any pip install).

Two gates:

1. Intra-repo links: every relative markdown link in README.md and
   docs/*.md must resolve to a file (anchors are stripped; external
   http(s)/mailto links are skipped).
2. Docstring audit: every public module / class / function / public
   method in the audited ``src/repro/core`` modules must carry a
   docstring (the audit set is the public engine surface documented in
   docs/ARCHITECTURE.md).

Run:  python tools/check_docs.py        (exit 1 on any failure)
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

DOC_FILES = [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]

AUDITED_MODULES = [
    "src/repro/core/engine.py",
    "src/repro/core/fused.py",
    "src/repro/core/modelspec.py",
    "src/repro/core/compression.py",
    "src/repro/core/topology.py",
    "src/repro/core/controller.py",
    "src/repro/core/consensus.py",
    "src/repro/core/algorithms.py",
    "src/repro/core/robust.py",
    "src/repro/data/partition.py",
    "src/repro/simulation/cluster.py",
    "src/repro/runtime/collectives.py",
    "src/repro/runtime/sharding.py",
    "src/repro/runtime/shardexec.py",
    "src/repro/launch/mesh.py",
    "src/repro/kernels/sparsify_block.py",
    "src/repro/kernels/quantize_block.py",
    "src/repro/kernels/gossip_edges.py",
    "src/repro/kernels/robust_gossip.py",
]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_links() -> list[str]:
    problems = []
    for md in DOC_FILES:
        if not md.exists():
            problems.append(f"{md.relative_to(REPO)}: file missing")
            continue
        for lineno, line in enumerate(md.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:",
                                      "#")):
                    continue
                path = target.split("#", 1)[0]
                if not path:
                    continue
                resolved = (md.parent / path).resolve()
                if not resolved.exists():
                    problems.append(
                        f"{md.relative_to(REPO)}:{lineno}: broken link "
                        f"-> {target}")
    return problems


def _missing_docstrings(tree: ast.Module, rel: str) -> list[str]:
    problems = []
    if not ast.get_docstring(tree):
        problems.append(f"{rel}: missing module docstring")
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.name.startswith("_"):
                continue
            if not ast.get_docstring(node):
                problems.append(f"{rel}:{node.lineno}: public function "
                                f"{node.name!r} lacks a docstring")
        elif isinstance(node, ast.ClassDef):
            if node.name.startswith("_"):
                continue
            if not ast.get_docstring(node):
                problems.append(f"{rel}:{node.lineno}: public class "
                                f"{node.name!r} lacks a docstring")
            for item in node.body:
                if isinstance(item, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)) and \
                        not item.name.startswith("_") and \
                        not ast.get_docstring(item):
                    problems.append(
                        f"{rel}:{item.lineno}: public method "
                        f"{node.name}.{item.name!r} lacks a docstring")
    return problems


def check_docstrings() -> list[str]:
    problems = []
    for rel in AUDITED_MODULES:
        path = REPO / rel
        if not path.exists():
            problems.append(f"{rel}: audited module missing")
            continue
        problems.extend(
            _missing_docstrings(ast.parse(path.read_text()), rel))
    return problems


def main() -> int:
    problems = check_links() + check_docstrings()
    for p in problems:
        print(f"FAIL: {p}")
    if problems:
        print(f"\n{len(problems)} docs problem(s)")
        return 1
    n_links = sum(
        len(LINK_RE.findall(md.read_text())) for md in DOC_FILES
        if md.exists())
    print(f"docs OK: {len(DOC_FILES)} markdown files ({n_links} links), "
          f"{len(AUDITED_MODULES)} audited modules")
    return 0


if __name__ == "__main__":
    sys.exit(main())
