"""Quickstart: the public API in ~60 lines.

1. Run a mini FedHP DFL experiment on the simulated heterogeneous edge
   cluster (the paper's setting) and compare with D-PSGD.
2. Instantiate an assigned architecture (reduced config) and take one
   training step.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_smoke_config
from repro.configs.base import FedHPConfig
from repro.core.experiment import run_algorithm
from repro.models import registry


def dfl_demo():
    print("== FedHP vs D-PSGD on a simulated heterogeneous edge cluster ==")
    cfg = FedHPConfig(num_workers=8, rounds=10, tau_init=5, tau_max=20,
                      lr=0.1, batch_size=32, seed=0)
    for algo in ("fedhp", "dpsgd"):
        h = run_algorithm(algo, cfg, non_iid_p=0.6)
        print(f"  {algo:6s}: accuracy={h.final_accuracy:.3f} "
              f"completion={h.records[-1].cumulative_time:7.1f}s "
              f"avg_waiting={h.avg_waiting:.2f}s")


def model_demo():
    print("== one train step of an assigned arch (reduced config) ==")
    cfg = get_smoke_config("olmoe-1b-7b")           # MoE family
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64,
                                global_batch=2)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    batch = registry.make_batch(cfg, shape, jax.random.PRNGKey(1))

    @jax.jit
    def step(p, b):
        (loss, _), g = jax.value_and_grad(
            lambda pp: registry.loss_fn(cfg, pp, b), has_aux=True)(p)
        return loss, jax.tree.map(lambda w, gg: w - 0.01 * gg.astype(w.dtype),
                                  p, g)

    loss, params = step(params, batch)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"  arch={cfg.name} params={n:,} loss={float(loss):.3f}")


if __name__ == "__main__":
    dfl_demo()
    model_demo()
