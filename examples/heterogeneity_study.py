"""Reproduce the paper's heterogeneity story end to end (Sec. V):

sweeps the non-IID level p and reports, per algorithm, accuracy /
completion time / average waiting time — the compact version of
Figs. 2-7 — plus a fault-injection leg (two workers die mid-run).

    PYTHONPATH=src python examples/heterogeneity_study.py

``--churn`` runs the dynamic-membership scenario instead: a seeded
ChurnSchedule (joins, graceful leaves, crashes, straggler spikes) hits
10% and 30% of the fleet and the engines race to a target accuracy —
FedHP's adaptive topology + tau re-equalization vs the static baselines.

    PYTHONPATH=src python examples/heterogeneity_study.py --churn

``--fused`` routes every algorithm through the scan-based fused engines
(core/fused.py: run_dfl_fused for the synchronous strategies,
run_adpsgd_fused for AD-PSGD) — same trajectories, one device dispatch
per segment instead of ~10 per round / ~3 per event:

    PYTHONPATH=src python examples/heterogeneity_study.py --fused

``--adpsgd`` runs the asynchronous study instead: AD-PSGD on the
reference event loop vs the fused event scan, uncompressed vs int8
compensated pairwise exchange, with per-round staleness reported:

    PYTHONPATH=src python examples/heterogeneity_study.py --adpsgd

``--compressed`` runs the compressed-gossip comparison instead: FedHP
and D-PSGD with int8 + error-feedback gossip (core/compression.py,
~3.6x fewer wire bytes, Eq. 10 comm time / wire_ratio) against their
uncompressed selves, racing to a target accuracy on equal wall time:

    PYTHONPATH=src python examples/heterogeneity_study.py --compressed

``--pytree`` runs the registry-model study instead: tiny dense
transformer / xLSTM language models (models/registry.py behind
core/modelspec.py's ModelAdapter) train under fedhp with a per-leaf
codec map ("leafmap:embed=randk:0.05,ln=none,default=int8") on the
gossip wire, under 10% churn:

    PYTHONPATH=src python examples/heterogeneity_study.py --pytree

``--sharded`` runs the sharded-execution study instead: the [W, P]
worker matrix split across the host's devices over a worker mesh
(runtime/shardexec), sharded vs single-device trajectories side by
side — force a multi-device CPU first:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/heterogeneity_study.py --sharded

``--scenarios`` runs the scenario-axis study instead: FedHP's adaptive
topology vs fixed complex-network graphs (Barabási–Albert,
Watts–Strogatz, geo/racks) under correlated rack outages, then 20%
sign-flip Byzantine workers with plain vs trimmed-mean vs median gossip:

    PYTHONPATH=src python examples/heterogeneity_study.py --scenarios
"""
import argparse
from dataclasses import replace

from repro.configs.base import FedHPConfig
from repro.core.experiment import churn_from_config, run_algorithm

CFG = FedHPConfig(num_workers=10, rounds=100, tau_init=8, tau_max=30,
                  lr=0.15, lr_decay=0.993, batch_size=32, seed=7)
BUDGET = 60.0
TARGET_ACC = 0.85
CHURN_ALGOS = ("fedhp", "dpsgd", "adpsgd")


def heterogeneity_study(fused: bool = False):
    print(f"{'algo':8s} {'p':>4s} {'acc':>6s} {'time(s)':>8s} {'wait':>6s}")
    for p in (0.1, 0.8):
        for algo in ("fedhp", "dpsgd", "ldsgd", "pens", "adpsgd"):
            h = run_algorithm(algo, CFG, non_iid_p=p, spread=3.0,
                              time_budget=BUDGET, fused=fused)
            print(f"{algo:8s} {p:4.1f} {h.final_accuracy:6.3f} "
                  f"{h.records[-1].cumulative_time:8.1f} "
                  f"{h.avg_waiting:6.2f}")

    print("\nfault tolerance: workers {0, 3} die at round 5 (FedHP)")
    h = run_algorithm("fedhp", CFG, non_iid_p=0.4, spread=3.0,
                      time_budget=BUDGET, fail_at={5: [0, 3]}, fused=fused)
    print(f"  survived; final accuracy {h.final_accuracy:.3f} "
          f"(topology repaired, Sec. DESIGN §6)")


def churn_study(fused: bool = False):
    """FedHP vs D-PSGD vs AD-PSGD under 10% / 30% dynamic membership."""
    print("dynamic membership: join/leave/crash/straggle schedule, seeded")
    print(f"{'algo':8s} {'churn':>6s} {'acc':>6s} "
          f"{'t_to_{:.0%}'.format(TARGET_ACC):>9s} {'total(s)':>9s} "
          f"{'events':>7s}")
    for rate in (0.1, 0.3):
        cfg = replace(CFG, churn_rate=rate)
        sched = churn_from_config(cfg)
        kinds = ",".join(f"{k}:{sum(e.kind == k for e in sched.events)}"
                         for k in ("leave", "crash", "join", "straggle")
                         if any(e.kind == k for e in sched.events))
        for algo in CHURN_ALGOS:
            h = run_algorithm(algo, cfg, non_iid_p=0.4, spread=3.0,
                              churn=sched, time_budget=BUDGET,
                              fused=fused)
            t = h.completion_time(TARGET_ACC)
            t_str = f"{t:9.1f}" if t is not None else f"{'never':>9s}"
            print(f"{algo:8s} {rate:6.0%} {h.final_accuracy:6.3f} {t_str} "
                  f"{h.records[-1].cumulative_time:9.1f} {kinds:>7s}")


def compressed_study(fused: bool = False):
    """Accuracy vs completion time: int8+EF compressed gossip against
    uncompressed FedHP / D-PSGD on the same simulated-time budget."""
    from repro.core.compression import FP32_BITS, wire_ratio
    from repro.core.experiment import model_bits_for
    ratio = wire_ratio(int(model_bits_for(CFG) // FP32_BITS))
    print(f"compressed gossip: int8 + error feedback, "
          f"{ratio:.2f}x fewer wire bits, comm time / {ratio:.2f}")
    print(f"{'algo':8s} {'wire':>6s} {'acc':>6s} "
          f"{'t_to_{:.0%}'.format(TARGET_ACC):>9s} {'total(s)':>9s}")
    for algo in ("fedhp", "dpsgd"):
        for mode in ("none", "int8"):
            cfg = replace(CFG, compress=mode)
            h = run_algorithm(algo, cfg, non_iid_p=0.4, spread=3.0,
                              time_budget=BUDGET, fused=fused)
            t = h.completion_time(TARGET_ACC)
            t_str = f"{t:9.1f}" if t is not None else f"{'never':>9s}"
            print(f"{algo:8s} {mode:>6s} {h.final_accuracy:6.3f} {t_str} "
                  f"{h.records[-1].cumulative_time:9.1f}")


def scenarios_study(fused: bool = False):
    """Scenario axis: complex-network topologies under correlated rack
    outages, then Byzantine attackers vs robust gossip."""
    from repro.simulation.cluster import ChurnSchedule

    racks = 4
    sched = ChurnSchedule.generate_correlated(
        CFG.num_workers, CFG.rounds, racks=racks, outages=2, seed=CFG.seed)
    n_out = sum(1 for e in sched.events if e.kind == "crash")
    print(f"rack outages: {n_out} grouped crash events over {racks} racks")
    print(f"{'algo':8s} {'topology':>10s} {'acc':>6s} {'total(s)':>9s}")
    for algo, base in (("fedhp", "full"), ("base", "ba:2"),
                       ("base", "ws:4:0.2"), ("base", f"geo:{racks}")):
        cfg = replace(CFG, base_topology=base)
        h = run_algorithm(algo, cfg, non_iid_p=0.4, spread=3.0,
                          churn=sched, time_budget=BUDGET, fused=fused)
        print(f"{algo:8s} {base:>10s} {h.final_accuracy:6.3f} "
              f"{h.records[-1].cumulative_time:9.1f}")

    byz = (3, 7)                                 # 20% of the fleet
    print(f"\nByzantine: workers {byz} sign-flip on the wire "
          f"(reference engine)")
    print(f"{'robust':>10s} {'acc':>6s}")
    for robust in ("none", "trimmed:2", "median"):
        cfg = replace(CFG, rounds=30, byzantine=byz, robust=robust)
        h = run_algorithm("dpsgd", cfg, non_iid_p=0.4, spread=3.0)
        print(f"{robust:>10s} {h.final_accuracy:6.3f}")


def pytree_study(fused: bool = False):
    """Registry pytree models under DFL (core/modelspec.py): a tiny
    dense transformer LM and a tiny xLSTM train under fedhp with a
    per-leaf codec map on the gossip wire, under 10% churn — the
    engines never see the model family, only its ModelAdapter."""
    from repro.core import compression, modelspec

    leafmap = "leafmap:embed=randk:0.05,ln=none,default=int8"
    print("registry pytree models under fedhp + churn "
          "(accuracy = exp(-loss), random-token baseline shown)")
    print(f"{'model':24s} {'params':>7s} {'wire':>6s} {'base':>6s} "
          f"{'acc':>6s} {'total(s)':>9s}")
    # tiny dims: fedhp replans every round and each distinct plan shape
    # costs one jit of the whole transformer/xLSTM
    for model in ("dense:d=16,layers=1,ff=32,vocab=32,seq=8",
                  "xlstm:d=16,ff=32,vocab=32,seq=8"):
        cfg = replace(CFG, model=model, compress=leafmap, lr=0.25,
                      tau_init=6, rounds=25, churn_rate=0.1)
        adapter = modelspec.get_adapter(cfg.model)
        lcodec = compression.parse_mode(leafmap).compile(
            adapter.leaf_offsets())
        h = run_algorithm("fedhp", cfg, non_iid_p=0.4, spread=3.0,
                          time_budget=BUDGET, fused=fused)
        print(f"{model.partition(':')[0]:24s} {adapter.param_count:7d} "
              f"{lcodec.wire_ratio():5.1f}x "
              f"{1.0 / adapter.cfg.vocab_size:6.4f} "
              f"{h.final_accuracy:6.4f} "
              f"{h.records[-1].cumulative_time:9.1f}")


def sharded_study(fused: bool = False):
    """Sharded [W, P] execution: the fleet's worker matrix split across
    the host's devices (one shard_map program per round / segment,
    cross-shard gossip over lax.ppermute) next to the single-device run
    it must reproduce — host clock fields identical, accuracy to
    summation-order drift."""
    import jax

    from repro.launch.mesh import make_worker_mesh

    ndev = jax.device_count()
    if ndev < 2:
        print("sharded study needs a multi-device host; run with\n"
              "  XLA_FLAGS=--xla_force_host_platform_device_count=8")
        return
    n_shards = 4 if ndev >= 4 else 2
    mesh = make_worker_mesh(n_shards)
    cfg = replace(CFG, rounds=40, gossip="sparse",
                  num_workers=(CFG.num_workers + n_shards - 1)
                  // n_shards * n_shards + 2)   # exercise padding too
    print(f"sharded execution: W={cfg.num_workers} over {n_shards} "
          f"device shards ({ndev} devices visible)")
    print(f"{'algo':8s} {'path':>8s} {'acc':>6s} {'total(s)':>9s} "
          f"{'wait':>6s}")
    for algo in ("fedhp", "dpsgd"):
        for m in (None, mesh):
            h = run_algorithm(algo, cfg, non_iid_p=0.4, spread=3.0,
                              time_budget=BUDGET, fused=fused, mesh=m)
            path = "sharded" if m is not None else "1-dev"
            print(f"{algo:8s} {path:>8s} {h.final_accuracy:6.3f} "
                  f"{h.records[-1].cumulative_time:9.1f} "
                  f"{h.avg_waiting:6.2f}")


def adpsgd_study():
    """Asynchronous engines head to head: reference event loop vs fused
    event scan, uncompressed vs int8 compensated pairwise exchange."""
    print("AD-PSGD: event-driven engines, staleness + compression")
    print(f"{'engine':10s} {'wire':>6s} {'acc':>6s} {'total(s)':>9s} "
          f"{'stale':>6s}")
    for mode in ("none", "int8"):
        cfg = replace(CFG, compress=mode)
        for fused in (False, True):
            h = run_algorithm("adpsgd", cfg, non_iid_p=0.4, spread=3.0,
                              time_budget=BUDGET, fused=fused)
            stale = sum(r.staleness for r in h.records) / len(h.records)
            print(f"{'fused' if fused else 'reference':10s} {mode:>6s} "
                  f"{h.final_accuracy:6.3f} "
                  f"{h.records[-1].cumulative_time:9.1f} {stale:6.2f}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--churn", action="store_true",
                    help="run the dynamic-membership (churn) scenario")
    ap.add_argument("--compressed", action="store_true",
                    help="run the compressed-gossip (int8 + EF) scenario")
    ap.add_argument("--adpsgd", action="store_true",
                    help="run the asynchronous (AD-PSGD) engine study "
                         "(always compares reference AND fused engines; "
                         "--fused has no extra effect here)")
    ap.add_argument("--scenarios", action="store_true",
                    help="run the scenario-axis study (complex-network "
                         "topologies, rack outages, Byzantine workers)")
    ap.add_argument("--pytree", action="store_true",
                    help="run registry pytree models (dense / xlstm LMs) "
                         "under fedhp with a per-leaf codec map")
    ap.add_argument("--sharded", action="store_true",
                    help="run the sharded [W, P] study (needs a multi-"
                         "device host; see XLA_FLAGS in the docstring)")
    ap.add_argument("--fused", action="store_true",
                    help="run the algorithms on the fused scan engines")
    args = ap.parse_args()
    if args.churn:
        churn_study(fused=args.fused)
    elif args.scenarios:
        scenarios_study(fused=args.fused)
    elif args.compressed:
        compressed_study(fused=args.fused)
    elif args.pytree:
        pytree_study(fused=args.fused)
    elif args.sharded:
        sharded_study(fused=args.fused)
    elif args.adpsgd:
        adpsgd_study()
    else:
        heterogeneity_study(fused=args.fused)


if __name__ == "__main__":
    main()
