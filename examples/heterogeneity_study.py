"""Reproduce the paper's heterogeneity story end to end (Sec. V):

sweeps the non-IID level p and reports, per algorithm, accuracy /
completion time / average waiting time — the compact version of
Figs. 2-7 — plus a fault-injection leg (two workers die mid-run).

    PYTHONPATH=src python examples/heterogeneity_study.py
"""
from repro.configs.base import FedHPConfig
from repro.core.experiment import run_algorithm

CFG = FedHPConfig(num_workers=10, rounds=100, tau_init=8, tau_max=30,
                  lr=0.15, lr_decay=0.993, batch_size=32, seed=7)
BUDGET = 60.0


def main():
    print(f"{'algo':8s} {'p':>4s} {'acc':>6s} {'time(s)':>8s} {'wait':>6s}")
    for p in (0.1, 0.8):
        for algo in ("fedhp", "dpsgd", "ldsgd", "pens", "adpsgd"):
            h = run_algorithm(algo, CFG, non_iid_p=p, spread=3.0,
                              time_budget=BUDGET)
            print(f"{algo:8s} {p:4.1f} {h.final_accuracy:6.3f} "
                  f"{h.records[-1].cumulative_time:8.1f} "
                  f"{h.avg_waiting:6.2f}")

    print("\nfault tolerance: workers {0, 3} die at round 5 (FedHP)")
    h = run_algorithm("fedhp", CFG, non_iid_p=0.4, spread=3.0,
                      time_budget=BUDGET, fail_at={5: [0, 3]})
    print(f"  survived; final accuracy {h.final_accuracy:.3f} "
          f"(topology repaired, Sec. DESIGN §6)")


if __name__ == "__main__":
    main()
