"""Batched serving example: prefill + decode with KV caches across three
model families (dense GQA, MoE, hybrid SSM).

    PYTHONPATH=src python examples/serve_batched.py
"""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["REPRO_DEVICES"] = "4"
    for arch in ("smollm-360m", "olmoe-1b-7b", "zamba2-7b"):
        cmd = [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
               "--smoke", "--batch", "2", "--prompt-len", "16",
               "--gen", "6"]
        print("+", " ".join(cmd))
        subprocess.run(cmd, check=True, env=env)


if __name__ == "__main__":
    main()
