"""End-to-end DFL training driver example (deliverable b).

Runs the FULL stack: model zoo -> worker-stacked sharding -> masked-tau
local SGD -> matching-wise gossip collectives -> FedHP controller ->
checkpointing, on an 8-device host-platform mesh. Includes a
kill-and-resume leg exercising elastic restore.

    PYTHONPATH=src python examples/train_dfl.py

(At pod scale the same driver runs with --production; see
src/repro/launch/train.py.)
"""
import os
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run(args, env):
    cmd = [sys.executable, "-m", "repro.launch.train"] + args
    print("+", " ".join(cmd))
    subprocess.run(cmd, check=True, env=env)


def main():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["REPRO_DEVICES"] = "8"
    with tempfile.TemporaryDirectory() as ckdir:
        # leg 1: 6 rounds with checkpoints every 3
        run(["--arch", "smollm-360m", "--smoke", "--steps", "6",
             "--workers", "4", "--checkpoint-dir", ckdir,
             "--checkpoint-every", "3"], env)
        # leg 2: resume from the checkpoint and continue to 10
        run(["--arch", "smollm-360m", "--smoke", "--steps", "10",
             "--workers", "4", "--checkpoint-dir", ckdir, "--resume"], env)
    print("train + checkpoint + elastic resume: OK")


if __name__ == "__main__":
    main()
