"""Time-varying non-IID drift: DriftingPartition semantics and the
engine integration (``cfg.drift_every``) — reference and fused engines
must see the same rotating shards and stay differentially equivalent.
"""
from __future__ import annotations

from dataclasses import replace

import numpy as np
import pytest

from repro.configs.base import FedHPConfig
from repro.core.experiment import run_algorithm, setup_experiment
from repro.data.partition import (DriftingPartition, label_histogram,
                                  pskew_partition)

CFG = FedHPConfig(num_workers=8, rounds=12, tau_init=4, tau_max=20,
                  lr=0.1, batch_size=32, seed=3, drift_every=4)


def _labels(n=600, c=10, seed=0):
    return np.random.default_rng(seed).integers(0, c, n)


# ---------------------------------------------------------------------------
# DriftingPartition semantics
# ---------------------------------------------------------------------------

def test_shift_schedule_and_periodicity():
    dp = DriftingPartition(_labels(), 12, 0.5, seed=1, period=5)
    assert [dp.shift_at(h) for h in (0, 4, 5, 9, 10)] == [0, 0, 1, 1, 2]
    # rotation is periodic in the fleet size: shift 12 == shift 0
    a = dp.shards_at(0)
    b = dp.shards_at(12 * 5)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_shift_zero_matches_static_partition():
    """drift_every > 0 with shift 0 must reproduce the static partition
    exactly (same seed stream) — the first drift period is the paper's
    assignment."""
    labels = _labels()
    dp = DriftingPartition(labels, 8, 0.5, seed=7, period=3)
    static = pskew_partition(labels, 8, 0.5, np.random.default_rng(7))
    for x, y in zip(dp.shards_at(0), static):
        np.testing.assert_array_equal(x, y)


def test_shards_rotate_and_cover():
    """Each shift is a full partition (all samples, no duplicates) and
    the per-worker histograms actually move between shifts."""
    labels = _labels()
    dp = DriftingPartition(labels, 8, 0.7, seed=2, period=1)
    h_prev = None
    for h in range(3):
        shards = dp.shards_at(h)
        allix = np.sort(np.concatenate(shards))
        np.testing.assert_array_equal(allix, np.arange(len(labels)))
        hist = label_histogram(labels, shards, 10)
        if h_prev is not None:
            assert (hist != h_prev).any(), f"no drift at shift {h}"
        h_prev = hist


def test_static_views_are_round_zero():
    dp = DriftingPartition(_labels(), 8, 0.5, seed=3, period=2)
    assert len(dp) == 8
    for w, ix in enumerate(dp):
        np.testing.assert_array_equal(ix, dp.shards_at(0)[w])
        np.testing.assert_array_equal(dp[w], dp.shards_at(0)[w])


def test_rejects_bad_period():
    with pytest.raises(ValueError):
        DriftingPartition(_labels(), 8, 0.5, seed=0, period=0)


def test_setup_experiment_routes_drift():
    _, _, _, shards, _ = setup_experiment(CFG, non_iid_p=0.5)
    assert isinstance(shards, DriftingPartition)
    assert shards.period == CFG.drift_every
    # drift_every=0 -> plain static list with the identical seed stream
    _, _, _, static, _ = setup_experiment(replace(CFG, drift_every=0),
                                          non_iid_p=0.5)
    assert isinstance(static, list)
    for x, y in zip(static, shards.shards_at(0)):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def test_drift_changes_trajectory():
    """drift_every must actually reach the batch sampler: the drifting
    run's trajectory diverges from the static one after the first
    rotation."""
    h_d = run_algorithm("dpsgd", CFG, non_iid_p=0.6, rounds=10)
    h_s = run_algorithm("dpsgd", replace(CFG, drift_every=0),
                        non_iid_p=0.6, rounds=10)
    a, b = h_d.as_arrays(), h_s.as_arrays()
    # identical until the first shift (rounds 0..3), different after
    np.testing.assert_allclose(a["loss"][:4], b["loss"][:4], rtol=1e-6)
    assert not np.allclose(a["loss"][4:], b["loss"][4:])


def test_drift_reference_matches_fused():
    """Both synchronous engines replay the same rotating shards: host
    fields exact, device metrics within the differential tolerance."""
    h_ref = run_algorithm("dpsgd", CFG, non_iid_p=0.6, rounds=10)
    h_fus = run_algorithm("dpsgd", CFG, non_iid_p=0.6, rounds=10,
                          fused=True)
    a, b = h_ref.as_arrays(), h_fus.as_arrays()
    for k in ("round", "round_time", "waiting_time", "mean_tau",
              "num_links", "cumulative_time"):
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    for k, tol in (("accuracy", 1e-5), ("loss", 1e-4), ("consensus", 1e-4)):
        np.testing.assert_allclose(a[k], b[k], rtol=tol, atol=tol,
                                   err_msg=k)


@pytest.mark.slow
def test_drift_adpsgd_reference_matches_fused():
    cfg = replace(CFG, num_workers=6)
    h_ref = run_algorithm("adpsgd", cfg, non_iid_p=0.6, rounds=8)
    h_fus = run_algorithm("adpsgd", cfg, non_iid_p=0.6, rounds=8,
                          fused=True)
    a, b = h_ref.as_arrays(), h_fus.as_arrays()
    for k in ("round", "cumulative_time"):
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    for k, tol in (("accuracy", 1e-5), ("loss", 1e-4)):
        np.testing.assert_allclose(a[k], b[k], rtol=tol, atol=tol,
                                   err_msg=k)
