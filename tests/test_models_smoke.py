"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, asserting output shapes + no NaNs (deliverable f)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_smoke_config
from repro.models import registry

SMOKE_SHAPE = dataclasses.replace(SHAPES["train_4k"], seq_len=64,
                                  global_batch=2)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, rng):
    cfg = get_smoke_config(arch)
    params = registry.init_params(cfg, rng)
    batch = registry.make_batch(cfg, SMOKE_SHAPE, rng)

    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda pp: registry.loss_fn(cfg, pp, b), has_aux=True)(p)
        new = jax.tree.map(lambda w, g: w - 0.01 * g.astype(w.dtype),
                           p, grads)
        return loss, new

    loss, new_params = jax.jit(step)(params, batch)
    assert jnp.isfinite(loss), f"{arch}: loss is not finite"
    # params changed and stayed finite
    leaves = jax.tree.leaves(new_params)
    assert all(jnp.isfinite(l).all() for l in leaves), f"{arch}: NaN params"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch, rng):
    cfg = get_smoke_config(arch)
    params = registry.init_params(cfg, rng)
    batch = registry.make_batch(cfg, SMOKE_SHAPE, rng)
    if cfg.family == "vlm":
        # decode path is text-only (vision embeds enter at prefill; equal
        # (t,h,w) positions make M-RoPE == RoPE for text decode)
        batch = {"tokens": batch["tokens"], "labels": batch["labels"]}
    logits, cache = jax.jit(
        lambda p, b: registry.run_prefill(cfg, p, b, max_len=96))(
            params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), f"{arch}: prefill logits NaN"

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = jax.jit(
        lambda p, c, t: registry.decode_step(cfg, p, c, t))(
            params, cache, tok)
    assert logits2.shape == (2, cfg.vocab_size)
    assert jnp.isfinite(logits2).all(), f"{arch}: decode logits NaN"
    assert int(cache2["len"]) == int(cache["len"]) + 1


def test_decode_matches_prefill_dense(rng):
    """Teacher-forced decode reproduces full-forward logits (dense)."""
    cfg = get_smoke_config("smollm-360m")
    params = registry.init_params(cfg, rng)
    toks = jax.random.randint(rng, (1, 8), 0, cfg.vocab_size, jnp.int32)
    from repro.models import dense
    # full forward logits at each position
    h = dense.forward(cfg, params, toks)
    full_logits = h @ dense.head_matrix(cfg, params)
    # prefill on prefix, then decode the remaining tokens one by one
    logits, cache = dense.prefill(cfg, params, toks[:, :4], max_len=8)
    assert jnp.allclose(logits, full_logits[:, 3].astype(jnp.float32),
                        atol=2e-2, rtol=2e-2)
    for i in range(4, 8):
        logits, cache = dense.decode_step(cfg, params, cache, toks[:, i:i+1])
        if i < 7:
            assert jnp.allclose(logits,
                                full_logits[:, i].astype(jnp.float32),
                                atol=2e-2, rtol=2e-2), f"pos {i} mismatch"
