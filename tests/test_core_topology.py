"""Unit + property tests for repro.core.topology."""
import numpy as np
import pytest
# hypothesis is optional (dev dependency): the guard skips only the
# property tests when it is absent, plain tests still run
from _hypothesis_compat import given, settings, st

from repro.core import topology as topo


def test_ring_and_full_shapes():
    for n in (2, 3, 8, 30):
        r = topo.ring_topology(n)
        f = topo.full_topology(n)
        topo.validate_topology(r)
        topo.validate_topology(f)
        assert topo.is_connected(r) and topo.is_connected(f)
        assert f.sum() == n * (n - 1)


def test_algebraic_connectivity_matches_bfs():
    rng = np.random.default_rng(0)
    for _ in range(20):
        n = int(rng.integers(2, 12))
        a = topo.erdos_topology(n, 0.4, rng)
        assert topo.is_connected(a) == (topo.algebraic_connectivity(a) > 1e-9)
    # a deliberately disconnected graph
    a = np.zeros((4, 4), dtype=np.int8)
    a[0, 1] = a[1, 0] = 1
    a[2, 3] = a[3, 2] = 1
    assert not topo.is_connected(a)
    assert topo.algebraic_connectivity(a) < 1e-9


@given(st.integers(min_value=2, max_value=16), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_mixing_matrices_doubly_stochastic(n, seed):
    rng = np.random.default_rng(seed)
    a = topo.erdos_topology(n, 0.5, rng)
    for fn in (topo.mixing_matrix_uniform, topo.mixing_matrix_metropolis):
        w = fn(a)
        assert np.allclose(w.sum(axis=0), 1.0)
        assert np.allclose(w.sum(axis=1), 1.0)
        assert np.allclose(w, w.T)
        assert (w >= -1e-12).all()
        # support: w_ij > 0 only on edges or diagonal
        off = w - np.diag(np.diag(w))
        assert ((off > 1e-12) <= (a > 0)).all()


@given(st.integers(min_value=2, max_value=20), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_spectral_gap_less_than_one_iff_connected(n, seed):
    rng = np.random.default_rng(seed)
    a = topo.erdos_topology(n, 0.5, rng)
    w = topo.mixing_matrix_uniform(a)
    rho = topo.spectral_gap_rho(w)
    assert 0.0 <= rho < 1.0  # Assumption 4 holds for connected graphs


def test_rho_fully_connected_is_zero_and_ring_is_large():
    w_full = topo.mixing_matrix_uniform(topo.full_topology(36))
    assert topo.spectral_gap_rho(w_full) < 1e-10
    w_ring = topo.mixing_matrix_uniform(topo.ring_topology(36))
    rho = topo.spectral_gap_rho(w_ring)
    assert rho > 0.95  # paper Sec III: ~0.99 for ring of 36


@given(st.integers(min_value=2, max_value=16), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_matching_decomposition_partitions_edges(n, seed):
    rng = np.random.default_rng(seed)
    a = topo.erdos_topology(n, 0.5, rng)
    matchings = topo.matching_decomposition(a)
    seen = set()
    for m in matchings:
        verts = [v for e in m for v in e]
        assert len(verts) == len(set(verts)), "matching has shared vertex"
        for e in m:
            assert a[e[0], e[1]] == 1
            assert e not in seen
            seen.add(e)
    assert len(seen) == a.sum() // 2, "every edge exactly once"
    # greedy bound: <= 2*Delta - 1
    delta = int(a.sum(axis=1).max())
    assert len(matchings) <= max(1, 2 * delta - 1)


def test_matchings_to_perms_involutions():
    a = topo.erdos_topology(8, 0.5, np.random.default_rng(3))
    ms = topo.matching_decomposition(a)
    perms = topo.matchings_to_perms(ms, 8)
    for row in perms:
        assert (row[row] == np.arange(8)).all()  # involution


@given(st.integers(min_value=3, max_value=16), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_masked_mixing_matrices_respect_membership(n, seed):
    """Mixing matrices built from a churn-masked adjacency (what both
    engines feed mixfn) stay row-stochastic and symmetric, keep zero
    off-diagonal mass on dead rows/cols (dead workers neither send nor
    receive), and keep support inside the surviving edge set."""
    rng = np.random.default_rng(seed)
    adj = topo.erdos_topology(n, 0.5, rng)
    alive = rng.random(n) > 0.3
    if not alive.any():
        alive[int(rng.integers(n))] = True
    masked = adj.copy()
    masked[~alive, :] = 0
    masked[:, ~alive] = 0
    for fn in (topo.mixing_matrix_uniform, topo.mixing_matrix_metropolis):
        w = fn(masked)
        assert np.allclose(w.sum(axis=0), 1.0)
        assert np.allclose(w.sum(axis=1), 1.0)
        assert np.allclose(w, w.T)
        off = w - np.diag(np.diag(w))
        dead = ~alive
        assert np.allclose(off[dead, :], 0.0)
        assert np.allclose(off[:, dead], 0.0)
        # dead workers self-mix only: their models stay frozen under
        # x <- Wx, which is exactly the engines' no-op row semantics
        assert np.allclose(np.diag(w)[dead], 1.0)
        assert ((off > 1e-12) <= (masked > 0)).all()


@given(st.integers(min_value=3, max_value=14), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_repair_connectivity_connected_and_deterministic(n, seed):
    """repair_connectivity on a random (adjacency, alive) pair yields a
    connected survivor subgraph, and for a fixed cost matrix the greedy
    reconnection is a pure function of its inputs."""
    rng = np.random.default_rng(seed)
    adj = (rng.random((n, n)) < 0.25).astype(np.int8)
    adj = np.maximum(adj, adj.T)
    np.fill_diagonal(adj, 0)
    alive = rng.random(n) > 0.35
    if alive.sum() < 2:
        alive[:2] = True
    cost = rng.uniform(0.1, 5.0, (n, n))
    cost = (cost + cost.T) / 2
    rep1 = topo.repair_connectivity(adj, alive, cost=cost)
    rep2 = topo.repair_connectivity(adj.copy(), alive.copy(),
                                    cost=cost.copy())
    np.testing.assert_array_equal(rep1, rep2)
    live = np.nonzero(alive)[0]
    assert topo.is_connected(rep1[np.ix_(live, live)])
    assert rep1[~alive].sum() == 0 and rep1[:, ~alive].sum() == 0
    # repair only ever ADDS edges among survivors
    assert (rep1[np.ix_(live, live)] >= adj[np.ix_(live, live)]).all()


def test_validate_topology_rejects_bad():
    with pytest.raises(ValueError):
        topo.validate_topology(np.ones((3, 3), dtype=np.int8))  # self loops
    bad = np.zeros((3, 3), dtype=np.int8)
    bad[0, 1] = 1  # asymmetric
    with pytest.raises(ValueError):
        topo.validate_topology(bad)
