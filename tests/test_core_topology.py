"""Unit + property tests for repro.core.topology."""
import numpy as np
import pytest
# hypothesis is optional (dev dependency): the guard skips only the
# property tests when it is absent, plain tests still run
from _hypothesis_compat import given, settings, st

from repro.core import topology as topo


def test_ring_and_full_shapes():
    for n in (2, 3, 8, 30):
        r = topo.ring_topology(n)
        f = topo.full_topology(n)
        topo.validate_topology(r)
        topo.validate_topology(f)
        assert topo.is_connected(r) and topo.is_connected(f)
        assert f.sum() == n * (n - 1)


def test_algebraic_connectivity_matches_bfs():
    rng = np.random.default_rng(0)
    for _ in range(20):
        n = int(rng.integers(2, 12))
        a = topo.erdos_topology(n, 0.4, rng)
        assert topo.is_connected(a) == (topo.algebraic_connectivity(a) > 1e-9)
    # a deliberately disconnected graph
    a = np.zeros((4, 4), dtype=np.int8)
    a[0, 1] = a[1, 0] = 1
    a[2, 3] = a[3, 2] = 1
    assert not topo.is_connected(a)
    assert topo.algebraic_connectivity(a) < 1e-9


@given(st.integers(min_value=2, max_value=16), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_mixing_matrices_doubly_stochastic(n, seed):
    rng = np.random.default_rng(seed)
    a = topo.erdos_topology(n, 0.5, rng)
    for fn in (topo.mixing_matrix_uniform, topo.mixing_matrix_metropolis):
        w = fn(a)
        assert np.allclose(w.sum(axis=0), 1.0)
        assert np.allclose(w.sum(axis=1), 1.0)
        assert np.allclose(w, w.T)
        assert (w >= -1e-12).all()
        # support: w_ij > 0 only on edges or diagonal
        off = w - np.diag(np.diag(w))
        assert ((off > 1e-12) <= (a > 0)).all()


@given(st.integers(min_value=2, max_value=20), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_spectral_gap_less_than_one_iff_connected(n, seed):
    rng = np.random.default_rng(seed)
    a = topo.erdos_topology(n, 0.5, rng)
    w = topo.mixing_matrix_uniform(a)
    rho = topo.spectral_gap_rho(w)
    assert 0.0 <= rho < 1.0  # Assumption 4 holds for connected graphs


def test_rho_fully_connected_is_zero_and_ring_is_large():
    w_full = topo.mixing_matrix_uniform(topo.full_topology(36))
    assert topo.spectral_gap_rho(w_full) < 1e-10
    w_ring = topo.mixing_matrix_uniform(topo.ring_topology(36))
    rho = topo.spectral_gap_rho(w_ring)
    assert rho > 0.95  # paper Sec III: ~0.99 for ring of 36


@given(st.integers(min_value=2, max_value=16), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_matching_decomposition_partitions_edges(n, seed):
    rng = np.random.default_rng(seed)
    a = topo.erdos_topology(n, 0.5, rng)
    matchings = topo.matching_decomposition(a)
    seen = set()
    for m in matchings:
        verts = [v for e in m for v in e]
        assert len(verts) == len(set(verts)), "matching has shared vertex"
        for e in m:
            assert a[e[0], e[1]] == 1
            assert e not in seen
            seen.add(e)
    assert len(seen) == a.sum() // 2, "every edge exactly once"
    # greedy bound: <= 2*Delta - 1
    delta = int(a.sum(axis=1).max())
    assert len(matchings) <= max(1, 2 * delta - 1)


def test_matchings_to_perms_involutions():
    a = topo.erdos_topology(8, 0.5, np.random.default_rng(3))
    ms = topo.matching_decomposition(a)
    perms = topo.matchings_to_perms(ms, 8)
    for row in perms:
        assert (row[row] == np.arange(8)).all()  # involution


@given(st.integers(min_value=3, max_value=16), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_masked_mixing_matrices_respect_membership(n, seed):
    """Mixing matrices built from a churn-masked adjacency (what both
    engines feed mixfn) stay row-stochastic and symmetric, keep zero
    off-diagonal mass on dead rows/cols (dead workers neither send nor
    receive), and keep support inside the surviving edge set."""
    rng = np.random.default_rng(seed)
    adj = topo.erdos_topology(n, 0.5, rng)
    alive = rng.random(n) > 0.3
    if not alive.any():
        alive[int(rng.integers(n))] = True
    masked = adj.copy()
    masked[~alive, :] = 0
    masked[:, ~alive] = 0
    for fn in (topo.mixing_matrix_uniform, topo.mixing_matrix_metropolis):
        w = fn(masked)
        assert np.allclose(w.sum(axis=0), 1.0)
        assert np.allclose(w.sum(axis=1), 1.0)
        assert np.allclose(w, w.T)
        off = w - np.diag(np.diag(w))
        dead = ~alive
        assert np.allclose(off[dead, :], 0.0)
        assert np.allclose(off[:, dead], 0.0)
        # dead workers self-mix only: their models stay frozen under
        # x <- Wx, which is exactly the engines' no-op row semantics
        assert np.allclose(np.diag(w)[dead], 1.0)
        assert ((off > 1e-12) <= (masked > 0)).all()


@given(st.integers(min_value=3, max_value=14), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_repair_connectivity_connected_and_deterministic(n, seed):
    """repair_connectivity on a random (adjacency, alive) pair yields a
    connected survivor subgraph, and for a fixed cost matrix the greedy
    reconnection is a pure function of its inputs."""
    rng = np.random.default_rng(seed)
    adj = (rng.random((n, n)) < 0.25).astype(np.int8)
    adj = np.maximum(adj, adj.T)
    np.fill_diagonal(adj, 0)
    alive = rng.random(n) > 0.35
    if alive.sum() < 2:
        alive[:2] = True
    cost = rng.uniform(0.1, 5.0, (n, n))
    cost = (cost + cost.T) / 2
    rep1 = topo.repair_connectivity(adj, alive, cost=cost)
    rep2 = topo.repair_connectivity(adj.copy(), alive.copy(),
                                    cost=cost.copy())
    np.testing.assert_array_equal(rep1, rep2)
    live = np.nonzero(alive)[0]
    assert topo.is_connected(rep1[np.ix_(live, live)])
    assert rep1[~alive].sum() == 0 and rep1[:, ~alive].sum() == 0
    # repair only ever ADDS edges among survivors
    assert (rep1[np.ix_(live, live)] >= adj[np.ix_(live, live)]).all()


def _min_forest_cost(adj, alive, cost):
    """Independent reference: Prim's MST total over the component graph
    (each component-pair weighted by its cheapest cross edge) — the
    optimal total cost any reconnection of the survivors can achieve."""
    live = np.nonzero(alive)[0]
    comps = topo.connected_components(adj, live)
    k = len(comps)
    if k <= 1:
        return 0.0
    wmat = np.full((k, k), np.inf)
    for a in range(k):
        for b in range(a + 1, k):
            w = cost[np.ix_(comps[a], comps[b])].min()
            wmat[a, b] = wmat[b, a] = w
    in_tree = {0}
    total = 0.0
    while len(in_tree) < k:
        best, pick = np.inf, -1
        for a in in_tree:
            for b in range(k):
                if b not in in_tree and wmat[a, b] < best:
                    best, pick = wmat[a, b], b
        in_tree.add(pick)
        total += best
    return total


@given(st.integers(min_value=3, max_value=14), st.integers(0, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_repair_adds_minimum_cost_forest(n, seed):
    """The greedy global-cheapest merge is Kruskal over the component
    graph, so the total cost of the edges repair adds must equal the
    minimum spanning forest cost (brute-force Prim reference)."""
    rng = np.random.default_rng(seed)
    adj = (rng.random((n, n)) < 0.2).astype(np.int8)
    adj = np.maximum(adj, adj.T)
    np.fill_diagonal(adj, 0)
    alive = rng.random(n) > 0.35
    if alive.sum() < 2:
        alive[:2] = True
    cost = rng.uniform(0.1, 5.0, (n, n))
    cost = (cost + cost.T) / 2
    rep = topo.repair_connectivity(adj, alive, cost=cost)
    masked = adj.copy()
    masked[~alive, :] = 0
    masked[:, ~alive] = 0
    added = np.triu((rep - masked) > 0, k=1)
    got = float(cost[added].sum())
    want = _min_forest_cost(adj, alive, cost)
    assert got == pytest.approx(want, rel=1e-12)


def test_repair_minimum_forest_seeded_sweep():
    """Non-hypothesis twin of the property test above (hypothesis is an
    optional dev dependency): 100 seeded random (adj, alive, cost) cases."""
    rng = np.random.default_rng(42)
    for _ in range(100):
        n = int(rng.integers(3, 14))
        adj = (rng.random((n, n)) < 0.2).astype(np.int8)
        adj = np.maximum(adj, adj.T)
        np.fill_diagonal(adj, 0)
        alive = rng.random(n) > 0.35
        if alive.sum() < 2:
            alive[:2] = True
        cost = rng.uniform(0.1, 5.0, (n, n))
        cost = (cost + cost.T) / 2
        rep = topo.repair_connectivity(adj, alive, cost=cost)
        masked = adj.copy()
        masked[~alive, :] = 0
        masked[:, ~alive] = 0
        added = np.triu((rep - masked) > 0, k=1)
        got = float(cost[added].sum())
        assert got == pytest.approx(_min_forest_cost(adj, alive, cost),
                                    rel=1e-12)
        live = np.nonzero(alive)[0]
        assert topo.is_connected(rep[np.ix_(live, live)])


def test_repair_picks_global_cheapest_cross_edge():
    """Regression for the comps[0]-anchored scan: with three components
    {0,1} {2,3} {4,5}, the cheapest cross-component edge (2, 4) does not
    touch the first component — a comps[0]-anchored greedy would start
    with a costlier edge; the global Kruskal merge must add (2, 4)."""
    n = 6
    adj = np.zeros((n, n), np.int8)
    for (i, j) in ((0, 1), (2, 3), (4, 5)):
        adj[i, j] = adj[j, i] = 1
    cost = np.full((n, n), 10.0)
    cost[2, 4] = cost[4, 2] = 0.5
    cost[0, 2] = cost[2, 0] = 3.0
    np.fill_diagonal(cost, 0.0)
    alive = np.ones(n, bool)
    rep = topo.repair_connectivity(adj, alive, cost=cost)
    assert rep[2, 4] == 1 and rep[4, 2] == 1
    assert topo.is_connected(rep)
    # exactly two edges added (three components -> forest of two links)
    assert (np.triu(rep - adj, k=1) > 0).sum() == 2


def test_erdos_fallback_warns_and_adds_chords():
    """An unsatisfiably low p cannot draw a connected graph, so the
    fallback must warn and return ring + chords, never a bare ring."""
    n, p = 30, 0.04   # expected edges 17 < n-1: connectivity impossible
    with pytest.warns(RuntimeWarning, match="falling back to ring"):
        a = topo.erdos_topology(n, p, np.random.default_rng(0))
    topo.validate_topology(a)
    assert topo.is_connected(a)
    ring_edges = topo.ring_topology(n).sum() // 2
    extra = a.sum() // 2 - ring_edges
    assert extra >= 1, "fallback degraded to a bare ring"
    target = max(1, int(round(p * n * (n - 1) / 2)) - n)
    assert extra == target


def test_erdos_fallback_higher_p_matches_density():
    """With a p whose expected edge count exceeds the ring's, the chord
    count recovers the requested density (minus the ring edges)."""
    n, p = 40, 0.055  # expected 42.9 edges, still << connectivity threshold
    with pytest.warns(RuntimeWarning):
        a = topo.erdos_topology(n, p, np.random.default_rng(1))
    assert topo.is_connected(a)
    want = n + max(1, int(round(p * n * (n - 1) / 2)) - n)
    assert a.sum() // 2 == want


def test_validate_topology_rejects_bad():
    with pytest.raises(ValueError):
        topo.validate_topology(np.ones((3, 3), dtype=np.int8))  # self loops
    bad = np.zeros((3, 3), dtype=np.int8)
    bad[0, 1] = 1  # asymmetric
    with pytest.raises(ValueError):
        topo.validate_topology(bad)


# ---------------------------------------------------------------------------
# complex-network families: Barabasi-Albert, Watts-Strogatz, geo/racks
# ---------------------------------------------------------------------------

FAMILY_SPECS = ("ba:1", "ba:2", "ba:3", "ws:2:0.0", "ws:4:0.2", "ws:6:1.0",
                "geo:1", "geo:3", "geo:5")


def test_families_valid_connected_roundtrip():
    """Every family x size x seed: validate_topology passes, the graph is
    connected, and it survives the edges_from_adj/adj_from_edges
    round-trip (so both the dense and the edge-list engines can run it)."""
    for spec in FAMILY_SPECS:
        for n in (5, 8, 17):
            if spec.startswith("ws:") and n <= int(spec.split(":")[1]):
                continue             # WS needs k < n
            for seed in range(3):
                a = topo.make_base_topology(n, spec, seed)
                topo.validate_topology(a)
                assert topo.is_connected(a), (spec, n, seed)
                e = topo.edges_from_adj(a)
                np.testing.assert_array_equal(topo.adj_from_edges(e, n), a,
                                              err_msg=f"{spec} n={n}")


def test_families_deterministic_per_seed():
    for spec in ("ba:2", "ws:4:0.3", "geo:3"):
        a = topo.make_base_topology(12, spec, 7)
        b = topo.make_base_topology(12, spec, 7)
        np.testing.assert_array_equal(a, b, err_msg=spec)
        c = topo.make_base_topology(12, spec, 8)
        if spec != "geo:3":          # geo's rack blocks are seed-free
            assert not np.array_equal(a, c), spec


def test_ba_edge_count_and_hubs():
    """BA attaches each of the n-m-1 later nodes with exactly m edges to a
    complete (m+1)-core, so the total edge count is closed-form; the
    preferential attachment should make the max degree exceed m."""
    rng = np.random.default_rng(0)
    for n, m in ((10, 1), (20, 2), (40, 3)):
        a = topo.barabasi_albert_topology(n, m, rng)
        want = m * (m + 1) // 2 + m * (n - m - 1)
        assert a.sum() // 2 == want, (n, m)
        assert a.sum(axis=1).max() > m, "no hub emerged"
    with pytest.raises(ValueError):
        topo.barabasi_albert_topology(4, 0, rng)
    with pytest.raises(ValueError):
        topo.barabasi_albert_topology(4, 4, rng)


def test_ws_zero_p_is_ring_lattice():
    """p=0 disables rewiring: the graph is exactly the circulant lattice
    with degree k everywhere."""
    n, k = 12, 4
    a = topo.watts_strogatz_topology(n, k, 0.0, np.random.default_rng(0))
    assert (a.sum(axis=1) == k).all()
    for i in range(n):
        for off in range(1, k // 2 + 1):
            assert a[i, (i + off) % n] == 1
    with pytest.raises(ValueError):
        topo.watts_strogatz_topology(6, 3, 0.1, np.random.default_rng(0))
    with pytest.raises(ValueError):
        topo.watts_strogatz_topology(6, 2, 1.5, np.random.default_rng(0))


def test_ws_rewiring_preserves_degree_sum():
    """Rewiring moves endpoints but never adds/removes edges: the edge
    count is invariant for any p."""
    n, k = 20, 4
    for p in (0.1, 0.5, 1.0):
        a = topo.watts_strogatz_topology(n, k, p, np.random.default_rng(3))
        assert a.sum() // 2 == n * k // 2, p
        assert topo.is_connected(a)


def test_rack_assignment_contiguous_blocks():
    assign = topo.rack_assignment(10, 3)
    assert assign.shape == (10,)
    # contiguous, sorted, covers all racks
    assert (np.diff(assign) >= 0).all()
    assert set(assign.tolist()) == {0, 1, 2}
    np.testing.assert_array_equal(np.bincount(assign), [4, 3, 3])
    with pytest.raises(ValueError):
        topo.rack_assignment(4, 5)


def test_geo_intra_rack_complete_plus_ring_uplinks():
    n, racks = 12, 4
    a = topo.geo_topology(n, racks, np.random.default_rng(0))
    assign = topo.rack_assignment(n, racks)
    same = np.equal.outer(assign, assign)
    np.fill_diagonal(same, False)
    # within a rack: complete
    assert (a[same] == 1).all()
    # across racks: exactly one uplink per ring edge (racks >= 3 -> racks
    # ring edges; racks == 2 would collapse the two ring directions)
    assert a[~same & np.triu(np.ones((n, n), bool), 1)].sum() == racks


def test_metropolis_vectorized_matches_loop():
    """The vectorized Metropolis-Hastings weights must be BIT-identical
    to the original O(N^2) loop (the differential engine tests depend on
    exact reproducibility of the mixing matrix)."""
    rng = np.random.default_rng(5)
    for spec in ("ba:2", "ws:4:0.2", "erdos:0.4"):
        for n in (6, 9, 16):
            adj = topo.make_base_topology(n, spec, int(rng.integers(1e6)))
            deg = adj.sum(axis=1)
            w_loop = np.zeros((n, n))
            for i in range(n):
                for j in range(n):
                    if adj[i, j]:
                        w_loop[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
                w_loop[i, i] = 1.0 - w_loop[i].sum()
            np.testing.assert_array_equal(topo.mixing_matrix_metropolis(adj),
                                          w_loop, err_msg=f"{spec} n={n}")


@given(st.integers(min_value=4, max_value=24), st.integers(0, 2**31 - 1),
       st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_ba_property_valid_connected(n, seed, m):
    a = topo.barabasi_albert_topology(n, m, np.random.default_rng(seed))
    topo.validate_topology(a)
    assert topo.is_connected(a)


@given(st.integers(min_value=6, max_value=24), st.integers(0, 2**31 - 1),
       st.floats(0.0, 1.0))
@settings(max_examples=25, deadline=None)
def test_ws_property_valid_connected(n, seed, p):
    a = topo.watts_strogatz_topology(n, 4, p, np.random.default_rng(seed))
    topo.validate_topology(a)
    assert topo.is_connected(a)
