"""Unit + property tests for repro.core.topology."""
import numpy as np
import pytest
# hypothesis is optional (dev dependency): the guard skips only the
# property tests when it is absent, plain tests still run
from _hypothesis_compat import given, settings, st

from repro.core import topology as topo


def test_ring_and_full_shapes():
    for n in (2, 3, 8, 30):
        r = topo.ring_topology(n)
        f = topo.full_topology(n)
        topo.validate_topology(r)
        topo.validate_topology(f)
        assert topo.is_connected(r) and topo.is_connected(f)
        assert f.sum() == n * (n - 1)


def test_algebraic_connectivity_matches_bfs():
    rng = np.random.default_rng(0)
    for _ in range(20):
        n = int(rng.integers(2, 12))
        a = topo.erdos_topology(n, 0.4, rng)
        assert topo.is_connected(a) == (topo.algebraic_connectivity(a) > 1e-9)
    # a deliberately disconnected graph
    a = np.zeros((4, 4), dtype=np.int8)
    a[0, 1] = a[1, 0] = 1
    a[2, 3] = a[3, 2] = 1
    assert not topo.is_connected(a)
    assert topo.algebraic_connectivity(a) < 1e-9


@given(st.integers(min_value=2, max_value=16), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_mixing_matrices_doubly_stochastic(n, seed):
    rng = np.random.default_rng(seed)
    a = topo.erdos_topology(n, 0.5, rng)
    for fn in (topo.mixing_matrix_uniform, topo.mixing_matrix_metropolis):
        w = fn(a)
        assert np.allclose(w.sum(axis=0), 1.0)
        assert np.allclose(w.sum(axis=1), 1.0)
        assert np.allclose(w, w.T)
        assert (w >= -1e-12).all()
        # support: w_ij > 0 only on edges or diagonal
        off = w - np.diag(np.diag(w))
        assert ((off > 1e-12) <= (a > 0)).all()


@given(st.integers(min_value=2, max_value=20), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_spectral_gap_less_than_one_iff_connected(n, seed):
    rng = np.random.default_rng(seed)
    a = topo.erdos_topology(n, 0.5, rng)
    w = topo.mixing_matrix_uniform(a)
    rho = topo.spectral_gap_rho(w)
    assert 0.0 <= rho < 1.0  # Assumption 4 holds for connected graphs


def test_rho_fully_connected_is_zero_and_ring_is_large():
    w_full = topo.mixing_matrix_uniform(topo.full_topology(36))
    assert topo.spectral_gap_rho(w_full) < 1e-10
    w_ring = topo.mixing_matrix_uniform(topo.ring_topology(36))
    rho = topo.spectral_gap_rho(w_ring)
    assert rho > 0.95  # paper Sec III: ~0.99 for ring of 36


@given(st.integers(min_value=2, max_value=16), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_matching_decomposition_partitions_edges(n, seed):
    rng = np.random.default_rng(seed)
    a = topo.erdos_topology(n, 0.5, rng)
    matchings = topo.matching_decomposition(a)
    seen = set()
    for m in matchings:
        verts = [v for e in m for v in e]
        assert len(verts) == len(set(verts)), "matching has shared vertex"
        for e in m:
            assert a[e[0], e[1]] == 1
            assert e not in seen
            seen.add(e)
    assert len(seen) == a.sum() // 2, "every edge exactly once"
    # greedy bound: <= 2*Delta - 1
    delta = int(a.sum(axis=1).max())
    assert len(matchings) <= max(1, 2 * delta - 1)


def test_matchings_to_perms_involutions():
    a = topo.erdos_topology(8, 0.5, np.random.default_rng(3))
    ms = topo.matching_decomposition(a)
    perms = topo.matchings_to_perms(ms, 8)
    for row in perms:
        assert (row[row] == np.arange(8)).all()  # involution


@given(st.integers(min_value=3, max_value=16), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_masked_mixing_matrices_respect_membership(n, seed):
    """Mixing matrices built from a churn-masked adjacency (what both
    engines feed mixfn) stay row-stochastic and symmetric, keep zero
    off-diagonal mass on dead rows/cols (dead workers neither send nor
    receive), and keep support inside the surviving edge set."""
    rng = np.random.default_rng(seed)
    adj = topo.erdos_topology(n, 0.5, rng)
    alive = rng.random(n) > 0.3
    if not alive.any():
        alive[int(rng.integers(n))] = True
    masked = adj.copy()
    masked[~alive, :] = 0
    masked[:, ~alive] = 0
    for fn in (topo.mixing_matrix_uniform, topo.mixing_matrix_metropolis):
        w = fn(masked)
        assert np.allclose(w.sum(axis=0), 1.0)
        assert np.allclose(w.sum(axis=1), 1.0)
        assert np.allclose(w, w.T)
        off = w - np.diag(np.diag(w))
        dead = ~alive
        assert np.allclose(off[dead, :], 0.0)
        assert np.allclose(off[:, dead], 0.0)
        # dead workers self-mix only: their models stay frozen under
        # x <- Wx, which is exactly the engines' no-op row semantics
        assert np.allclose(np.diag(w)[dead], 1.0)
        assert ((off > 1e-12) <= (masked > 0)).all()


@given(st.integers(min_value=3, max_value=14), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_repair_connectivity_connected_and_deterministic(n, seed):
    """repair_connectivity on a random (adjacency, alive) pair yields a
    connected survivor subgraph, and for a fixed cost matrix the greedy
    reconnection is a pure function of its inputs."""
    rng = np.random.default_rng(seed)
    adj = (rng.random((n, n)) < 0.25).astype(np.int8)
    adj = np.maximum(adj, adj.T)
    np.fill_diagonal(adj, 0)
    alive = rng.random(n) > 0.35
    if alive.sum() < 2:
        alive[:2] = True
    cost = rng.uniform(0.1, 5.0, (n, n))
    cost = (cost + cost.T) / 2
    rep1 = topo.repair_connectivity(adj, alive, cost=cost)
    rep2 = topo.repair_connectivity(adj.copy(), alive.copy(),
                                    cost=cost.copy())
    np.testing.assert_array_equal(rep1, rep2)
    live = np.nonzero(alive)[0]
    assert topo.is_connected(rep1[np.ix_(live, live)])
    assert rep1[~alive].sum() == 0 and rep1[:, ~alive].sum() == 0
    # repair only ever ADDS edges among survivors
    assert (rep1[np.ix_(live, live)] >= adj[np.ix_(live, live)]).all()


def _min_forest_cost(adj, alive, cost):
    """Independent reference: Prim's MST total over the component graph
    (each component-pair weighted by its cheapest cross edge) — the
    optimal total cost any reconnection of the survivors can achieve."""
    live = np.nonzero(alive)[0]
    comps = topo.connected_components(adj, live)
    k = len(comps)
    if k <= 1:
        return 0.0
    wmat = np.full((k, k), np.inf)
    for a in range(k):
        for b in range(a + 1, k):
            w = cost[np.ix_(comps[a], comps[b])].min()
            wmat[a, b] = wmat[b, a] = w
    in_tree = {0}
    total = 0.0
    while len(in_tree) < k:
        best, pick = np.inf, -1
        for a in in_tree:
            for b in range(k):
                if b not in in_tree and wmat[a, b] < best:
                    best, pick = wmat[a, b], b
        in_tree.add(pick)
        total += best
    return total


@given(st.integers(min_value=3, max_value=14), st.integers(0, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_repair_adds_minimum_cost_forest(n, seed):
    """The greedy global-cheapest merge is Kruskal over the component
    graph, so the total cost of the edges repair adds must equal the
    minimum spanning forest cost (brute-force Prim reference)."""
    rng = np.random.default_rng(seed)
    adj = (rng.random((n, n)) < 0.2).astype(np.int8)
    adj = np.maximum(adj, adj.T)
    np.fill_diagonal(adj, 0)
    alive = rng.random(n) > 0.35
    if alive.sum() < 2:
        alive[:2] = True
    cost = rng.uniform(0.1, 5.0, (n, n))
    cost = (cost + cost.T) / 2
    rep = topo.repair_connectivity(adj, alive, cost=cost)
    masked = adj.copy()
    masked[~alive, :] = 0
    masked[:, ~alive] = 0
    added = np.triu((rep - masked) > 0, k=1)
    got = float(cost[added].sum())
    want = _min_forest_cost(adj, alive, cost)
    assert got == pytest.approx(want, rel=1e-12)


def test_repair_minimum_forest_seeded_sweep():
    """Non-hypothesis twin of the property test above (hypothesis is an
    optional dev dependency): 100 seeded random (adj, alive, cost) cases."""
    rng = np.random.default_rng(42)
    for _ in range(100):
        n = int(rng.integers(3, 14))
        adj = (rng.random((n, n)) < 0.2).astype(np.int8)
        adj = np.maximum(adj, adj.T)
        np.fill_diagonal(adj, 0)
        alive = rng.random(n) > 0.35
        if alive.sum() < 2:
            alive[:2] = True
        cost = rng.uniform(0.1, 5.0, (n, n))
        cost = (cost + cost.T) / 2
        rep = topo.repair_connectivity(adj, alive, cost=cost)
        masked = adj.copy()
        masked[~alive, :] = 0
        masked[:, ~alive] = 0
        added = np.triu((rep - masked) > 0, k=1)
        got = float(cost[added].sum())
        assert got == pytest.approx(_min_forest_cost(adj, alive, cost),
                                    rel=1e-12)
        live = np.nonzero(alive)[0]
        assert topo.is_connected(rep[np.ix_(live, live)])


def test_repair_picks_global_cheapest_cross_edge():
    """Regression for the comps[0]-anchored scan: with three components
    {0,1} {2,3} {4,5}, the cheapest cross-component edge (2, 4) does not
    touch the first component — a comps[0]-anchored greedy would start
    with a costlier edge; the global Kruskal merge must add (2, 4)."""
    n = 6
    adj = np.zeros((n, n), np.int8)
    for (i, j) in ((0, 1), (2, 3), (4, 5)):
        adj[i, j] = adj[j, i] = 1
    cost = np.full((n, n), 10.0)
    cost[2, 4] = cost[4, 2] = 0.5
    cost[0, 2] = cost[2, 0] = 3.0
    np.fill_diagonal(cost, 0.0)
    alive = np.ones(n, bool)
    rep = topo.repair_connectivity(adj, alive, cost=cost)
    assert rep[2, 4] == 1 and rep[4, 2] == 1
    assert topo.is_connected(rep)
    # exactly two edges added (three components -> forest of two links)
    assert (np.triu(rep - adj, k=1) > 0).sum() == 2


def test_erdos_fallback_warns_and_adds_chords():
    """An unsatisfiably low p cannot draw a connected graph, so the
    fallback must warn and return ring + chords, never a bare ring."""
    n, p = 30, 0.04   # expected edges 17 < n-1: connectivity impossible
    with pytest.warns(RuntimeWarning, match="falling back to ring"):
        a = topo.erdos_topology(n, p, np.random.default_rng(0))
    topo.validate_topology(a)
    assert topo.is_connected(a)
    ring_edges = topo.ring_topology(n).sum() // 2
    extra = a.sum() // 2 - ring_edges
    assert extra >= 1, "fallback degraded to a bare ring"
    target = max(1, int(round(p * n * (n - 1) / 2)) - n)
    assert extra == target


def test_erdos_fallback_higher_p_matches_density():
    """With a p whose expected edge count exceeds the ring's, the chord
    count recovers the requested density (minus the ring edges)."""
    n, p = 40, 0.055  # expected 42.9 edges, still << connectivity threshold
    with pytest.warns(RuntimeWarning):
        a = topo.erdos_topology(n, p, np.random.default_rng(1))
    assert topo.is_connected(a)
    want = n + max(1, int(round(p * n * (n - 1) / 2)) - n)
    assert a.sum() // 2 == want


def test_validate_topology_rejects_bad():
    with pytest.raises(ValueError):
        topo.validate_topology(np.ones((3, 3), dtype=np.int8))  # self loops
    bad = np.zeros((3, 3), dtype=np.int8)
    bad[0, 1] = 1  # asymmetric
    with pytest.raises(ValueError):
        topo.validate_topology(bad)
