"""Roofline derivation unit tests: HLO collective parsing + term math."""
from __future__ import annotations

import numpy as np

from repro.launch import roofline as rl

HLO = """
HloModule jit_step

%region_0 (a: f32[], b: f32[]) -> f32[] {
  ROOT %add = f32[] add(%a, %b)
}

ENTRY %main {
  %p0 = bf16[2,6144]{1,0} parameter(0)
  %p1 = f32[128,1024]{1,0} parameter(1)
  %ag = bf16[32,6144]{1,0} all-gather(%p0), replica_groups=[16,16]<=[256]
  %ar = f32[128,1024]{1,0} all-reduce(%p1), to_apply=%region_0
  %cp = bf16[2,6144]{1,0} collective-permute(%p0), source_target_pairs={{0,1},{1,0}}
  %rs-start = f32[8,1024]{1,0} reduce-scatter-start(%p1), dimensions={0}
  %dot = f32[128,128]{1,0} dot(%p1, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  ROOT %t = (bf16[32,6144]{1,0}) tuple(%ag)
}
"""


def test_parse_collective_bytes_kinds_and_sizes():
    stats = rl.parse_collective_bytes(HLO)
    # all-gather operand: bf16[2,6144] = 24576 B
    assert stats.bytes_by_kind["all-gather"] == 2 * 6144 * 2
    # all-reduce operand: f32[128,1024] = 524288 B
    assert stats.bytes_by_kind["all-reduce"] == 128 * 1024 * 4
    # collective-permute operand: bf16[2,6144]
    assert stats.bytes_by_kind["collective-permute"] == 2 * 6144 * 2
    assert stats.count_by_kind == {"all-gather": 1, "all-reduce": 1,
                                   "collective-permute": 1,
                                   "reduce-scatter": 1}
    # dot / tuple / parameter are NOT collectives
    assert "dot" not in stats.bytes_by_kind


def test_roofline_terms_and_dominance():
    r = rl.Roofline(flops=197e12 * 256, hbm_bytes=819e9 * 256 * 2,
                    collective_bytes=50e9 * 256 * 0.5, chips=256)
    assert np.isclose(r.compute_s, 1.0)
    assert np.isclose(r.memory_s, 2.0)
    assert np.isclose(r.collective_s, 0.5)
    assert r.dominant == "memory"
    assert np.isclose(r.step_time_s, 2.0)
    # MFU bound: useful fraction over the binding term
    assert np.isclose(r.mfu(197e12 * 256), 0.5)


def test_model_flops_kinds():
    from repro.configs import SHAPES, get_config
    cfg = get_config("smollm-360m")
    t = rl.model_flops(cfg, SHAPES["train_4k"])
    p = rl.model_flops(cfg, SHAPES["prefill_32k"])
    d = rl.model_flops(cfg, SHAPES["decode_32k"])
    n = cfg.param_count()
    assert np.isclose(t, 6 * n * 4096 * 256, rtol=1e-6)
    assert np.isclose(p, 2 * n * 32768 * 32, rtol=1e-6)
    assert np.isclose(d, 2 * n * 128, rtol=1e-6)
    # MoE uses ACTIVE params
    moe = rl.model_flops(get_config("kimi-k2-1t-a32b"), SHAPES["train_4k"])
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.active_param_count() < 0.1 * kimi.param_count()
    assert np.isclose(moe, 6 * kimi.active_param_count() * 4096 * 256,
                      rtol=1e-6)


def test_hardware_constants_match_spec():
    assert rl.PEAK_FLOPS == 197e12
    assert rl.HBM_BW == 819e9
    assert rl.ICI_BW == 50e9
