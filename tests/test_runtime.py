"""Distributed-runtime tests: gossip collectives, train step, compression,
checkpoint/elastic — run in a subprocess so the 8-device host platform
doesn't leak into other tests (spec: never set device count globally)."""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# pytest files that skip themselves below 8 devices; the launchers here
# run them with the device count forced so they execute under tier 1.
# The CI multi-device lane runs the same files directly (it exports
# XLA_FLAGS itself), so keep this list in sync with .github/workflows.
MULTI_DEVICE_TEST_FILES = ["test_collectives.py", "test_sharded_engine.py"]


def _run_in_8dev_subprocess(cmd, timeout):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=timeout, cwd=REPO)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-2000:])
    return proc


@pytest.mark.slow
def test_runtime_multi_device_checks():
    proc = _run_in_8dev_subprocess(
        [sys.executable, os.path.join(REPO, "tests", "_runtime_checks.py")],
        timeout=1200)
    assert proc.returncode == 0, "runtime checks failed (see output)"
    assert "FAIL" not in proc.stdout


@pytest.mark.slow
@pytest.mark.parametrize("fname", MULTI_DEVICE_TEST_FILES)
def test_multi_device_pytest_files(fname):
    """Launch the skipif-guarded multi-device pytest files on a forced
    8-device CPU subprocess (collectives parity + the sharded-engine
    differential matrix)."""
    proc = _run_in_8dev_subprocess(
        [sys.executable, "-m", "pytest", "-q",
         os.path.join(REPO, "tests", fname)], timeout=3000)
    assert proc.returncode == 0, f"{fname} failed under 8 devices"
