"""Distributed-runtime tests: gossip collectives, train step, compression,
checkpoint/elastic — run in a subprocess so the 8-device host platform
doesn't leak into other tests (spec: never set device count globally)."""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_runtime_multi_device_checks():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "_runtime_checks.py")],
        capture_output=True, text=True, env=env, timeout=1200)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-2000:])
    assert proc.returncode == 0, "runtime checks failed (see output)"
    assert "FAIL" not in proc.stdout
