"""Tests for consensus-distance estimation (Eq. 7-9, 36-39, 43)."""
import numpy as np
# hypothesis is optional (dev dependency): the guard skips only the
# property tests when it is absent, plain tests still run
from _hypothesis_compat import given, settings, st

from repro.core import topology as topo
from repro.core.consensus import (
    ConsensusTracker,
    consensus_distance_to_mean,
    floyd_warshall_estimate,
    measured_distance_matrix,
    pairwise_distances,
)


def _random_models(n, p, seed):
    return np.random.default_rng(seed).normal(size=(n, p))


def test_pairwise_matches_direct():
    x = _random_models(6, 40, 0)
    d = pairwise_distances(x)
    for i in range(6):
        for j in range(6):
            assert np.isclose(d[i, j], np.linalg.norm(x[i] - x[j]), atol=1e-8)


@given(st.integers(2, 10), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_fw_estimate_upper_bounds_true_distance(n, seed):
    """Triangle-inequality estimate (Eq. 37) never underestimates."""
    x = _random_models(n, 16, seed)
    true = pairwise_distances(x)
    adj = topo.ring_topology(n)
    est = floyd_warshall_estimate(measured_distance_matrix(adj, true))
    assert (est >= true - 1e-9).all()
    # measured edges are exact
    mask = adj > 0
    assert np.allclose(est[mask], true[mask])


def test_fw_estimate_exact_on_full_topology():
    x = _random_models(8, 32, 1)
    true = pairwise_distances(x)
    adj = topo.full_topology(8)
    est = floyd_warshall_estimate(measured_distance_matrix(adj, true))
    assert np.allclose(est, true)


def test_tracker_budget_zero_for_full_topology():
    """Eq. (36): fully-connected topology -> D^{h+1} bound is 0."""
    n = 6
    tr = ConsensusTracker(n)
    x = _random_models(n, 8, 2)
    adj = topo.full_topology(n)
    tr.update(adj, pairwise_distances(x), mean_update_norm=1.0)
    assert tr.average_consensus_bound(adj) == 0.0
    assert tr.satisfies_budget(adj)


def test_tracker_dmax_ema():
    tr = ConsensusTracker(4, beta2=0.5)
    adj = topo.full_topology(4)
    d = np.zeros((4, 4))
    tr.update(adj, d, mean_update_norm=2.0)
    assert np.isclose(tr.d_max, 2.0)
    tr.update(adj, d, mean_update_norm=4.0)
    assert np.isclose(tr.d_max, 0.5 * 2.0 + 0.5 * 4.0)


def test_tracker_ema_smooths_unmeasured_only():
    n = 5
    tr = ConsensusTracker(n, beta1=0.5)
    x = _random_models(n, 8, 3)
    true = pairwise_distances(x)
    ring = topo.ring_topology(n)
    tr.update(ring, true, 1.0)
    first = tr.dist.copy()
    # second round with the same measurements: measured entries unchanged,
    # unmeasured entries EMA-converge toward the FW estimate
    tr.update(ring, true, 1.0)
    mask = ring > 0
    assert np.allclose(tr.dist[mask], first[mask])


def test_consensus_distance_to_mean():
    x = np.stack([np.zeros(4), np.ones(4) * 2])
    d = consensus_distance_to_mean(x)
    assert np.allclose(d, [2.0, 2.0])  # mean=1 -> each at L2 distance 2
