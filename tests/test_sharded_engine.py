"""Differential harness: the sharded execution path vs the single-device
oracles, on a forced multi-device CPU mesh.

``engine.run_dfl(mesh=...)`` and ``run_dfl_fused(mesh=...)`` split the
flat [W, P] worker matrix over the mesh's worker axis
(``runtime/shardexec``): local SGD and the join blend run per shard,
gossip rides the ppermute-routed edge tables. These tests prove the
sharded trajectory interchangeable with the unsharded engine it mirrors:

- HOST-side record fields (round/round_time/waiting_time/mean_tau/
  num_links/cumulative_time) are produced by the identical control plane
  and must match BIT-EXACTLY — the sharded path only moves device math;
- device metrics (accuracy/loss/consensus) differ only by the routed
  delta's summation order, ~1e-7 per round, so uncompressed runs match
  to the standard DEVICE_TOL;
- compressed runs get a documented looser tolerance: payloads are
  bit-identical per row (the oracle row codecs run on both sides), but
  int8's quantization buckets amplify the 1e-7 mixing-order noise — a
  boundary coordinate lands in the adjacent bucket, and over ~10 rounds
  that compounds to ~1e-3 in accuracy (measured 1.5e-3 worst case).
  Adaptive strategies are therefore NOT paired with codecs here: FedHP's
  integer tau/topology decisions consume the noisy measurements and a
  flipped plan breaks host-field exactness — inherent to
  adaptive x quantized, not a sharding bug.

Requires >= 8 devices: skips under plain pytest, runs via the
tests/test_runtime.py subprocess launcher or the CI multi-device lane.
"""
from __future__ import annotations

from dataclasses import replace

import jax
import numpy as np
import pytest

from repro.configs.base import FedHPConfig
from repro.core.experiment import run_algorithm
from repro.simulation.cluster import ChurnEvent, ChurnSchedule

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8; see tests/test_runtime.py launcher)")

CFG = FedHPConfig(num_workers=8, rounds=8, tau_init=5, tau_max=20,
                  lr=0.1, batch_size=32, seed=3, gossip="sparse")

SCHED = ChurnSchedule((
    ChurnEvent(2, "leave", 1),
    ChurnEvent(3, "crash", 6),
    ChurnEvent(4, "straggle", 2, factor=5.0, duration=3),
    ChurnEvent(6, "join", 1),
))

EXACT = ("round", "round_time", "waiting_time", "mean_tau", "num_links",
         "cumulative_time")
DEVICE_TOL = {"accuracy": 1e-6, "loss": 1e-4, "consensus": 1e-4}
# compressed sharded cells: see module docstring — int8 bucket flips
# compound the 1e-7 summation-order noise into ~1e-3 over 10 rounds
# (measured 1.5e-3 accuracy worst case); a routing or residual bug blows
# past this by orders of magnitude
SHARDED_COMPRESSED_TOL = {"accuracy": 5e-3, "loss": 1e-2,
                          "consensus": 1e-2}


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import make_worker_mesh
    return make_worker_mesh(4)


def _assert_equivalent(h_ref, h_shard, device_tol=DEVICE_TOL):
    assert len(h_ref.records) == len(h_shard.records)
    a, b = h_ref.as_arrays(), h_shard.as_arrays()
    for k in EXACT:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    for k, tol in device_tol.items():
        np.testing.assert_allclose(a[k], b[k], rtol=tol, atol=tol,
                                   err_msg=k)


def _pair(mesh, algo, *, fused, churn=None, cfg=CFG, **kw):
    h_o = run_algorithm(algo, cfg, non_iid_p=0.4, spread=3.0, fused=fused,
                        churn=churn, **kw)
    h_s = run_algorithm(algo, cfg, non_iid_p=0.4, spread=3.0, fused=fused,
                        churn=churn, mesh=mesh, **kw)
    return h_o, h_s


def test_sharded_matches_oracle_smoke(mesh):
    """Fast gate: reference D-PSGD, 6 rounds, no churn."""
    _assert_equivalent(*_pair(mesh, "dpsgd", fused=False,
                              cfg=replace(CFG, rounds=6)))


@pytest.mark.slow
@pytest.mark.parametrize("fused", [False, True], ids=["reference", "fused"])
@pytest.mark.parametrize("churn", [None, SCHED], ids=["nochurn", "churn"])
@pytest.mark.parametrize("algo", ["dpsgd", "ldsgd", "fedhp"])
def test_sharded_matches_oracle(mesh, algo, churn, fused):
    _assert_equivalent(*_pair(mesh, algo, fused=fused, churn=churn))


@pytest.mark.slow
@pytest.mark.parametrize("fused", [False, True], ids=["reference", "fused"])
@pytest.mark.parametrize("comp", ["int8", "topk:0.05", "randk:0.1"])
def test_sharded_matches_oracle_compressed(mesh, comp, fused):
    _assert_equivalent(
        *_pair(mesh, "dpsgd", fused=fused, cfg=replace(CFG, compress=comp)),
        device_tol=SHARDED_COMPRESSED_TOL)


@pytest.mark.slow
@pytest.mark.parametrize("fused", [False, True], ids=["reference", "fused"])
def test_sharded_padding_w_not_divisible(mesh, fused):
    """W=10 over 4 shards: the fleet pads to 12 rows; the inert rows
    (zero params, tau 0, no edges, zero metric weight) must not perturb
    anything the host sees."""
    h_o, h_s = _pair(mesh, "fedhp", fused=fused,
                     cfg=replace(CFG, num_workers=10))
    _assert_equivalent(h_o, h_s)
    # final_params come back sliced to the real fleet
    lead = jax.tree.leaves(h_s.final_params)[0].shape[0]
    assert lead == 10


@pytest.mark.slow
def test_sharded_dense_config_uses_edge_form(mesh):
    """cfg.gossip='dense' still runs the edge-list transport when sharded
    (per-edge weights are bit-identical to the dense off-diagonals), so
    the trajectory matches the dense oracle."""
    _assert_equivalent(*_pair(mesh, "dpsgd", fused=False,
                              cfg=replace(CFG, gossip="dense")))
    _assert_equivalent(*_pair(mesh, "dpsgd", fused=True,
                              cfg=replace(CFG, gossip="dense")))


def test_sharded_exclusions_raise(mesh):
    """The documented single-device-only modes fail loudly, not wrongly."""
    with pytest.raises(ValueError, match="AD-PSGD"):
        run_algorithm("adpsgd", CFG, mesh=mesh)
    with pytest.raises(ValueError, match="cross-loss"):
        run_algorithm("pens", CFG, mesh=mesh, fused=True)
    with pytest.raises(ValueError, match="seeds|lane"):
        run_algorithm("dpsgd", CFG, mesh=mesh, fused=True,
                      seeds=np.arange(2))
    with pytest.raises(ValueError, match="leaf"):
        run_algorithm("dpsgd", replace(CFG, compress="leafmap:default=int8"),
                      mesh=mesh, fused=True)
    with pytest.raises(ValueError, match="single-device"):
        run_algorithm("dpsgd", replace(CFG, robust="trimmed:1"), mesh=mesh)
