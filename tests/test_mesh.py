"""Mesh construction guards (launch/mesh.py).

``make_debug_mesh`` used to hand the shape straight to ``jax.make_mesh``,
which on a too-small host silently builds a mesh over however many
devices exist — every shard_map downstream then computes with the wrong
worker extent. These tests pin the fixed contract: raise by default,
shrink deterministically (with a warning) on request. They run at ANY
device count — the oversubscribed shape is derived from the live count —
so they belong to tier 1 directly, no subprocess needed.
"""
from __future__ import annotations

import math

import jax
import pytest

from repro.launch.mesh import make_debug_mesh, make_worker_mesh

NDEV = len(jax.devices())


def test_debug_mesh_fits_host():
    mesh = make_debug_mesh((NDEV, 1), ("data", "model"))
    assert mesh.shape == {"data": NDEV, "model": 1}


def test_debug_mesh_raises_when_oversubscribed():
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_debug_mesh((2 * NDEV, 2), ("data", "model"))


def test_debug_mesh_shrinks_deterministically():
    with pytest.warns(UserWarning, match="shrank mesh"):
        mesh = make_debug_mesh((2 * NDEV, 2), ("data", "model"),
                               shrink=True)
    sizes = [mesh.shape[a] for a in ("data", "model")]
    assert math.prod(sizes) <= NDEV
    # halving the leftmost even axis first: the doubled axis comes back
    # down before the trailing one is touched
    assert sizes[0] <= 2 * NDEV
    with pytest.warns(UserWarning):
        again = make_debug_mesh((2 * NDEV, 2), ("data", "model"),
                                shrink=True)
    assert [again.shape[a] for a in ("data", "model")] == sizes


def test_debug_mesh_shrink_handles_odd_axes():
    with pytest.warns(UserWarning):
        mesh = make_debug_mesh((3 * NDEV, 1), ("data", "model"),
                               shrink=True)
    assert math.prod(mesh.shape[a] for a in ("data", "model")) <= NDEV


def test_worker_mesh_defaults_to_all_devices():
    mesh = make_worker_mesh()
    assert mesh.axis_names == ("workers",)
    assert mesh.shape["workers"] == NDEV


def test_worker_mesh_validates_range():
    with pytest.raises(ValueError, match="out of range"):
        make_worker_mesh(NDEV + 1)
    with pytest.raises(ValueError, match="out of range"):
        make_worker_mesh(0)
