"""Differential harness: the fused scan engines vs the reference engines.

``run_dfl_fused`` (and, below, the event-driven ``run_adpsgd_fused``) is
only allowed on the hot path because these tests prove it
interchangeable with ``run_dfl`` (resp. ``run_adpsgd``): identical
host-side streams
(cluster RNG, churn schedule, batch draws, strategy plans) and device
trajectories (accuracy / consensus / cumulative_time) within float
tolerance, across strategies, with and without churn, and with the
vmapped-seeds batching matching independent runs.

Tolerances: host-computed fields (times, taus, links) are replayed with
the same formulas and must match exactly; device metrics go through one
fused XLA program instead of ~10 per round, so reductions re-associate —
they match to ~1e-5 relative. FedHP closes the loop (measurements feed
integer tau / topology decisions), so any drift would compound into
divergent plans; the exact match on mean_tau/num_links is the strongest
evidence the fused measurement path reproduces the reference's.
"""
from __future__ import annotations

from dataclasses import replace

import numpy as np
import pytest
# hypothesis is optional (dev dependency): the guard skips only the
# property tests when it is absent, plain tests still run
from _hypothesis_compat import given, settings, st

from repro.configs.base import FedHPConfig
from repro.core.experiment import run_algorithm
from repro.simulation.cluster import ChurnEvent, ChurnSchedule

CFG = FedHPConfig(num_workers=8, rounds=10, tau_init=5, tau_max=20,
                  lr=0.1, batch_size=32, seed=3)
# compressed gossip: same shape, int8 + error feedback on the wire
CCFG = replace(CFG, compress="int8")

# joins, a graceful leave, a crash and a straggler spike inside 10 rounds
SCHED = ChurnSchedule((
    ChurnEvent(2, "leave", 1),
    ChurnEvent(3, "crash", 6),
    ChurnEvent(4, "straggle", 2, factor=5.0, duration=3),
    ChurnEvent(6, "join", 1),
))

# host-replayed fields must be bit-identical; device trajectories may
# re-associate reductions inside the fused program
EXACT = ("round", "round_time", "waiting_time", "mean_tau", "num_links",
         "cumulative_time")
DEVICE_TOL = {"accuracy": 1e-6, "loss": 1e-4, "consensus": 1e-4}
# compressed runs: int8 rounding amplifies cross-program ulp differences
# to a full quantization step on rare boundary coordinates, so consensus
# drifts up to ~2e-4 absolute (measured 2.4e-4 worst case across
# strategies ± churn); accuracy still matches exactly and a real
# residual-update divergence would blow past this by orders of magnitude
COMPRESSED_TOL = {"accuracy": 1e-6, "loss": 1e-4, "consensus": 2e-3}


def _assert_equivalent(h_ref, h_fus, device_tol=DEVICE_TOL):
    assert len(h_ref.records) == len(h_fus.records)
    a, b = h_ref.as_arrays(), h_fus.as_arrays()
    for k in EXACT:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    for k, tol in device_tol.items():
        np.testing.assert_allclose(a[k], b[k], rtol=tol, atol=tol,
                                   err_msg=k)


def _pair(algo, churn, rounds=10, cfg=CFG, **kw):
    h_ref = run_algorithm(algo, cfg, non_iid_p=0.4, rounds=rounds,
                          churn=churn, **kw)
    h_fus = run_algorithm(algo, cfg, non_iid_p=0.4, rounds=rounds,
                          churn=churn, fused=True, **kw)
    return h_ref, h_fus


def test_fused_matches_reference_dpsgd_smoke():
    """Fast gate: D-PSGD, 6 rounds, no churn — runs in the default CI
    lane; the full strategy x churn matrix is in the slow set below."""
    _assert_equivalent(*_pair("dpsgd", None, rounds=6))


@pytest.mark.slow
@pytest.mark.parametrize("churn", [None, SCHED], ids=["nochurn", "churn"])
@pytest.mark.parametrize("algo", ["dpsgd", "ldsgd", "fedhp"])
def test_fused_matches_reference(algo, churn):
    _assert_equivalent(*_pair(algo, churn))


@pytest.mark.slow
def test_fused_matches_reference_pens():
    """PENS exercises the cross-loss surfacing and per-plan RNG replay."""
    _assert_equivalent(*_pair("pens", None))


@pytest.mark.slow
def test_fused_matches_reference_metropolis_mixing():
    _assert_equivalent(*_pair("dpsgd", SCHED, mixing="metropolis"))


@pytest.mark.slow
def test_fused_time_budget_cuts_identically():
    h_ref, h_fus = _pair("dpsgd", None, time_budget=5.0)
    assert len(h_ref.records) == len(h_fus.records)
    assert h_ref.records[-1].cumulative_time >= 5.0
    _assert_equivalent(h_ref, h_fus)


@pytest.mark.slow
@settings(max_examples=4, deadline=None)
@given(algo=st.sampled_from(["dpsgd", "ldsgd", "fedhp"]),
       churn=st.booleans(),
       rounds=st.integers(4, 8))
def test_fused_matches_reference_property(algo, churn, rounds):
    """Property sweep over (strategy, churn, horizon): the equivalence is
    not tuned to one trajectory length or schedule."""
    _assert_equivalent(*_pair(algo, SCHED if churn else None,
                              rounds=rounds))


# ---------------------------------------------------------------------------
# compressed gossip (int8 + error feedback) through both engines
# ---------------------------------------------------------------------------

def test_compressed_fused_matches_reference_smoke():
    """Fast gate for the compressed path: D-PSGD, 6 rounds, no churn."""
    _assert_equivalent(*_pair("dpsgd", None, rounds=6, cfg=CCFG),
                       device_tol=COMPRESSED_TOL)


@pytest.mark.slow
@pytest.mark.parametrize("churn", [None, SCHED], ids=["nochurn", "churn"])
@pytest.mark.parametrize("algo", ["dpsgd", "ldsgd", "fedhp", "pens"])
def test_compressed_fused_matches_reference(algo, churn):
    """The compressed update (Pallas quantize kernels + residual scan
    state in the fused engine vs jnp oracle + eager residuals in the
    reference) stays interchangeable across strategies ± churn."""
    _assert_equivalent(*_pair(algo, churn, cfg=CCFG),
                       device_tol=COMPRESSED_TOL)


@pytest.mark.slow
def test_compressed_no_error_feedback_matches_too():
    """Naive quantized mixing (EF off) is a distinct code path — the
    engines must still agree on it."""
    cfg = replace(CCFG, error_feedback=False)
    _assert_equivalent(*_pair("dpsgd", SCHED, cfg=cfg),
                       device_tol=COMPRESSED_TOL)


def test_compressed_changes_trajectory_and_cuts_comm_time():
    """Sanity: compression is actually on — the device trajectory differs
    from the uncompressed run and every communication round is charged
    comm_time / wire_ratio, so the clock runs strictly faster."""
    h_u = run_algorithm("dpsgd", CFG, non_iid_p=0.4, rounds=6)
    h_c = run_algorithm("dpsgd", CCFG, non_iid_p=0.4, rounds=6)
    a, b = h_u.as_arrays(), h_c.as_arrays()
    assert not np.array_equal(a["consensus"], b["consensus"])
    assert (b["round_time"] < a["round_time"]).all()


@pytest.mark.slow
def test_compressed_vmapped_seeds_match_independent_runs():
    """Residual state is per-lane: a vmapped compressed scan equals
    independent compressed runs."""
    import jax.numpy as jnp
    seeds = (11, 12)
    batched = run_algorithm("dpsgd", CCFG, non_iid_p=0.4, rounds=6,
                            fused=True, seeds=jnp.asarray(seeds))
    for s, hv in zip(seeds, batched):
        (hi,) = run_algorithm("dpsgd", CCFG, non_iid_p=0.4, rounds=6,
                              fused=True, seeds=jnp.asarray([s]))
        a, b = hv.as_arrays(), hi.as_arrays()
        for k in EXACT:
            np.testing.assert_array_equal(a[k], b[k], err_msg=f"{s}:{k}")
        for k, tol in COMPRESSED_TOL.items():
            np.testing.assert_allclose(a[k], b[k], rtol=tol, atol=tol,
                                       err_msg=f"{s}:{k}")


# ---------------------------------------------------------------------------
# sparsified gossip (top-k / rand-k) through both engines
# ---------------------------------------------------------------------------

# sparse codecs at a 25% keep fraction: x̂-tracked top-k and shared-mask
# rand-k (codec state rides the fused scan exactly like int8 residuals)
TKCFG = replace(CFG, compress="topk:0.25")
RKCFG = replace(CFG, compress="randk:0.25")


def test_sparse_fused_matches_reference_smoke():
    """Fast gate for the sparse paths: D-PSGD, 6 rounds, no churn."""
    _assert_equivalent(*_pair("dpsgd", None, rounds=6, cfg=TKCFG),
                       device_tol=COMPRESSED_TOL)
    _assert_equivalent(*_pair("dpsgd", None, rounds=6, cfg=RKCFG),
                       device_tol=COMPRESSED_TOL)


@pytest.mark.slow
@pytest.mark.parametrize("churn", [None, SCHED], ids=["nochurn", "churn"])
@pytest.mark.parametrize("cfg", [TKCFG, RKCFG], ids=["topk", "randk"])
@pytest.mark.parametrize("algo", ["dpsgd", "ldsgd", "fedhp"])
def test_sparse_fused_matches_reference(algo, cfg, churn):
    """The sparse updates (Pallas mask-and-pack kernel + codec state in
    the fused scan vs jnp oracle + eager state in the reference) stay
    interchangeable across strategies ± churn."""
    _assert_equivalent(*_pair(algo, churn, cfg=cfg),
                       device_tol=COMPRESSED_TOL)


@pytest.mark.slow
@pytest.mark.parametrize("cfg", [TKCFG, RKCFG], ids=["topk", "randk"])
def test_sparse_no_error_feedback_matches_too(cfg):
    """Naive sparse mixing (EF off: no x̂ tracking / no state) is a
    distinct code path — the engines must still agree on it."""
    _assert_equivalent(*_pair("dpsgd", SCHED,
                              cfg=replace(cfg, error_feedback=False)),
                       device_tol=COMPRESSED_TOL)


@pytest.mark.slow
def test_tighten_k_fused_matches_reference():
    """The replan-cadence k-tightening feedback path (plan.codec) must
    replay identically in both engines: the tightened codec changes the
    Eq. 10 wire charge, so any divergence shows up in the bit-exact
    round_time/cumulative_time columns immediately."""
    cfg = replace(CFG, compress="topk:0.5", tighten_k=True,
                  sparse_k_floor=0.125)
    _assert_equivalent(*_pair("fedhp", None, rounds=12, cfg=cfg),
                       device_tol=COMPRESSED_TOL)


@pytest.mark.slow
def test_sparse_vmapped_seeds_match_independent_runs():
    """Codec state is per-lane while the rand-k mask stream is shared
    (cfg.seed-derived): a vmapped sparse scan equals independent runs."""
    import jax.numpy as jnp
    seeds = (11, 12)
    for cfg in (TKCFG, RKCFG):
        batched = run_algorithm("dpsgd", cfg, non_iid_p=0.4, rounds=6,
                                fused=True, seeds=jnp.asarray(seeds))
        for s, hv in zip(seeds, batched):
            (hi,) = run_algorithm("dpsgd", cfg, non_iid_p=0.4, rounds=6,
                                  fused=True, seeds=jnp.asarray([s]))
            a, b = hv.as_arrays(), hi.as_arrays()
            for k in EXACT:
                np.testing.assert_array_equal(a[k], b[k],
                                              err_msg=f"{s}:{k}")
            for k, tol in COMPRESSED_TOL.items():
                np.testing.assert_allclose(a[k], b[k], rtol=tol, atol=tol,
                                           err_msg=f"{s}:{k}")


def test_sparse_cuts_comm_time_per_codec():
    """Eq. 10 charges each codec's own wire ratio: rand-k (no indices on
    the wire) runs a strictly faster clock than top-k at the same k,
    which beats uncompressed."""
    h_u = run_algorithm("dpsgd", CFG, non_iid_p=0.4, rounds=6)
    h_t = run_algorithm("dpsgd", TKCFG, non_iid_p=0.4, rounds=6)
    h_r = run_algorithm("dpsgd", RKCFG, non_iid_p=0.4, rounds=6)
    u, t, r = (h.as_arrays() for h in (h_u, h_t, h_r))
    assert (t["round_time"] < u["round_time"]).all()
    assert (r["round_time"] < t["round_time"]).all()


# ---------------------------------------------------------------------------
# AD-PSGD: fused event-driven scan vs the reference event loop
# ---------------------------------------------------------------------------

# AD-PSGD host fields include the per-round mean staleness (computed from
# the shared event schedule) — exact like the other host-replayed fields
ADPSGD_EXACT = EXACT + ("staleness",)


def _assert_adpsgd_equivalent(h_ref, h_fus, device_tol=DEVICE_TOL):
    assert len(h_ref.records) == len(h_fus.records)
    a, b = h_ref.as_arrays(), h_fus.as_arrays()
    for k in ADPSGD_EXACT:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    for k, tol in device_tol.items():
        np.testing.assert_allclose(a[k], b[k], rtol=tol, atol=tol,
                                   err_msg=k)


def test_adpsgd_fused_matches_reference_smoke():
    """Fast gate: 6 rounds, no churn, uncompressed — runs in the default
    CI lane; the seed x churn x compression matrix is in the slow set."""
    _assert_adpsgd_equivalent(*_pair("adpsgd", None, rounds=6))


@pytest.mark.slow
@pytest.mark.parametrize("churn", [None, SCHED], ids=["nochurn", "churn"])
@pytest.mark.parametrize("seed", [3, 11, 29])
def test_adpsgd_fused_matches_reference(seed, churn):
    """The fused event scan replays the reference loop's schedule, batch
    stream and pairwise math across seeds ± churn."""
    cfg = replace(CFG, seed=seed)
    _assert_adpsgd_equivalent(*_pair("adpsgd", churn, cfg=cfg))


@pytest.mark.slow
@pytest.mark.parametrize("churn", [None, SCHED], ids=["nochurn", "churn"])
@pytest.mark.parametrize("seed", [3, 11])
def test_adpsgd_compressed_fused_matches_reference(seed, churn):
    """Compressed pairwise exchange: Pallas quantize kernels + residual
    scan state vs the jnp oracle path of the reference loop."""
    cfg = replace(CCFG, seed=seed)
    _assert_adpsgd_equivalent(*_pair("adpsgd", churn, cfg=cfg),
                              device_tol=COMPRESSED_TOL)


@pytest.mark.slow
def test_adpsgd_compressed_no_error_feedback_matches_too():
    cfg = replace(CCFG, error_feedback=False)
    _assert_adpsgd_equivalent(*_pair("adpsgd", SCHED, cfg=cfg),
                              device_tol=COMPRESSED_TOL)


@pytest.mark.slow
@pytest.mark.parametrize("churn", [None, SCHED], ids=["nochurn", "churn"])
@pytest.mark.parametrize("cfg", [TKCFG, RKCFG], ids=["topk", "randk"])
def test_adpsgd_sparse_fused_matches_reference(cfg, churn):
    """Sparse pairwise exchange: the x̂-tracked / shared-mask event
    updates (Pallas kernels + per-worker codec state in the scan carry)
    stay interchangeable with the reference event loop — including the
    per-event rand-k mask stream indexed by the global event counter."""
    _assert_adpsgd_equivalent(*_pair("adpsgd", churn, cfg=cfg),
                              device_tol=COMPRESSED_TOL)


@pytest.mark.slow
@pytest.mark.parametrize("cfg", [TKCFG, RKCFG], ids=["topk", "randk"])
def test_adpsgd_sparse_no_error_feedback_matches_too(cfg):
    _assert_adpsgd_equivalent(
        *_pair("adpsgd", SCHED, cfg=replace(cfg, error_feedback=False)),
        device_tol=COMPRESSED_TOL)


@pytest.mark.slow
def test_adpsgd_time_budget_cuts_identically():
    h_ref, h_fus = _pair("adpsgd", None, time_budget=3.0)
    assert h_ref.records[-1].cumulative_time >= 3.0
    _assert_adpsgd_equivalent(h_ref, h_fus)


def test_adpsgd_compressed_charges_less_event_time():
    """Compressed events pay beta / wire_ratio (Eq. 10), so the event
    clock runs strictly faster; the trajectory itself changes too."""
    h_u = run_algorithm("adpsgd", CFG, non_iid_p=0.4, rounds=6)
    h_c = run_algorithm("adpsgd", CCFG, non_iid_p=0.4, rounds=6)
    a, b = h_u.as_arrays(), h_c.as_arrays()
    assert b["cumulative_time"][-1] < a["cumulative_time"][-1]
    assert not np.array_equal(a["consensus"], b["consensus"])


@pytest.mark.slow
def test_adpsgd_vmapped_seeds_match_independent_runs():
    """Batched lanes share the cfg.seed event schedule; each lane's model
    init + batch stream must match its own single-lane run, and the
    cfg.seed lane reproduces the unbatched run exactly."""
    import jax.numpy as jnp
    seeds = (3, 11)                     # 3 == CFG.seed
    batched = run_algorithm("adpsgd", CFG, non_iid_p=0.4, rounds=6,
                            fused=True, seeds=jnp.asarray(seeds))
    assert len(batched) == len(seeds)
    for s, hv in zip(seeds, batched):
        (hi,) = run_algorithm("adpsgd", CFG, non_iid_p=0.4, rounds=6,
                              fused=True, seeds=jnp.asarray([s]))
        a, b = hv.as_arrays(), hi.as_arrays()
        for k in ADPSGD_EXACT:
            np.testing.assert_array_equal(a[k], b[k], err_msg=f"{s}:{k}")
        for k in ("accuracy", "loss", "consensus"):
            np.testing.assert_array_equal(a[k], b[k], err_msg=f"{s}:{k}")
    unbatched = run_algorithm("adpsgd", CFG, non_iid_p=0.4, rounds=6,
                              fused=True)
    a, b = batched[0].as_arrays(), unbatched.as_arrays()
    for k in ADPSGD_EXACT + ("accuracy", "loss", "consensus"):
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


# ---------------------------------------------------------------------------
# vmapped seeds
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_vmapped_seeds_match_independent_runs():
    """One vmapped scan over S seeds == S independent fused runs."""
    import jax.numpy as jnp
    seeds = (11, 12, 13)
    batched = run_algorithm("dpsgd", CFG, non_iid_p=0.4, rounds=8,
                            fused=True, seeds=jnp.asarray(seeds))
    assert len(batched) == len(seeds)
    for s, hv in zip(seeds, batched):
        (hi,) = run_algorithm("dpsgd", CFG, non_iid_p=0.4, rounds=8,
                              fused=True, seeds=jnp.asarray([s]))
        a, b = hv.as_arrays(), hi.as_arrays()
        for k in EXACT:
            np.testing.assert_array_equal(a[k], b[k], err_msg=f"{s}:{k}")
        for k, tol in DEVICE_TOL.items():
            np.testing.assert_allclose(a[k], b[k], rtol=1e-5, atol=1e-5,
                                       err_msg=f"{s}:{k}")


def test_seeds_lanes_are_distinct_experiments():
    """Different seeds must give different trajectories (the lanes are not
    sharing a model init or batch stream)."""
    import jax.numpy as jnp
    h1, h2 = run_algorithm("dpsgd", CFG, non_iid_p=0.4, rounds=4,
                           fused=True, seeds=jnp.asarray([1, 2]))
    a, b = h1.as_arrays(), h2.as_arrays()
    assert not np.array_equal(a["consensus"], b["consensus"])
    # host-side control plane (cluster, plans, clock) is shared
    np.testing.assert_array_equal(a["cumulative_time"], b["cumulative_time"])


def test_seeds_reject_adaptive_strategies():
    import jax.numpy as jnp
    with pytest.raises(ValueError, match="adapts"):
        run_algorithm("fedhp", CFG, rounds=4, fused=True,
                      seeds=jnp.asarray([1, 2]))


# ---------------------------------------------------------------------------
# replan segmentation (the documented deviation knob)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_replan_segments_converge_too():
    """replan_every > 1 freezes FedHP's plan within segments — trajectories
    may deviate from the reference, but the run must still learn and keep
    the same record/bookkeeping structure."""
    from dataclasses import replace
    cfg = replace(CFG, replan_every=4)
    h = run_algorithm("fedhp", cfg, non_iid_p=0.4, rounds=12, fused=True)
    assert len(h.records) == 12
    assert np.isfinite([r.loss for r in h.records]).all()
    assert h.final_accuracy > 0.8
    assert h.final_accuracy > h.records[0].accuracy
