"""Tests for the FedHP adaptive control algorithm (Alg. 3)."""
import numpy as np
# hypothesis is optional (dev dependency): the guard skips only the
# property tests when it is absent, plain tests still run
from _hypothesis_compat import given, settings, st

from repro.core import topology as topo
from repro.core.consensus import ConsensusTracker, pairwise_distances
from repro.core.controller import (
    AdaptiveController,
    equalized_taus,
    evaluate_topology,
    prune_dead,
    theory_tau_star,
)


def _setup(n=8, seed=0, hetero=3.0):
    rng = np.random.default_rng(seed)
    mu = rng.uniform(1.0, hetero, size=n)          # per-iter compute time
    beta = rng.uniform(0.5, 5.0, size=(n, n))
    beta = (beta + beta.T) / 2
    np.fill_diagonal(beta, 0.0)
    x = rng.normal(size=(n, 32))
    return mu, beta, x


def _tracker(n, adj, x, d_scale=100.0):
    tr = ConsensusTracker(n)
    tr.update(adj, pairwise_distances(x), mean_update_norm=d_scale)
    return tr


def test_theory_tau_star_bounds_and_fallback():
    assert theory_tau_star(8, 2.0, 1.0, 100, 0.1, 1.0, tau_max=50) >= 1
    assert theory_tau_star(8, 0.0, 1.0, 100, 0.1, 1.0, tau_max=50) == 25
    assert theory_tau_star(8, 2.0, 0.0, 100, 0.1, 0.0, tau_max=50) == 25
    # monotone: more noise (sigma) -> smaller tau*
    hi = theory_tau_star(8, 2.0, 1.0, 100, 0.1, 0.5, tau_max=1000)
    lo = theory_tau_star(8, 2.0, 1.0, 100, 0.1, 2.0, tau_max=1000)
    assert hi >= lo


def test_equalized_taus_fast_worker_more_steps():
    """Eq. (40): higher-capability workers get larger tau."""
    n = 6
    mu = np.array([1.0, 1.0, 2.0, 2.0, 4.0, 8.0])
    beta = np.full((n, n), 1.0)
    np.fill_diagonal(beta, 0.0)
    adj = topo.full_topology(n)
    taus, pace = equalized_taus(adj, mu, beta, tau_star=16, tau_max=50)
    assert pace == 0 or pace == 1
    assert taus[0] >= taus[2] >= taus[4] >= taus[5] >= 1
    # equalization: all t_i <= pace time (up to tau >= 1 clamp)
    t = taus * mu + 1.0
    assert (t[:4] <= t[pace] + mu[:4]).all()


def test_evaluate_topology_waiting_time():
    mu, beta, _ = _setup()
    adj = topo.full_topology(8)
    d = evaluate_topology(adj, mu, beta, tau_star=10, tau_max=50)
    assert d.round_time > 0
    assert 0 <= d.waiting_time <= d.round_time


def test_controller_improves_round_time_vs_base():
    """Greedy link removal must never *increase* predicted round time."""
    n = 10
    mu, beta, x = _setup(n, seed=1)
    base = topo.full_topology(n)
    ctl = AdaptiveController(base, tau_max=50)
    tr = _tracker(n, base, x)
    d0 = evaluate_topology(base, mu, beta, 10, 50)
    dec = ctl.decide(mu, beta, tr, f1=2.0, smooth_l=1.0, sigma=1.0,
                     eta=0.1, rounds=100)
    assert dec.round_time <= d0.round_time + 1e-9
    assert topo.is_connected(dec.adj)
    assert tr.satisfies_budget(dec.adj)


def test_controller_respects_tight_consensus_budget():
    """With a tiny D_max no link may be removed -> base topology returned."""
    n = 6
    mu, beta, x = _setup(n, seed=2)
    base = topo.full_topology(n)
    ctl = AdaptiveController(base, tau_max=50)
    tr = _tracker(n, base, x, d_scale=1e-9)  # near-zero budget
    dec = ctl.decide(mu, beta, tr, f1=2.0, smooth_l=1.0, sigma=1.0,
                     eta=0.1, rounds=100)
    assert (dec.adj == base).all()


def test_controller_prunes_slow_links_with_loose_budget():
    n = 8
    mu, beta, x = _setup(n, seed=3)
    # one pathologically slow link
    beta[0, 1] = beta[1, 0] = 1e3
    base = topo.full_topology(n)
    ctl = AdaptiveController(base, tau_max=50)
    tr = _tracker(n, base, x, d_scale=1e9)  # effectively unconstrained
    dec = ctl.decide(mu, beta, tr, f1=2.0, smooth_l=1.0, sigma=1.0,
                     eta=0.1, rounds=100)
    assert dec.adj[0, 1] == 0, "slowest link should be pruned"
    assert topo.is_connected(dec.adj)


@given(st.integers(4, 12), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_controller_invariants(n, seed):
    mu, beta, x = _setup(n, seed)
    base = topo.full_topology(n)
    ctl = AdaptiveController(base, tau_max=30)
    tr = _tracker(n, base, x, d_scale=float(
        np.random.default_rng(seed).uniform(0.1, 1e3)))
    dec = ctl.decide(mu, beta, tr, f1=2.0, smooth_l=1.0, sigma=1.0,
                     eta=0.1, rounds=50)
    topo.validate_topology(dec.adj)
    assert topo.is_connected(dec.adj)
    assert tr.satisfies_budget(dec.adj)
    assert (dec.taus >= 1).all() and (dec.taus <= 30).all()
    # matchings cover the decided topology exactly
    cover = np.zeros_like(dec.adj)
    for m in dec.matchings:
        for (i, j) in m:
            cover[i, j] = cover[j, i] = 1
    assert (cover == dec.adj).all()


def test_prune_dead_repairs_connectivity():
    n = 6
    adj = topo.ring_topology(n)
    alive = np.array([True, False, True, True, False, True])
    pruned = prune_dead(adj, alive)
    dead = np.nonzero(~alive)[0]
    assert pruned[dead].sum() == 0 and pruned[:, dead].sum() == 0
    live = np.nonzero(alive)[0]
    assert topo.is_connected(pruned[np.ix_(live, live)])


# ---------------------------------------------------------------------------
# compression-aware planning (wire_ratio scales the Eq. 10 comm term)
# ---------------------------------------------------------------------------

def _decide(ctl, tr, mu, beta, wire_ratio, sigma=1.0, tau_max=None):
    return ctl.decide(mu, beta, tr, f1=2.0, smooth_l=1.0, sigma=sigma,
                      eta=0.1, rounds=100, wire_ratio=wire_ratio)


def test_wire_ratio_shifts_tau_star_monotonically():
    """Satellite property: the comm term scales with 1/wire_ratio, so
    LOWERING the wire ratio (more expensive wire) monotonically shifts
    tau* toward more local steps — the pace setter amortizes each
    costlier exchange over more compute — and no point of the sweep
    yields a disconnected topology or a busted consensus budget."""
    n = 10
    mu, beta, x = _setup(n, seed=4)
    base = topo.full_topology(n)
    taus, links = [], []
    for ratio in (16.0, 8.0, 4.0, 2.0, 1.0, 0.5):      # comm cost rising
        ctl = AdaptiveController(base, tau_max=200)
        tr = _tracker(n, base, x, d_scale=10.0)
        # large sigma -> the Remark 2 theory term is tiny, so tau* is
        # driven by the comm floor the wire ratio moves
        dec = _decide(ctl, tr, mu, beta, ratio, sigma=3.0)
        assert topo.is_connected(dec.adj)
        assert tr.satisfies_budget(dec.adj)
        assert dec.wire_ratio == ratio
        taus.append(dec.tau_pace)
    assert taus == sorted(taus), taus            # non-decreasing with cost
    assert taus[0] < taus[-1]                    # and actually moves


@given(st.integers(4, 12), st.integers(0, 2**31 - 1),
       st.sampled_from([2.0, 4.0, 8.0, 16.0]))
@settings(max_examples=15, deadline=None)
def test_wire_ratio_monotonicity_property(n, seed, hi):
    """For any heterogeneity draw: a cheaper wire never forces MORE
    local steps, and the decided topology stays connected at both ends
    of the ratio."""
    mu, beta, x = _setup(n, seed)
    base = topo.full_topology(n)
    outs = []
    for ratio in (1.0, hi):
        ctl = AdaptiveController(base, tau_max=100)
        tr = _tracker(n, base, x, d_scale=10.0)
        dec = _decide(ctl, tr, mu, beta, ratio, sigma=3.0)
        assert topo.is_connected(dec.adj)
        outs.append(dec)
    assert outs[1].tau_pace <= outs[0].tau_pace


def test_decision_responds_to_wire_ratio():
    """Acceptance: the planned (tau, topology) actually changes when the
    codec's wire ratio does — the planner is not compression-blind."""
    n = 10
    mu, beta, x = _setup(n, seed=6)
    beta *= 10.0                                  # comm-dominated cluster
    outs = []
    for ratio in (1.0, 8.0):
        ctl = AdaptiveController(topo.full_topology(n), tau_max=100)
        tr = _tracker(n, topo.full_topology(n), x, d_scale=1e3)
        outs.append(_decide(ctl, tr, mu, beta, ratio, sigma=3.0))
    a, b = outs
    assert not (np.array_equal(a.taus, b.taus)
                and np.array_equal(a.adj, b.adj))
    # the cheaper wire lowered the predicted round time
    assert b.round_time < a.round_time


# ---------------------------------------------------------------------------
# the replan-cadence sparsity feedback path (SparsityScheduler)
# ---------------------------------------------------------------------------

def test_sparsity_scheduler_halves_and_floors():
    from repro.core.compression import parse_mode
    from repro.core.controller import SparsityScheduler
    s = SparsityScheduler(parse_mode("topk:0.4"), floor_frac=0.25)
    assert s.step(10.0).k == 0.4          # first observation: anchor only
    assert s.step(9.0).k == 0.4           # not halved yet
    assert s.step(4.9).k == 0.2           # consensus halved -> k halves
    assert s.step(4.0).k == 0.2           # hysteresis re-anchored at 4.9
    assert s.step(2.0).k == 0.1           # floor 0.4 * 0.25
    assert s.step(0.1).k == 0.1           # never below the floor
    assert s.step(0.0).k == 0.1           # degenerate signals ignored
    assert s.step(float("nan")).k == 0.1


def test_sparsity_scheduler_absolute_spec_stays_absolute():
    """Halving an absolute keep count must never cross below 1.0 — that
    would silently reinterpret k as a fraction of P and EXPAND the
    payload instead of tightening it."""
    from repro.core.compression import parse_mode
    from repro.core.controller import SparsityScheduler
    s = SparsityScheduler(parse_mode("topk:3"), floor_frac=0.125)
    s.step(100.0)
    ks = [s.step(100.0 * 0.4 ** i).k for i in range(1, 6)]
    assert all(k >= 1.0 for k in ks), ks
    assert ks[-1] == 1.0
    # resolved counts only ever shrink (wire ratio only ever grows)
    res = [parse_mode("topk:3").with_k(k).resolve_k(1000) for k in ks]
    assert res == sorted(res, reverse=True) and res[-1] >= 1


def test_sparsity_scheduler_rejects_non_sparse():
    from repro.core.compression import parse_mode
    from repro.core.controller import SparsityScheduler
    import pytest
    with pytest.raises(ValueError, match="sparse"):
        SparsityScheduler(parse_mode("int8"))


def test_fedhp_strategy_learns_wire_ratio_and_tightens_k():
    """End-to-end feedback path at the strategy level: observe() feeds
    the engine's wire ratio into the next decide(), and with tighten_k
    the plan's codec halves k as the observed consensus distances
    shrink (replay identical in both engines — the observations are all
    host-side here)."""
    from dataclasses import replace as dreplace
    from repro.configs.base import FedHPConfig
    from repro.core.algorithms import FedHPStrategy
    n = 6
    cfg = FedHPConfig(num_workers=n, rounds=50, compress="topk:0.4",
                      tighten_k=True, sparse_k_floor=0.25,
                      replan_every=1)
    base = topo.full_topology(n)
    strat = FedHPStrategy(cfg, base)
    mu, beta, x = _setup(n, seed=7)
    p0 = strat.plan(0)
    assert p0.codec.k == 0.4
    dists = pairwise_distances(x)
    ks = []
    for h in range(6):
        scale = 0.4 ** h                 # consensus shrinking fast
        strat.observe(h, adj=base, mu=mu, beta=beta,
                      edge_dist=dists * scale, update_norms=[1e3],
                      smooth_l=1.0, sigma=1.0, loss=2.0, wire_ratio=5.0)
        plan = strat.plan(h + 1)
        ks.append(plan.codec.k)
    assert strat.last_decision.wire_ratio == 5.0   # learned, not assumed
    assert ks[-1] == 0.1                           # halved to the floor
    assert ks == sorted(ks, reverse=True)          # only ever tightens
    # the flag turns the learning off
    cfg2 = dreplace(cfg, planner_wire_aware=False)
    strat2 = FedHPStrategy(cfg2, base)
    strat2.observe(0, adj=base, mu=mu, beta=beta, edge_dist=dists,
                   update_norms=[1e3], smooth_l=1.0, sigma=1.0, loss=2.0,
                   wire_ratio=5.0)
    strat2.plan(1)
    assert strat2.last_decision.wire_ratio == 1.0


def test_controller_with_failures():
    n = 8
    mu, beta, x = _setup(n, seed=5)
    base = topo.ring_topology(n)
    ctl = AdaptiveController(base, tau_max=50)
    tr = _tracker(n, base, x, d_scale=10.0)
    alive = np.ones(n, dtype=bool)
    alive[[2, 5]] = False
    dec = ctl.decide(mu, beta, tr, f1=2.0, smooth_l=1.0, sigma=1.0,
                     eta=0.1, rounds=100, alive=alive)
    assert dec.adj[2].sum() == 0 and dec.adj[5].sum() == 0
    live = np.nonzero(alive)[0]
    assert topo.is_connected(dec.adj[np.ix_(live, live)])
