"""Tests for the FedHP adaptive control algorithm (Alg. 3)."""
import numpy as np
# hypothesis is optional (dev dependency): the guard skips only the
# property tests when it is absent, plain tests still run
from _hypothesis_compat import given, settings, st

from repro.core import topology as topo
from repro.core.consensus import ConsensusTracker, pairwise_distances
from repro.core.controller import (
    AdaptiveController,
    equalized_taus,
    evaluate_topology,
    prune_dead,
    theory_tau_star,
)


def _setup(n=8, seed=0, hetero=3.0):
    rng = np.random.default_rng(seed)
    mu = rng.uniform(1.0, hetero, size=n)          # per-iter compute time
    beta = rng.uniform(0.5, 5.0, size=(n, n))
    beta = (beta + beta.T) / 2
    np.fill_diagonal(beta, 0.0)
    x = rng.normal(size=(n, 32))
    return mu, beta, x


def _tracker(n, adj, x, d_scale=100.0):
    tr = ConsensusTracker(n)
    tr.update(adj, pairwise_distances(x), mean_update_norm=d_scale)
    return tr


def test_theory_tau_star_bounds_and_fallback():
    assert theory_tau_star(8, 2.0, 1.0, 100, 0.1, 1.0, tau_max=50) >= 1
    assert theory_tau_star(8, 0.0, 1.0, 100, 0.1, 1.0, tau_max=50) == 25
    assert theory_tau_star(8, 2.0, 0.0, 100, 0.1, 0.0, tau_max=50) == 25
    # monotone: more noise (sigma) -> smaller tau*
    hi = theory_tau_star(8, 2.0, 1.0, 100, 0.1, 0.5, tau_max=1000)
    lo = theory_tau_star(8, 2.0, 1.0, 100, 0.1, 2.0, tau_max=1000)
    assert hi >= lo


def test_equalized_taus_fast_worker_more_steps():
    """Eq. (40): higher-capability workers get larger tau."""
    n = 6
    mu = np.array([1.0, 1.0, 2.0, 2.0, 4.0, 8.0])
    beta = np.full((n, n), 1.0)
    np.fill_diagonal(beta, 0.0)
    adj = topo.full_topology(n)
    taus, pace = equalized_taus(adj, mu, beta, tau_star=16, tau_max=50)
    assert pace == 0 or pace == 1
    assert taus[0] >= taus[2] >= taus[4] >= taus[5] >= 1
    # equalization: all t_i <= pace time (up to tau >= 1 clamp)
    t = taus * mu + 1.0
    assert (t[:4] <= t[pace] + mu[:4]).all()


def test_evaluate_topology_waiting_time():
    mu, beta, _ = _setup()
    adj = topo.full_topology(8)
    d = evaluate_topology(adj, mu, beta, tau_star=10, tau_max=50)
    assert d.round_time > 0
    assert 0 <= d.waiting_time <= d.round_time


def test_controller_improves_round_time_vs_base():
    """Greedy link removal must never *increase* predicted round time."""
    n = 10
    mu, beta, x = _setup(n, seed=1)
    base = topo.full_topology(n)
    ctl = AdaptiveController(base, tau_max=50)
    tr = _tracker(n, base, x)
    d0 = evaluate_topology(base, mu, beta, 10, 50)
    dec = ctl.decide(mu, beta, tr, f1=2.0, smooth_l=1.0, sigma=1.0,
                     eta=0.1, rounds=100)
    assert dec.round_time <= d0.round_time + 1e-9
    assert topo.is_connected(dec.adj)
    assert tr.satisfies_budget(dec.adj)


def test_controller_respects_tight_consensus_budget():
    """With a tiny D_max no link may be removed -> base topology returned."""
    n = 6
    mu, beta, x = _setup(n, seed=2)
    base = topo.full_topology(n)
    ctl = AdaptiveController(base, tau_max=50)
    tr = _tracker(n, base, x, d_scale=1e-9)  # near-zero budget
    dec = ctl.decide(mu, beta, tr, f1=2.0, smooth_l=1.0, sigma=1.0,
                     eta=0.1, rounds=100)
    assert (dec.adj == base).all()


def test_controller_prunes_slow_links_with_loose_budget():
    n = 8
    mu, beta, x = _setup(n, seed=3)
    # one pathologically slow link
    beta[0, 1] = beta[1, 0] = 1e3
    base = topo.full_topology(n)
    ctl = AdaptiveController(base, tau_max=50)
    tr = _tracker(n, base, x, d_scale=1e9)  # effectively unconstrained
    dec = ctl.decide(mu, beta, tr, f1=2.0, smooth_l=1.0, sigma=1.0,
                     eta=0.1, rounds=100)
    assert dec.adj[0, 1] == 0, "slowest link should be pruned"
    assert topo.is_connected(dec.adj)


@given(st.integers(4, 12), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_controller_invariants(n, seed):
    mu, beta, x = _setup(n, seed)
    base = topo.full_topology(n)
    ctl = AdaptiveController(base, tau_max=30)
    tr = _tracker(n, base, x, d_scale=float(
        np.random.default_rng(seed).uniform(0.1, 1e3)))
    dec = ctl.decide(mu, beta, tr, f1=2.0, smooth_l=1.0, sigma=1.0,
                     eta=0.1, rounds=50)
    topo.validate_topology(dec.adj)
    assert topo.is_connected(dec.adj)
    assert tr.satisfies_budget(dec.adj)
    assert (dec.taus >= 1).all() and (dec.taus <= 30).all()
    # matchings cover the decided topology exactly
    cover = np.zeros_like(dec.adj)
    for m in dec.matchings:
        for (i, j) in m:
            cover[i, j] = cover[j, i] = 1
    assert (cover == dec.adj).all()


def test_prune_dead_repairs_connectivity():
    n = 6
    adj = topo.ring_topology(n)
    alive = np.array([True, False, True, True, False, True])
    pruned = prune_dead(adj, alive)
    dead = np.nonzero(~alive)[0]
    assert pruned[dead].sum() == 0 and pruned[:, dead].sum() == 0
    live = np.nonzero(alive)[0]
    assert topo.is_connected(pruned[np.ix_(live, live)])


def test_controller_with_failures():
    n = 8
    mu, beta, x = _setup(n, seed=5)
    base = topo.ring_topology(n)
    ctl = AdaptiveController(base, tau_max=50)
    tr = _tracker(n, base, x, d_scale=10.0)
    alive = np.ones(n, dtype=bool)
    alive[[2, 5]] = False
    dec = ctl.decide(mu, beta, tr, f1=2.0, smooth_l=1.0, sigma=1.0,
                     eta=0.1, rounds=100, alive=alive)
    assert dec.adj[2].sum() == 0 and dec.adj[5].sum() == 0
    live = np.nonzero(alive)[0]
    assert topo.is_connected(dec.adj[np.ix_(live, live)])
