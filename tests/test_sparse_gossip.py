"""Sparse edge-list gossip: edge-op parity, kernel parity, and the
sparse-vs-dense engine differential.

The edge-list path (``cfg.gossip="sparse"``) must be a drop-in for the
dense [W, W] mixing matrix: the host control plane (cluster RNG, plans,
clock) is shared code so host-replayed fields match bit-exactly, and the
device trajectories differ only by summation order (segment_sum / the
gather-mix-scatter kernel vs tensordot) — within 1e-5, 2e-3 compressed.
Edge mixing weights are computed from degrees with the same float ops as
the dense matrices' off-diagonals, so there is no weight drift to hide
behind.
"""
from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedHPConfig
from repro.core import topology as topo
from repro.core.experiment import run_algorithm
from repro.kernels import ref as kref
from repro.kernels.gossip_edges import gossip_edges, pad_edges
from repro.simulation.cluster import ChurnEvent, ChurnSchedule

CFG = FedHPConfig(num_workers=8, rounds=10, tau_init=5, tau_max=20,
                  lr=0.1, batch_size=32, seed=3)
SPARSE = replace(CFG, gossip="sparse")

SCHED = ChurnSchedule((
    ChurnEvent(2, "leave", 1),
    ChurnEvent(3, "crash", 6),
    ChurnEvent(4, "straggle", 2, factor=5.0, duration=3),
    ChurnEvent(6, "join", 1),
))

EXACT = ("round", "round_time", "waiting_time", "mean_tau", "num_links",
         "cumulative_time")
DEVICE_TOL = {"accuracy": 1e-6, "loss": 1e-4, "consensus": 1e-4}
COMPRESSED_TOL = {"accuracy": 1e-6, "loss": 1e-4, "consensus": 2e-3}


# ---------------------------------------------------------------------------
# edge-list ops vs their dense twins
# ---------------------------------------------------------------------------

def _random_adj(rng, n, p=0.4):
    a = (rng.random((n, n)) < p).astype(np.int8)
    a = np.maximum(a, a.T)
    np.fill_diagonal(a, 0)
    return a


def test_edges_adjacency_roundtrip():
    rng = np.random.default_rng(0)
    for _ in range(50):
        n = int(rng.integers(2, 20))
        adj = _random_adj(rng, n)
        e = topo.edges_from_adj(adj)
        np.testing.assert_array_equal(topo.adj_from_edges(e, n), adj)
        assert e.shape == (adj.sum() // 2, 2)
        assert (e[:, 0] < e[:, 1]).all()


def test_ring_edges_matches_ring_topology():
    for n in (2, 3, 5, 16):
        np.testing.assert_array_equal(
            topo.adj_from_edges(topo.ring_edges(n), n), topo.ring_topology(n))


def test_degrees_from_edges():
    rng = np.random.default_rng(1)
    for _ in range(20):
        n = int(rng.integers(2, 16))
        adj = _random_adj(rng, n)
        e = topo.edges_from_adj(adj)
        np.testing.assert_array_equal(topo.degrees_from_edges(e, n),
                                      adj.sum(axis=1))


def test_edge_weights_match_dense_offdiagonals():
    """The per-edge weights must be BIT-identical to the dense mixing
    matrices' off-diagonal entries (same float expressions), so the only
    sparse-vs-dense divergence anywhere is summation order."""
    rng = np.random.default_rng(2)
    for mixing, mixfn in (("uniform", topo.mixing_matrix_uniform),
                          ("metropolis", topo.mixing_matrix_metropolis)):
        for _ in range(20):
            n = int(rng.integers(2, 16))
            adj = _random_adj(rng, n)
            if adj.sum() == 0:
                continue
            e = topo.edges_from_adj(adj)
            w = topo.edge_mixing_weights(e, n, mixing)
            dense = mixfn(adj)
            np.testing.assert_array_equal(w, dense[e[:, 0], e[:, 1]],
                                          err_msg=mixing)


def test_mask_edges_matches_masked_adjacency():
    rng = np.random.default_rng(3)
    for _ in range(20):
        n = int(rng.integers(3, 16))
        adj = _random_adj(rng, n)
        alive = rng.random(n) > 0.3
        masked = adj.copy()
        masked[~alive, :] = 0
        masked[:, ~alive] = 0
        e = topo.edges_from_adj(adj)
        kept = topo.mask_edges(e, alive)
        np.testing.assert_array_equal(kept, topo.edges_from_adj(masked))


def test_connected_components_edges_matches_dense():
    rng = np.random.default_rng(4)
    for _ in range(50):
        n = int(rng.integers(2, 18))
        adj = _random_adj(rng, n, p=0.15)
        e = topo.edges_from_adj(adj)
        nodes = None
        if rng.random() < 0.5:
            alive = rng.random(n) > 0.3
            if not alive.any():
                alive[0] = True
            nodes = np.nonzero(alive)[0]
        want = topo.connected_components(adj, nodes)
        got = topo.connected_components_edges(e, n, nodes)
        assert len(got) == len(want)
        for ga, wa in zip(got, want):
            np.testing.assert_array_equal(np.sort(ga), np.sort(wa))
        assert topo.is_connected_edges(e, n) == topo.is_connected(adj)


def test_directed_edges_doubles_and_preserves_weights():
    adj = _random_adj(np.random.default_rng(5), 10)
    e = topo.edges_from_adj(adj)
    w = topo.edge_mixing_weights(e, 10, "metropolis")
    src, dst, ww = topo.directed_edges(e, w)
    assert src.shape == dst.shape == ww.shape == (2 * len(e),)
    # every undirected edge appears once per direction, same weight
    pairs = {(int(s), int(d)): float(x) for s, d, x in zip(src, dst, ww)}
    for (i, j), wij in zip(e, w):
        # directed_edges casts to the device dtype (f32)
        assert pairs[(i, j)] == np.float32(wij)
        assert pairs[(j, i)] == np.float32(wij)


# ---------------------------------------------------------------------------
# kernel vs jnp oracle vs dense matrix
# ---------------------------------------------------------------------------

def test_gossip_edges_ref_matches_dense_mix():
    """y = x + sum_e w_e (x_src - x_dst) over both edge orientations is
    exactly W @ x for the row-stochastic dense mixing matrix."""
    rng = np.random.default_rng(6)
    for mixing, mixfn in (("uniform", topo.mixing_matrix_uniform),
                          ("metropolis", topo.mixing_matrix_metropolis)):
        n = 12
        adj = _random_adj(rng, n)
        e = topo.edges_from_adj(adj)
        w = topo.edge_mixing_weights(e, n, mixing)
        src, dst, ww = topo.directed_edges(e, w)
        x = rng.standard_normal((n, 33)).astype(np.float32)
        y = np.asarray(kref.gossip_edges_ref(
            jnp.asarray(x), jnp.asarray(src), jnp.asarray(dst),
            jnp.asarray(ww)))
        want = mixfn(adj).astype(np.float32) @ x
        np.testing.assert_allclose(y, want, atol=1e-5, err_msg=mixing)


@pytest.mark.parametrize("shape", [(8, 256), (8, 16), (30, 700), (2, 5)])
def test_gossip_edges_kernel_matches_ref(shape):
    """Pallas gather-mix-scatter (interpret mode on CPU) vs the
    segment_sum oracle, across row/col padding regimes."""
    rng = np.random.default_rng(7)
    n, p = shape
    adj = _random_adj(rng, n, p=0.5)
    e = topo.edges_from_adj(adj)
    w = topo.edge_mixing_weights(e, n, "metropolis")
    src, dst, ww = topo.directed_edges(e, w)
    src, dst, ww = pad_edges(src, dst, ww)
    x = rng.standard_normal((n, p)).astype(np.float32)
    y = np.asarray(gossip_edges(jnp.asarray(x), jnp.asarray(src),
                                jnp.asarray(dst), jnp.asarray(ww),
                                interpret=True))
    want = np.asarray(kref.gossip_edges_ref(
        jnp.asarray(x), jnp.asarray(src), jnp.asarray(dst),
        jnp.asarray(ww)))
    np.testing.assert_allclose(y, want, atol=1e-5)


def test_gossip_edges_kernel_zero_weight_edges_are_noops():
    """All-zero weights (padding rows / no-comm rounds in the fused scan)
    must return x EXACTLY — bit-identical, not just close."""
    rng = np.random.default_rng(8)
    x = rng.standard_normal((8, 64)).astype(np.float32)
    src = jnp.zeros(8, jnp.int32)
    dst = jnp.zeros(8, jnp.int32)
    w = jnp.zeros(8, jnp.float32)
    y = np.asarray(gossip_edges(jnp.asarray(x), src, dst, w,
                                interpret=True))
    np.testing.assert_array_equal(y, x)


def test_pad_edges_pads_to_multiple_with_noop_rows():
    src, dst, w = (np.array([1, 2, 3]), np.array([0, 1, 2]),
                   np.array([0.1, 0.2, 0.3], np.float32))
    ps, pd, pw = pad_edges(src, dst, w)
    assert ps.shape == pd.shape == pw.shape == (8,)
    np.testing.assert_array_equal(pw[3:], 0.0)
    ps2, pd2, pw2 = pad_edges(src, dst, w, e_max=16)
    assert ps2.shape == (16,)


def test_gossip_edges_preserves_mean():
    """Symmetric weights (both orientations of every undirected edge)
    make the implied mixing matrix doubly stochastic: the fleet mean is
    invariant under the sparse mix."""
    rng = np.random.default_rng(9)
    n = 16
    adj = _random_adj(rng, n)
    e = topo.edges_from_adj(adj)
    w = topo.edge_mixing_weights(e, n, "uniform")
    src, dst, ww = topo.directed_edges(e, w)
    x = rng.standard_normal((n, 40)).astype(np.float32)
    y = np.asarray(kref.gossip_edges_ref(
        jnp.asarray(x), jnp.asarray(src), jnp.asarray(dst),
        jnp.asarray(ww)))
    np.testing.assert_allclose(y.mean(0), x.mean(0), atol=1e-5)


# ---------------------------------------------------------------------------
# engine differential: cfg.gossip="sparse" vs "dense"
# ---------------------------------------------------------------------------

def _assert_equivalent(h_dense, h_sparse, device_tol=DEVICE_TOL):
    assert len(h_dense.records) == len(h_sparse.records)
    a, b = h_dense.as_arrays(), h_sparse.as_arrays()
    for k in EXACT:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    for k, tol in device_tol.items():
        np.testing.assert_allclose(a[k], b[k], rtol=tol, atol=tol,
                                   err_msg=k)


def _pair(algo, churn, rounds=10, cfg=CFG, **kw):
    h_d = run_algorithm(algo, cfg, non_iid_p=0.4, rounds=rounds,
                        churn=churn, **kw)
    h_s = run_algorithm(algo, replace(cfg, gossip="sparse"), non_iid_p=0.4,
                        rounds=rounds, churn=churn, **kw)
    return h_d, h_s


def test_sparse_matches_dense_reference_smoke():
    """Fast gate: D-PSGD, 6 rounds, reference engine."""
    _assert_equivalent(*_pair("dpsgd", None, rounds=6))


def test_sparse_matches_dense_fused_smoke():
    """Fast gate: D-PSGD, 6 rounds, fused engine (sparse fused routes
    through the Pallas gather-mix-scatter kernel inside the scan)."""
    _assert_equivalent(*_pair("dpsgd", None, rounds=6, fused=True))


@pytest.mark.slow
@pytest.mark.parametrize("churn", [None, SCHED], ids=["nochurn", "churn"])
@pytest.mark.parametrize("fused", [False, True], ids=["reference", "fused"])
@pytest.mark.parametrize("algo", ["dpsgd", "ldsgd", "fedhp"])
def test_sparse_matches_dense(algo, fused, churn):
    """Strategy x engine x churn: the edge-list path is a drop-in for the
    dense mixing matrix everywhere the dense path runs. LD-SGD exercises
    the no-communication rounds (all-zero-weight edge tables must be an
    exact no-op); FedHP closes the control loop, so the exact match on
    mean_tau / num_links proves the sparse measurements feed back
    identically."""
    _assert_equivalent(*_pair(algo, churn, fused=fused))


@pytest.mark.slow
@pytest.mark.parametrize("compress", ["int8", "topk:0.25", "randk:0.25"],
                         ids=["int8", "topk", "randk"])
@pytest.mark.parametrize("fused", [False, True], ids=["reference", "fused"])
def test_sparse_matches_dense_compressed(fused, compress):
    """Compressed gossip over edges: the codecs mix through the shared
    mix_delta closure (segment_sum / kernel vs tensordot), so compressed
    trajectories stay within the compressed tolerance band."""
    cfg = replace(CFG, compress=compress)
    _assert_equivalent(*_pair("dpsgd", SCHED, cfg=cfg, fused=fused),
                       device_tol=COMPRESSED_TOL)


@pytest.mark.slow
def test_sparse_metropolis_matches_dense():
    _assert_equivalent(*_pair("dpsgd", SCHED, mixing="metropolis",
                              fused=True))


@pytest.mark.slow
def test_sparse_fused_vmapped_seeds_match_dense():
    """The edge tables broadcast across vmapped seed lanes."""
    seeds = (11, 12)
    dense = run_algorithm("dpsgd", CFG, non_iid_p=0.4, rounds=6,
                          fused=True, seeds=jnp.asarray(seeds))
    sparse = run_algorithm("dpsgd", SPARSE, non_iid_p=0.4, rounds=6,
                           fused=True, seeds=jnp.asarray(seeds))
    for hd, hs in zip(dense, sparse):
        _assert_equivalent(hd, hs)


def test_sparse_fused_matches_sparse_reference():
    """Both sparse engines against each other (kernel vs segment_sum on
    the same edge stream)."""
    h_ref = run_algorithm("dpsgd", SPARSE, non_iid_p=0.4, rounds=6)
    h_fus = run_algorithm("dpsgd", SPARSE, non_iid_p=0.4, rounds=6,
                          fused=True)
    _assert_equivalent(h_ref, h_fus)


# ---------------------------------------------------------------------------
# capped Floyd-Warshall (large-W planner path)
# ---------------------------------------------------------------------------

def test_floyd_warshall_cap_exact_below_threshold():
    from repro.core import consensus as cns
    rng = np.random.default_rng(10)
    n = 40
    adj = _random_adj(rng, n, p=0.2)
    pd = rng.random((n, n)) + 0.1
    pd = (pd + pd.T) / 2
    m = cns.measured_distance_matrix(adj, pd)
    np.testing.assert_array_equal(
        cns.floyd_warshall_estimate(m),
        cns.floyd_warshall_estimate(m, max_dense=10**9))


def test_floyd_warshall_cap_upper_bounds_exact():
    """Above the threshold the bounded-hop relaxation is the exact
    shortest path over at-most-(hops+1)-edge routes: it never undershoots
    the true shortest path, never exceeds any short route it can see
    (direct edges, 2-edge detours), and leaves unreached pairs at inf for
    the EMA fallback."""
    from repro.core import consensus as cns
    rng = np.random.default_rng(11)
    n = 60
    adj = _random_adj(rng, n, p=0.1)
    pd = rng.random((n, n)) + 0.1
    pd = (pd + pd.T) / 2
    m = cns.measured_distance_matrix(adj, pd)
    exact = cns.floyd_warshall_estimate(m, max_dense=10**9)
    capped = cns.floyd_warshall_estimate(m, max_dense=1, hops=3)
    fin = np.isfinite(capped)
    assert (capped[fin] >= exact[fin] - 1e-12).all()
    # never worse than the direct measurement on measured edges
    assert (capped[adj > 0] <= m[adj > 0] + 1e-12).all()
    # never worse than the best 2-edge route min_p (m_ip + m_pj)
    best2 = np.min(m[:, :, None] + m[None, :, :], axis=1)
    mask = np.isfinite(best2)
    np.fill_diagonal(mask, False)
    assert (capped[mask] <= best2[mask] + 1e-12).all()


def test_floyd_warshall_cap_ring_leaves_far_pairs_inf():
    from repro.core import consensus as cns
    n = 64
    adj = topo.ring_topology(n)
    m = cns.measured_distance_matrix(adj, np.ones((n, n)))
    capped = cns.floyd_warshall_estimate(m, max_dense=1, hops=3)
    # within 4 ring hops: exact integer distances; beyond: inf
    assert capped[0, 4] == 4.0
    assert not np.isfinite(capped[0, 5])


def test_tracker_large_w_uses_capped_estimate():
    """ConsensusTracker.update stays finite (EMA fallback covers the
    hop-capped infs) and cheap at W beyond the dense threshold."""
    from repro.core import consensus as cns
    n = cns.FW_DENSE_MAX + 8
    rng = np.random.default_rng(12)
    adj = topo.ring_topology(n)
    pd = np.abs(rng.standard_normal((n, n))) + 0.1
    pd = (pd + pd.T) / 2
    tr = cns.ConsensusTracker(n)
    out = tr.update(adj, pd, 1.0)
    assert np.isfinite(out).all()
    assert out.shape == (n, n)


@pytest.mark.parametrize("base", ["ba:2", "ws:4:0.2"], ids=["ba", "ws"])
def test_sparse_matches_dense_complex_topologies(base):
    """Differential matrix over the complex-network families: the
    edge-list path must be a drop-in on Barabasi-Albert and
    Watts-Strogatz graphs too. The "base" strategy gossips over the raw
    family graph every round (dpsgd would substitute a ring), so hubs
    and rewired chords actually reach the segment ops."""
    cfg = replace(CFG, base_topology=base)
    _assert_equivalent(*_pair("base", SCHED, cfg=cfg))
    _assert_equivalent(*_pair("base", SCHED, cfg=cfg, fused=True))
