"""Dynamic-membership (churn) tests: schedule generation, topology repair,
tau re-equalization over survivors, consensus-tracker membership, and a
full engine round loop under a crash schedule.

Covers the four tentpole guarantees:
  (a) the round topology stays connected after any single departure,
  (b) taus are re-equalized over the surviving set,
  (c) the consensus tracker holds no rows for departed workers,
  (d) run_dfl under a crash schedule still improves accuracy.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.configs.base import FedHPConfig
from repro.core import topology as topo
from repro.core.algorithms import STRATEGIES
from repro.core.consensus import ConsensusTracker, pairwise_distances
from repro.core.controller import AdaptiveController, equalized_taus, prune_dead
from repro.core.experiment import churn_from_config, run_algorithm
from repro.simulation.cluster import ChurnEvent, ChurnSchedule, SimCluster


def _star(n: int) -> np.ndarray:
    """Hub-and-spoke: removing the hub (0) disconnects everything."""
    a = np.zeros((n, n), np.int8)
    a[0, 1:] = 1
    a[1:, 0] = 1
    return a


# ---------------------------------------------------------------------------
# ChurnSchedule
# ---------------------------------------------------------------------------

def test_schedule_generate_deterministic():
    a = ChurnSchedule.generate(10, 50, rate=0.3, seed=4)
    b = ChurnSchedule.generate(10, 50, rate=0.3, seed=4)
    assert a.events == b.events
    c = ChurnSchedule.generate(10, 50, rate=0.3, seed=5)
    assert a.events != c.events


def test_schedule_generate_respects_min_alive():
    n = 6
    for seed in range(20):           # incl. rejoin interleavings (default p)
        sched = ChurnSchedule.generate(n, 60, rate=1.0, seed=seed,
                                       min_alive=3)
        cl = SimCluster(n, model_bits=1e3, churn=sched)
        for h in range(60):
            assert cl.advance_round(h).sum() >= 3, (seed, h)


def test_cluster_applies_events():
    n = 5
    sched = ChurnSchedule((
        ChurnEvent(2, "leave", 1),
        ChurnEvent(3, "crash", 2),
        ChurnEvent(5, "join", 1),
        ChurnEvent(4, "straggle", 0, factor=8.0, duration=3),
    ))
    cl = SimCluster(n, model_bits=1e3, seed=0, churn=sched)
    assert cl.advance_round(0).all()
    assert not cl.advance_round(2)[1]
    alive = cl.advance_round(3)
    assert not alive[2] and cl.last_crashed[2]
    mu_before = cl.mu_mean[0]
    cl.advance_round(4)
    assert cl.sample_mu()[0] > 4 * mu_before       # 8x spike, small noise
    alive = cl.advance_round(5)
    assert alive[1] and cl.last_joined[1]
    cl.advance_round(8)                            # spike expired
    assert cl._straggle_factor[0] == 1.0


# ---------------------------------------------------------------------------
# (a) topology repair
# ---------------------------------------------------------------------------

def test_repair_connectivity_any_single_departure():
    for base in (_star(7), topo.ring_topology(8),
                 topo.make_base_topology(9, "erdos:0.3", seed=1)):
        n = base.shape[0]
        for dead in range(n):
            alive = np.ones(n, bool)
            alive[dead] = False
            rep = topo.repair_connectivity(base, alive)
            live = np.nonzero(alive)[0]
            assert rep[dead].sum() == 0 and rep[:, dead].sum() == 0
            assert topo.is_connected(rep[np.ix_(live, live)])


def test_repair_prefers_cheap_links():
    # two components {0,1} and {2,3}; the 1-3 link is far cheaper
    adj = np.zeros((4, 4), np.int8)
    adj[0, 1] = adj[1, 0] = 1
    adj[2, 3] = adj[3, 2] = 1
    cost = np.full((4, 4), 100.0)
    cost[1, 3] = cost[3, 1] = 1.0
    rep = topo.repair_connectivity(adj, np.ones(4, bool), cost)
    assert rep[1, 3] == 1 and rep[3, 1] == 1
    assert topo.is_connected(rep)


def test_repair_triggers_when_survivors_lose_every_link():
    """Regression: a departure that kills EVERY edge of the round topology
    must still trigger repair_connectivity. The old engine guard
    (``adj[alive][:, alive].sum() > 0``) skipped repair exactly in that
    case, silently disabling gossip for the round."""
    from repro.core.algorithms import Strategy, RoundPlan
    from repro.core.experiment import setup_experiment
    from repro.core import engine

    n = 5

    class StarOblivious(Strategy):
        """Plans the hub-and-spoke topology but ignores churn entirely —
        the engine's safety net is the only thing standing between a hub
        crash and an edgeless round."""

        def plan(self, h, alive=None):
            self._membership(alive)
            taus = np.full(self.n, self.cfg.tau_init, np.int64)
            taus[~self.alive] = 0
            return RoundPlan(self.base_adj.copy(), taus)

    cfg = FedHPConfig(num_workers=n, rounds=6, tau_init=3, tau_max=10,
                      lr=0.1, batch_size=16, seed=2)
    sched = ChurnSchedule((ChurnEvent(2, "crash", 0),))  # kill the hub
    train, tx, ty, shards, cluster = setup_experiment(
        cfg, non_iid_p=0.2, churn=sched, rounds=6)
    strat = StarOblivious(cfg, _star(n))
    h = engine.run_dfl(train, tx, ty, shards, cluster, cfg, strat, rounds=6)
    # from the crash round on, the spokes must have been reconnected:
    # a spanning structure over the 4 survivors needs >= 3 links
    for r in h.records[2:]:
        assert r.num_links >= n - 2, (r.round, r.num_links)
    assert np.isfinite([r.loss for r in h.records]).all()


def test_strategies_return_connected_topology_under_departure():
    n = 8
    cfg = FedHPConfig(num_workers=n, tau_init=4, tau_max=20)
    alive = np.ones(n, bool)
    alive[[0, 5]] = False
    live = np.nonzero(alive)[0]
    for name, cls in STRATEGIES.items():
        strat = cls(cfg, topo.full_topology(n))
        plan = strat.plan(0, alive=alive)
        assert plan.adj[~alive].sum() == 0, name
        if name == "ldsgd":                      # round 0 is local-only
            plan = strat.plan(cfg.ldsgd_i1, alive=alive)
        sub = plan.adj[np.ix_(live, live)]
        assert topo.is_connected(sub), name
        assert (plan.taus[~alive] == 0).all(), name


# ---------------------------------------------------------------------------
# (b) tau re-equalization over survivors
# ---------------------------------------------------------------------------

def test_taus_reequalized_over_survivors():
    n = 8
    rng = np.random.default_rng(2)
    mu = rng.uniform(0.05, 0.5, n)
    beta = rng.uniform(0.5, 3.0, (n, n))
    np.fill_diagonal(beta, 0.0)
    alive = np.ones(n, bool)
    alive[[1, 4]] = False
    adj = prune_dead(topo.full_topology(n), alive, cost=beta)
    taus, pace = equalized_taus(adj, mu, beta, tau_star=16, tau_max=50,
                                alive=alive)
    assert (taus[~alive] == 0).all()
    assert alive[pace]
    # survivors' predicted finish times cluster at the pace-setter's
    comm = np.where(adj > 0, beta, 0.0).max(1)
    t = taus * mu + comm
    t_pace = t[pace]
    for i in np.nonzero(alive)[0]:
        if 1 < taus[i] < 50:                     # not floor/cap-clamped
            assert t[i] <= t_pace + 1e-9
            assert t[i] + mu[i] > t_pace - 1e-9  # within one local step


def test_controller_decides_over_survivors_only():
    n = 10
    rng = np.random.default_rng(3)
    mu = rng.uniform(0.05, 0.5, n)
    beta = rng.uniform(0.5, 3.0, (n, n))
    beta = (beta + beta.T) / 2
    np.fill_diagonal(beta, 0.0)
    ctl = AdaptiveController(topo.full_topology(n), tau_max=30)
    tr = ConsensusTracker(n)
    x = rng.normal(size=(n, 16))
    tr.update(topo.full_topology(n), pairwise_distances(x), 5.0)
    alive = np.ones(n, bool)
    alive[[0, 7, 9]] = False
    tr.sync_membership(alive)
    dec = ctl.decide(mu, beta, tr, f1=2.0, smooth_l=1.0, sigma=1.0,
                     eta=0.1, rounds=100, alive=alive)
    assert (dec.taus[~alive] == 0).all()
    assert (dec.taus[alive] >= 1).all()
    assert alive[dec.pace_worker]
    live = np.nonzero(alive)[0]
    assert topo.is_connected(dec.adj[np.ix_(live, live)])
    # round time is attained by a survivor, not a ghost
    t = dec.taus * mu + np.where(dec.adj > 0, beta, 0.0).max(1)
    assert np.isclose(dec.round_time, t[alive].max())


# ---------------------------------------------------------------------------
# (c) consensus tracker membership
# ---------------------------------------------------------------------------

def test_tracker_drops_rows_for_departed():
    n = 6
    tr = ConsensusTracker(n)
    x = np.random.default_rng(0).normal(size=(n, 8))
    tr.update(topo.full_topology(n), pairwise_distances(x), 1.0)
    assert (tr.dist[np.triu_indices(n, 1)] > 0).all()
    alive = np.ones(n, bool)
    alive[[2, 4]] = False
    tr.sync_membership(alive)
    assert tr.dist[2].sum() == 0 and tr.dist[:, 2].sum() == 0
    assert tr.dist[4].sum() == 0 and tr.dist[:, 4].sum() == 0
    assert not tr.present[2] and not tr.present[4]
    # Eq. 36 normalizes over survivors and never charges departed pairs
    empty = np.zeros((n, n), np.int8)
    bound = tr.average_consensus_bound(empty)
    live = np.nonzero(alive)[0]
    sub = tr.dist[np.ix_(live, live)]
    assert np.isclose(bound, sub.sum() / len(live) ** 2)


def test_tracker_reinit_on_rejoin():
    n = 5
    tr = ConsensusTracker(n)
    x = np.random.default_rng(1).normal(size=(n, 8))
    tr.update(topo.full_topology(n), pairwise_distances(x), 1.0)
    alive = np.ones(n, bool)
    alive[3] = False
    tr.sync_membership(alive)
    alive[3] = True
    tr.sync_membership(alive)
    assert tr.present[3]
    # fresh row gets the pessimistic mean prior, not stale zeros
    others = [i for i in range(n) if i != 3]
    assert (tr.dist[3, others] > 0).all()
    assert tr.dist[3, 3] == 0.0


# ---------------------------------------------------------------------------
# (d) engine round loop under a crash schedule
# ---------------------------------------------------------------------------

CFG = FedHPConfig(num_workers=8, rounds=14, tau_init=5, tau_max=20,
                  lr=0.1, batch_size=32, seed=3)


def test_run_dfl_improves_under_crash_schedule():
    sched = ChurnSchedule((
        ChurnEvent(3, "crash", 2),
        ChurnEvent(6, "crash", 5),
        ChurnEvent(8, "straggle", 1, factor=5.0, duration=4),
    ))
    h = run_algorithm("fedhp", CFG, non_iid_p=0.3, rounds=14, churn=sched)
    assert len(h.records) == 14
    assert np.isfinite([r.loss for r in h.records]).all()
    assert h.final_accuracy > 0.8
    assert h.final_accuracy > h.records[0].accuracy
    # crash rounds charge the detection timeout on top of compute+comm
    r3 = h.records[3]
    assert r3.round_time >= CFG.crash_timeout


def test_run_dfl_generated_churn_all_strategies():
    cfg = FedHPConfig(num_workers=8, rounds=12, tau_init=5, tau_max=20,
                      lr=0.1, batch_size=32, seed=3, churn_rate=0.3)
    sched = churn_from_config(cfg, 12)
    assert sched is not None and len(sched.events) > 0
    for algo in ("fedhp", "dpsgd", "ldsgd", "pens"):
        h = run_algorithm(algo, cfg, non_iid_p=0.3, rounds=12, churn=sched)
        assert h.final_accuracy > 0.7, algo
        assert np.isfinite([r.loss for r in h.records]).all(), algo


def test_run_adpsgd_survives_churn():
    sched = ChurnSchedule((
        ChurnEvent(2, "leave", 0),
        ChurnEvent(4, "crash", 3),
        ChurnEvent(7, "join", 0),
    ))
    h = run_algorithm("adpsgd", CFG, non_iid_p=0.3, rounds=12, churn=sched)
    assert len(h.records) > 0
    assert h.final_accuracy > 0.7
    assert np.isfinite([r.loss for r in h.records]).all()


def test_join_reinits_from_population():
    """A worker that rejoins adopts the incumbents' average model, so the
    fleet's consensus distance does not blow up at the join round."""
    sched = ChurnSchedule((
        ChurnEvent(2, "leave", 1),
        ChurnEvent(8, "join", 1),
    ))
    h = run_algorithm("fedhp", CFG, non_iid_p=0.3, rounds=12, churn=sched)
    cons = [r.consensus for r in h.records]
    assert np.isfinite(cons).all()
    # join round's consensus stays within the run's historical envelope
    assert cons[8] <= 3.0 * max(cons[:8]) + 1e-6
