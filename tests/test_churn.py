"""Dynamic-membership (churn) tests: schedule generation, topology repair,
tau re-equalization over survivors, consensus-tracker membership, and a
full engine round loop under a crash schedule.

Covers the four tentpole guarantees:
  (a) the round topology stays connected after any single departure,
  (b) taus are re-equalized over the surviving set,
  (c) the consensus tracker holds no rows for departed workers,
  (d) run_dfl under a crash schedule still improves accuracy.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.configs.base import FedHPConfig
from repro.core import topology as topo
from repro.core.algorithms import STRATEGIES
from repro.core.consensus import ConsensusTracker, pairwise_distances
from repro.core.controller import AdaptiveController, equalized_taus, prune_dead
from repro.core.experiment import churn_from_config, run_algorithm
from repro.simulation.cluster import ChurnEvent, ChurnSchedule, SimCluster


def _star(n: int) -> np.ndarray:
    """Hub-and-spoke: removing the hub (0) disconnects everything."""
    a = np.zeros((n, n), np.int8)
    a[0, 1:] = 1
    a[1:, 0] = 1
    return a


# ---------------------------------------------------------------------------
# ChurnSchedule
# ---------------------------------------------------------------------------

def test_schedule_generate_deterministic():
    a = ChurnSchedule.generate(10, 50, rate=0.3, seed=4)
    b = ChurnSchedule.generate(10, 50, rate=0.3, seed=4)
    assert a.events == b.events
    c = ChurnSchedule.generate(10, 50, rate=0.3, seed=5)
    assert a.events != c.events


def test_schedule_generate_respects_min_alive():
    n = 6
    for seed in range(20):           # incl. rejoin interleavings (default p)
        sched = ChurnSchedule.generate(n, 60, rate=1.0, seed=seed,
                                       min_alive=3)
        cl = SimCluster(n, model_bits=1e3, churn=sched)
        for h in range(60):
            assert cl.advance_round(h).sum() >= 3, (seed, h)


def test_cluster_applies_events():
    n = 5
    sched = ChurnSchedule((
        ChurnEvent(2, "leave", 1),
        ChurnEvent(3, "crash", 2),
        ChurnEvent(5, "join", 1),
        ChurnEvent(4, "straggle", 0, factor=8.0, duration=3),
    ))
    cl = SimCluster(n, model_bits=1e3, seed=0, churn=sched)
    assert cl.advance_round(0).all()
    assert not cl.advance_round(2)[1]
    alive = cl.advance_round(3)
    assert not alive[2] and cl.last_crashed[2]
    mu_before = cl.mu_mean[0]
    cl.advance_round(4)
    assert cl.sample_mu()[0] > 4 * mu_before       # 8x spike, small noise
    alive = cl.advance_round(5)
    assert alive[1] and cl.last_joined[1]
    cl.advance_round(8)                            # spike expired
    assert cl._straggle_factor[0] == 1.0


# ---------------------------------------------------------------------------
# (a) topology repair
# ---------------------------------------------------------------------------

def test_repair_connectivity_any_single_departure():
    for base in (_star(7), topo.ring_topology(8),
                 topo.make_base_topology(9, "erdos:0.3", seed=1)):
        n = base.shape[0]
        for dead in range(n):
            alive = np.ones(n, bool)
            alive[dead] = False
            rep = topo.repair_connectivity(base, alive)
            live = np.nonzero(alive)[0]
            assert rep[dead].sum() == 0 and rep[:, dead].sum() == 0
            assert topo.is_connected(rep[np.ix_(live, live)])


def test_repair_prefers_cheap_links():
    # two components {0,1} and {2,3}; the 1-3 link is far cheaper
    adj = np.zeros((4, 4), np.int8)
    adj[0, 1] = adj[1, 0] = 1
    adj[2, 3] = adj[3, 2] = 1
    cost = np.full((4, 4), 100.0)
    cost[1, 3] = cost[3, 1] = 1.0
    rep = topo.repair_connectivity(adj, np.ones(4, bool), cost)
    assert rep[1, 3] == 1 and rep[3, 1] == 1
    assert topo.is_connected(rep)


def test_repair_triggers_when_survivors_lose_every_link():
    """Regression: a departure that kills EVERY edge of the round topology
    must still trigger repair_connectivity. The old engine guard
    (``adj[alive][:, alive].sum() > 0``) skipped repair exactly in that
    case, silently disabling gossip for the round."""
    from repro.core.algorithms import Strategy, RoundPlan
    from repro.core.experiment import setup_experiment
    from repro.core import engine

    n = 5

    class StarOblivious(Strategy):
        """Plans the hub-and-spoke topology but ignores churn entirely —
        the engine's safety net is the only thing standing between a hub
        crash and an edgeless round."""

        def plan(self, h, alive=None):
            self._membership(alive)
            taus = np.full(self.n, self.cfg.tau_init, np.int64)
            taus[~self.alive] = 0
            return RoundPlan(self.base_adj.copy(), taus)

    cfg = FedHPConfig(num_workers=n, rounds=6, tau_init=3, tau_max=10,
                      lr=0.1, batch_size=16, seed=2)
    sched = ChurnSchedule((ChurnEvent(2, "crash", 0),))  # kill the hub
    train, tx, ty, shards, cluster = setup_experiment(
        cfg, non_iid_p=0.2, churn=sched, rounds=6)
    strat = StarOblivious(cfg, _star(n))
    h = engine.run_dfl(train, tx, ty, shards, cluster, cfg, strat, rounds=6)
    # from the crash round on, the spokes must have been reconnected:
    # a spanning structure over the 4 survivors needs >= 3 links
    for r in h.records[2:]:
        assert r.num_links >= n - 2, (r.round, r.num_links)
    assert np.isfinite([r.loss for r in h.records]).all()


def test_strategies_return_connected_topology_under_departure():
    n = 8
    cfg = FedHPConfig(num_workers=n, tau_init=4, tau_max=20)
    alive = np.ones(n, bool)
    alive[[0, 5]] = False
    live = np.nonzero(alive)[0]
    for name, cls in STRATEGIES.items():
        strat = cls(cfg, topo.full_topology(n))
        plan = strat.plan(0, alive=alive)
        assert plan.adj[~alive].sum() == 0, name
        if name == "ldsgd":                      # round 0 is local-only
            plan = strat.plan(cfg.ldsgd_i1, alive=alive)
        sub = plan.adj[np.ix_(live, live)]
        assert topo.is_connected(sub), name
        assert (plan.taus[~alive] == 0).all(), name


# ---------------------------------------------------------------------------
# (b) tau re-equalization over survivors
# ---------------------------------------------------------------------------

def test_taus_reequalized_over_survivors():
    n = 8
    rng = np.random.default_rng(2)
    mu = rng.uniform(0.05, 0.5, n)
    beta = rng.uniform(0.5, 3.0, (n, n))
    np.fill_diagonal(beta, 0.0)
    alive = np.ones(n, bool)
    alive[[1, 4]] = False
    adj = prune_dead(topo.full_topology(n), alive, cost=beta)
    taus, pace = equalized_taus(adj, mu, beta, tau_star=16, tau_max=50,
                                alive=alive)
    assert (taus[~alive] == 0).all()
    assert alive[pace]
    # survivors' predicted finish times cluster at the pace-setter's
    comm = np.where(adj > 0, beta, 0.0).max(1)
    t = taus * mu + comm
    t_pace = t[pace]
    for i in np.nonzero(alive)[0]:
        if 1 < taus[i] < 50:                     # not floor/cap-clamped
            assert t[i] <= t_pace + 1e-9
            assert t[i] + mu[i] > t_pace - 1e-9  # within one local step


def test_controller_decides_over_survivors_only():
    n = 10
    rng = np.random.default_rng(3)
    mu = rng.uniform(0.05, 0.5, n)
    beta = rng.uniform(0.5, 3.0, (n, n))
    beta = (beta + beta.T) / 2
    np.fill_diagonal(beta, 0.0)
    ctl = AdaptiveController(topo.full_topology(n), tau_max=30)
    tr = ConsensusTracker(n)
    x = rng.normal(size=(n, 16))
    tr.update(topo.full_topology(n), pairwise_distances(x), 5.0)
    alive = np.ones(n, bool)
    alive[[0, 7, 9]] = False
    tr.sync_membership(alive)
    dec = ctl.decide(mu, beta, tr, f1=2.0, smooth_l=1.0, sigma=1.0,
                     eta=0.1, rounds=100, alive=alive)
    assert (dec.taus[~alive] == 0).all()
    assert (dec.taus[alive] >= 1).all()
    assert alive[dec.pace_worker]
    live = np.nonzero(alive)[0]
    assert topo.is_connected(dec.adj[np.ix_(live, live)])
    # round time is attained by a survivor, not a ghost
    t = dec.taus * mu + np.where(dec.adj > 0, beta, 0.0).max(1)
    assert np.isclose(dec.round_time, t[alive].max())


# ---------------------------------------------------------------------------
# (c) consensus tracker membership
# ---------------------------------------------------------------------------

def test_tracker_drops_rows_for_departed():
    n = 6
    tr = ConsensusTracker(n)
    x = np.random.default_rng(0).normal(size=(n, 8))
    tr.update(topo.full_topology(n), pairwise_distances(x), 1.0)
    assert (tr.dist[np.triu_indices(n, 1)] > 0).all()
    alive = np.ones(n, bool)
    alive[[2, 4]] = False
    tr.sync_membership(alive)
    assert tr.dist[2].sum() == 0 and tr.dist[:, 2].sum() == 0
    assert tr.dist[4].sum() == 0 and tr.dist[:, 4].sum() == 0
    assert not tr.present[2] and not tr.present[4]
    # Eq. 36 normalizes over survivors and never charges departed pairs
    empty = np.zeros((n, n), np.int8)
    bound = tr.average_consensus_bound(empty)
    live = np.nonzero(alive)[0]
    sub = tr.dist[np.ix_(live, live)]
    assert np.isclose(bound, sub.sum() / len(live) ** 2)


def test_tracker_reinit_on_rejoin():
    n = 5
    tr = ConsensusTracker(n)
    x = np.random.default_rng(1).normal(size=(n, 8))
    tr.update(topo.full_topology(n), pairwise_distances(x), 1.0)
    alive = np.ones(n, bool)
    alive[3] = False
    tr.sync_membership(alive)
    alive[3] = True
    tr.sync_membership(alive)
    assert tr.present[3]
    # fresh row gets the pessimistic mean prior, not stale zeros
    others = [i for i in range(n) if i != 3]
    assert (tr.dist[3, others] > 0).all()
    assert tr.dist[3, 3] == 0.0


# ---------------------------------------------------------------------------
# (d) engine round loop under a crash schedule
# ---------------------------------------------------------------------------

CFG = FedHPConfig(num_workers=8, rounds=14, tau_init=5, tau_max=20,
                  lr=0.1, batch_size=32, seed=3)


def test_run_dfl_improves_under_crash_schedule():
    sched = ChurnSchedule((
        ChurnEvent(3, "crash", 2),
        ChurnEvent(6, "crash", 5),
        ChurnEvent(8, "straggle", 1, factor=5.0, duration=4),
    ))
    h = run_algorithm("fedhp", CFG, non_iid_p=0.3, rounds=14, churn=sched)
    assert len(h.records) == 14
    assert np.isfinite([r.loss for r in h.records]).all()
    assert h.final_accuracy > 0.8
    assert h.final_accuracy > h.records[0].accuracy
    # crash rounds charge the detection timeout on top of compute+comm
    r3 = h.records[3]
    assert r3.round_time >= CFG.crash_timeout


def test_run_dfl_generated_churn_all_strategies():
    cfg = FedHPConfig(num_workers=8, rounds=12, tau_init=5, tau_max=20,
                      lr=0.1, batch_size=32, seed=3, churn_rate=0.3)
    sched = churn_from_config(cfg, 12)
    assert sched is not None and len(sched.events) > 0
    for algo in ("fedhp", "dpsgd", "ldsgd", "pens"):
        h = run_algorithm(algo, cfg, non_iid_p=0.3, rounds=12, churn=sched)
        assert h.final_accuracy > 0.7, algo
        assert np.isfinite([r.loss for r in h.records]).all(), algo


def test_run_adpsgd_survives_churn():
    sched = ChurnSchedule((
        ChurnEvent(2, "leave", 0),
        ChurnEvent(4, "crash", 3),
        ChurnEvent(7, "join", 0),
    ))
    h = run_algorithm("adpsgd", CFG, non_iid_p=0.3, rounds=12, churn=sched)
    assert len(h.records) > 0
    assert h.final_accuracy > 0.7
    assert np.isfinite([r.loss for r in h.records]).all()


def test_join_reinits_from_population():
    """A worker that rejoins adopts the incumbents' average model, so the
    fleet's consensus distance does not blow up at the join round."""
    sched = ChurnSchedule((
        ChurnEvent(2, "leave", 1),
        ChurnEvent(8, "join", 1),
    ))
    h = run_algorithm("fedhp", CFG, non_iid_p=0.3, rounds=12, churn=sched)
    cons = [r.consensus for r in h.records]
    assert np.isfinite(cons).all()
    # join round's consensus stays within the run's historical envelope
    assert cons[8] <= 3.0 * max(cons[:8]) + 1e-6


# ---------------------------------------------------------------------------
# generator invariants (regression: kinds-subset rate under-delivery)
# ---------------------------------------------------------------------------

from _hypothesis_compat import given, settings, st  # noqa: E402
from repro.simulation.cluster import CHURN_KINDS, _alive_replay  # noqa: E402

KIND_SUBSETS = (("crash",), ("leave",), ("leave", "crash"),
                ("crash", "join"), ("leave", "join", "straggle"),
                CHURN_KINDS)


def test_generate_delivers_rate_for_every_kinds_subset():
    """Regression: the old fixed leave/crash coin ``continue``d on the
    disallowed kind, silently halving the delivered departure rate for
    single-kind subsets. With min_alive=1 (clamp never binds at these
    rates) every subset must deliver exactly round(rate*N) departures,
    all drawn from the allowed kinds."""
    n, rounds = 12, 80
    for kinds in KIND_SUBSETS:
        allowed_dep = {k for k in ("leave", "crash") if k in kinds}
        for rate in (0.25, 0.5, 0.75):
            for seed in range(5):
                sched = ChurnSchedule.generate(
                    n, rounds, rate=rate, seed=seed, kinds=kinds,
                    min_alive=1)
                deps = [e for e in sched.events
                        if e.kind in ("leave", "crash")]
                assert {e.kind for e in sched.events} <= set(kinds), kinds
                if allowed_dep:
                    assert len(deps) == round(rate * n), (kinds, rate, seed)
                else:
                    assert not deps, (kinds, rate, seed)


def test_generate_min_alive_sweep():
    """min_alive is never violated at ANY round, for every kinds subset
    and aggressive rates (rate=1.0 forces the clamp to bind)."""
    n, rounds = 8, 60
    for kinds in KIND_SUBSETS:
        for min_alive in (1, 3, 5):
            for seed in range(8):
                sched = ChurnSchedule.generate(
                    n, rounds, rate=1.0, seed=seed, kinds=kinds,
                    min_alive=min_alive)
                cl = SimCluster(n, model_bits=1e3, churn=sched)
                for h in range(rounds):
                    alive = cl.advance_round(h)
                    assert alive.sum() >= min_alive, \
                        (kinds, min_alive, seed, h)


def test_generate_stragglers_hit_survivors():
    """Regression: straggler spikes drew from range(N) ignoring
    departures, so spikes could land on dead workers (silent no-ops that
    under-deliver the scenario). Every spike's target must be alive at
    the spike round under full-schedule replay."""
    n, rounds = 10, 60
    for seed in range(20):
        sched = ChurnSchedule.generate(n, rounds, rate=0.6, seed=seed,
                                       rejoin_p=0.3)
        alive_at = _alive_replay(list(sched.events), n)
        spikes = [e for e in sched.events if e.kind == "straggle"]
        assert spikes, seed                      # rate 0.6 -> 6 spikes drawn
        for e in spikes:
            assert alive_at(e.round)[e.worker], (seed, e)


def test_generate_correlated_grouped_rack_outages():
    """Correlated schedules: every outage is a grouped event whose
    members share one rack_assignment block, min_alive holds at every
    round, and grouped rejoins restore the same group."""
    from repro.core.topology import rack_assignment
    n, rounds, racks = 12, 50, 4
    assign = rack_assignment(n, racks)
    saw_outage = False
    for seed in range(15):
        sched = ChurnSchedule.generate_correlated(
            n, rounds, racks=racks, outages=3, seed=seed, min_alive=3)
        cl = SimCluster(n, model_bits=1e3, churn=sched)
        for h in range(rounds):
            assert cl.advance_round(h).sum() >= 3, (seed, h)
        for e in sched.events:
            assert e.group, e                    # every event is grouped
            if e.kind == "crash":
                saw_outage = True
                assert len({int(assign[w]) for w in e.workers}) == 1, e
    assert saw_outage


def test_generate_correlated_rejects_bad_kind():
    with pytest.raises(ValueError):
        ChurnSchedule.generate_correlated(8, 20, racks=2, outages=1,
                                          kind="straggle")


def test_cluster_applies_grouped_events():
    """SimCluster.advance_round applies a grouped crash/join to every
    member atomically."""
    n = 8
    sched = ChurnSchedule((
        ChurnEvent(2, "crash", 1, group=(1, 2, 3)),
        ChurnEvent(5, "join", 1, group=(1, 2, 3)),
    ))
    cl = SimCluster(n, model_bits=1e3, churn=sched)
    assert cl.advance_round(1).all()
    alive = cl.advance_round(2)
    assert not alive[[1, 2, 3]].any() and alive[[0, 4, 5, 6, 7]].all()
    assert cl.last_crashed[[1, 2, 3]].all()
    alive = cl.advance_round(5)
    assert alive.all() and cl.last_joined[[1, 2, 3]].all()


def test_cluster_rejects_out_of_range_group_member():
    sched = ChurnSchedule((ChurnEvent(1, "crash", 0, group=(0, 9)),))
    with pytest.raises(ValueError, match="targets worker 9"):
        SimCluster(4, model_bits=1e3, churn=sched)


@given(st.integers(min_value=4, max_value=16), st.integers(0, 2**31 - 1),
       st.sampled_from(KIND_SUBSETS),
       st.floats(min_value=0.0, max_value=1.0))
@settings(max_examples=40, deadline=None)
def test_generate_property_invariants(n, seed, kinds, rate):
    """Property sweep: delivered departures == round(rate*n) whenever the
    clamp cannot bind (min_alive=1, departures < n), every event kind is
    from the allowed subset, and replayed membership respects min_alive."""
    sched = ChurnSchedule.generate(n, 50, rate=rate, seed=seed,
                                   kinds=kinds, min_alive=1)
    assert {e.kind for e in sched.events} <= set(kinds)
    deps = [e for e in sched.events if e.kind in ("leave", "crash")]
    allowed_dep = {k for k in ("leave", "crash") if k in kinds}
    want = round(rate * n) if allowed_dep else 0
    if want < n:                       # clamp can only bind at want == n
        assert len(deps) == want
    alive_at = _alive_replay(list(sched.events), n)
    assert all(alive_at(r).sum() >= 1 for r in range(50))
