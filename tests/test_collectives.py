"""Multi-device collectives tests, promoted from the hand-run
``tests/_runtime_checks.py`` script into parametrized cases.

These REQUIRE >= 8 local devices. The repo conftest never forces the
device count (spec: smoke tests and benches must see one device), so
under a plain ``pytest`` run every test here skips; they execute

- via the subprocess launcher in ``tests/test_runtime.py`` (tier 1), or
- directly in the CI multi-device lane, which exports
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Covered: ``gossip_fn`` (matching-decomposed ppermute gossip == dense
W @ X), ``gossip_compressed_fn`` (int8 / top-k / rand-k codec parity
with core/compression), ``gossip_edges_sharded_fn`` and
``gossip_edges_compressed_sharded_fn`` (offset-routed edge-list gossip
vs the segment_sum / compressed_gossip_ref oracles, plus a hypothesis
property over random topologies and shard counts), and
``ring_allreduce_mean_fn``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from _hypothesis_compat import given, settings, st
from repro.core import compression
from repro.core import topology as topo
from repro.kernels import ref as kernel_ref
from repro.runtime import collectives

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8; see tests/test_runtime.py launcher)")

W = 4          # pod x data workers on the 3-axis mesh
W8 = 8         # workers on the flat edge-list paths


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))


@pytest.fixture(scope="module")
def dense_setup(mesh):
    adj = topo.full_topology(W)
    mix = topo.mixing_matrix_uniform(adj)
    pairs = collectives.matchings_as_pairs(adj)
    wt = collectives.matching_weight_tables(adj, mix)
    spec = P(("pod", "data"), None, "model")
    x = jax.random.normal(jax.random.PRNGKey(0), (W, 6, 32))
    want = jnp.tensordot(jnp.asarray(mix, jnp.float32), x, axes=1)
    return dict(adj=adj, mix=mix, pairs=pairs, wt=wt, spec=spec, x=x,
                want=want)


def test_gossip_matches_dense_mix(mesh, dense_setup):
    s = dense_setup
    gossip = collectives.gossip_fn(mesh, ("pod", "data"), s["pairs"],
                                   s["wt"], s["spec"])
    with mesh:
        y = jax.jit(gossip,
                    in_shardings=(NamedSharding(mesh, s["spec"]),),
                    out_shardings=NamedSharding(mesh, s["spec"]))(s["x"])
    np.testing.assert_allclose(np.asarray(y), np.asarray(s["want"]),
                               atol=1e-5)
    # Eq. 5 with a doubly stochastic mix preserves the fleet mean
    np.testing.assert_allclose(np.asarray(y).mean(0),
                               np.asarray(s["x"]).mean(0), atol=1e-5)


def test_gossip_measures_distances(mesh, dense_setup):
    s = dense_setup
    gossip_d = collectives.gossip_fn(mesh, ("pod", "data"), s["pairs"],
                                     s["wt"], s["spec"],
                                     measure_distances=True)
    with mesh:
        y2, dists = jax.jit(gossip_d)(s["x"])
    np.testing.assert_allclose(np.asarray(y2), np.asarray(s["want"]),
                               atol=1e-5)
    # distance of matching 0 equals ||x_i - x_partner|| (Alg. 1 line 9)
    i, j = s["pairs"][0][0]
    d0 = np.linalg.norm(np.asarray(s["x"])[i] - np.asarray(s["x"])[j])
    np.testing.assert_allclose(float(np.asarray(dists)[0]), d0, rtol=1e-4)


def test_compressed_gossip_int8(mesh, dense_setup):
    s = dense_setup
    gossip_c = collectives.gossip_compressed_fn(mesh, ("pod", "data"),
                                                s["pairs"], s["wt"],
                                                s["spec"])
    err0 = jnp.zeros_like(s["x"])
    with mesh:
        yc, err = jax.jit(gossip_c)(s["x"], err0, jnp.int32(0))
    rel = (np.linalg.norm(np.asarray(yc) - np.asarray(s["want"]))
           / np.linalg.norm(np.asarray(s["want"])))
    assert rel < 0.02, f"int8 gossip rel err {rel:.4f}"
    assert float(jnp.abs(err).max()) > 0, "error feedback should be nonzero"
    # residual parity with the canonical compensated update e' = z - Q(z),
    # per device shard ([1, 6, 16] blocks of the model axis) through the
    # shared core/compression wire format
    z_np = np.asarray(s["x"], np.float32)             # err0 == 0 -> z == x
    want_err = np.zeros_like(z_np)
    for ww in range(W):
        for m in range(2):
            blk = z_np[ww, :, 16 * m:16 * (m + 1)].reshape(-1)
            q2, s2 = compression.quantize_flat(jnp.asarray(blk))
            deq = np.asarray(compression.dequantize_flat(q2, s2, blk.size))
            want_err[ww, :, 16 * m:16 * (m + 1)] = \
                (blk - deq).reshape(6, 16)
    np.testing.assert_allclose(np.asarray(err), want_err, atol=1e-7,
                               rtol=1e-5)


def test_compressed_gossip_randk(mesh, dense_setup):
    s = dense_setup
    gossip_rk = collectives.gossip_compressed_fn(
        mesh, ("pod", "data"), s["pairs"], s["wt"], s["spec"],
        mode="randk:0.25", seed=7)
    err0 = jnp.zeros_like(s["x"])
    with mesh:
        yr, err_r = jax.jit(gossip_rk)(s["x"], err0, jnp.int32(0))
        yr2, _ = jax.jit(gossip_rk)(s["x"], err0, jnp.int32(1))
    # the doubly stochastic compensated update preserves the fleet mean
    np.testing.assert_allclose(np.asarray(yr).mean(0),
                               np.asarray(s["x"]).mean(0), atol=1e-5)
    assert float(jnp.abs(err_r).max()) == 0.0, "rand-k carries no state"
    assert not np.allclose(np.asarray(yr), np.asarray(yr2)), \
        "rand-k mask must advance with step"


def test_compressed_gossip_topk(mesh, dense_setup):
    s = dense_setup
    gossip_tk = collectives.gossip_compressed_fn(
        mesh, ("pod", "data"), s["pairs"], s["wt"], s["spec"],
        mode="topk:0.5", gamma=0.5)
    with mesh:
        yt, xhat = jax.jit(gossip_tk)(s["x"], s["x"], jnp.int32(0))
    # one round from x̂ = x mixes the damped exact update (innovation
    # q = topk(x - x̂) = 0, x̂ unchanged)
    want_tk = s["x"] + 0.5 * (s["want"] - s["x"])
    np.testing.assert_allclose(np.asarray(yt), np.asarray(want_tk),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(xhat), np.asarray(s["x"]),
                               atol=1e-7)


def test_ring_allreduce_mean(mesh, dense_setup):
    s = dense_setup
    fn = collectives.ring_allreduce_mean_fn(mesh, ("pod", "data"),
                                            s["spec"])
    with mesh:
        y = jax.jit(fn)(s["x"])
    want = np.broadcast_to(np.asarray(s["x"]).mean(0), s["x"].shape)
    np.testing.assert_allclose(np.asarray(y), want, atol=1e-6)


# ---------------------------------------------------------------------------
# offset-routed edge-list gossip (the sharded engine's transport)
# ---------------------------------------------------------------------------

def _edges_for(adj, n, mixing="metropolis"):
    e = topo.edges_from_adj(adj)
    ew = topo.edge_mixing_weights(e, n, mixing)
    return topo.directed_edges(e, ew)


@pytest.mark.parametrize("name,adj", [
    ("ring", topo.ring_topology(W8)),
    ("erdos", topo.erdos_topology(W8, 0.4, np.random.default_rng(11))),
])
def test_edges_sharded_matches_oracle(mesh, name, adj):
    x8 = jax.random.normal(jax.random.PRNGKey(3), (W8, 24))
    x8s = jax.device_put(x8, NamedSharding(mesh, P(("pod", "data"), None)))
    s8, d8, wt8 = _edges_for(adj, W8)
    fe = collectives.gossip_edges_sharded_fn(mesh, ("pod", "data"),
                                             s8, d8, wt8, W8)
    with mesh:
        ye = jax.jit(fe)(x8s)
    want = kernel_ref.gossip_edges_ref(x8, jnp.asarray(s8),
                                       jnp.asarray(d8), jnp.asarray(wt8))
    np.testing.assert_allclose(np.asarray(ye), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("kind,k,ef", [
    ("int8", 0, True),
    ("topk", 6, True),       # x̂-tracked ChocoSGD form
    ("topk", 6, False),      # naive stateless top-k
    ("randk", 6, False),
])
def test_edges_compressed_sharded_matches_oracle(mesh, kind, k, ef):
    adj = topo.erdos_topology(W8, 0.5, np.random.default_rng(5))
    s8, d8, wt8 = _edges_for(adj, W8)
    x8 = jax.random.normal(jax.random.PRNGKey(4), (W8, 37))
    flat = jnp.asarray(x8, jnp.float32)
    err0 = compression.state_init(flat, kind, ef)
    fc = collectives.gossip_edges_compressed_sharded_fn(
        mesh, ("pod", "data"), s8, d8, wt8, W8, kind=kind, k=k,
        error_feedback=ef, seed=0, gamma=0.5)
    xs = jax.device_put(flat, NamedSharding(mesh, P(("pod", "data"), None)))
    es = jax.device_put(err0, NamedSharding(mesh, P(("pod", "data"), None)))
    with mesh:
        ys, news = jax.jit(fc)(xs, es, jnp.int32(2))
    want_y, want_e = compression.compressed_gossip_ref(
        flat, err0, None, error_feedback=ef, kind=kind, k=k,
        key=compression.sparsify_base_key(0), step=jnp.int32(2), gamma=0.5,
        use_kernel=False,
        edges=(jnp.asarray(s8), jnp.asarray(d8), jnp.asarray(wt8)))
    np.testing.assert_allclose(np.asarray(ys), np.asarray(want_y),
                               atol=1e-5)
    # codec payloads are row-local, so the state never crosses shards:
    # it matches to lowering ulps (shard_map may re-associate the
    # dequant arithmetic), far below any routing/residual bug
    np.testing.assert_allclose(np.asarray(news), np.asarray(want_e),
                               atol=1e-6)


@settings(deadline=None, max_examples=20)
@given(data=st.data())
def test_routing_delivers_every_edge_exactly_once(data):
    """Property: for random topologies and shard counts, applying the
    sharded edge gossip to X = I_W extracts the effective mixing matrix,
    which must equal the dense matrix built from the directed edge list —
    i.e. every directed edge is delivered exactly once, to the right
    destination row, with the right weight."""
    n_shards = data.draw(st.sampled_from([2, 4, 8]), label="n_shards")
    w = data.draw(st.sampled_from([8, 16]), label="W")
    seed = data.draw(st.integers(0, 2**31 - 1), label="seed")
    rng = np.random.default_rng(seed)
    adj = topo.erdos_topology(w, rng.uniform(0.15, 0.8), rng)
    if adj.sum() == 0:                      # no edges -> identity mix
        adj = topo.ring_topology(w)
    src, dst, wts = _edges_for(adj, w, mixing="uniform")

    from repro.launch.mesh import make_worker_mesh
    mesh = make_worker_mesh(n_shards)
    fe = collectives.gossip_edges_sharded_fn(mesh, ("workers",),
                                             src, dst, wts, w)
    eye = jnp.eye(w, dtype=jnp.float32)
    got = np.asarray(jax.jit(fe)(jax.device_put(
        eye, NamedSharding(mesh, P("workers", None)))))

    want = np.eye(w, dtype=np.float64)
    for s, d, wt in zip(src, dst, wts):     # y_d += w (x_s - x_d)
        want[d, s] += wt
        want[d, d] -= wt
    np.testing.assert_allclose(got, want, atol=1e-6)
