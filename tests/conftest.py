"""Shared pytest config. NOTE (spec): never set
xla_force_host_platform_device_count here — smoke tests and benches must
see 1 device; multi-device tests run in subprocesses."""
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test (multi-device subprocess runs, multi-"
        "round differential engine comparisons); excluded from the fast "
        "CI lane via -m 'not slow'")
