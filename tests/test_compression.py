"""Compressed-gossip subsystem tests (core/compression.py).

Covers the wire-format accounting the Eq. 10 timing extension relies on,
kernel-vs-oracle parity of the int8 round trip on the engines' [W, P]
layout, and the error-feedback property the scheme exists for: with
residual compensation the compressed mixing converges (in time average)
to the uncompressed network mean, while naive quantized mixing stalls at
a biased quantization-grid fixed point.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression, topology as topo

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# wire accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", [96, 1000, 2762, 7300, 8192, 100_000])
def test_wire_ratio_bounds(p):
    """int8 + per-tile f32 scales land between 2x and 4x smaller than raw
    f32 for any realistic parameter count (the acceptance floor is 2x)."""
    ratio = compression.wire_ratio(p)
    assert 2.0 < ratio <= 4.0
    assert compression.wire_bits(p, "none") == 32 * p


def test_wire_bits_accounting_exact():
    """P=7300 (the simulated MLP payload): pads to one [8, 1024] grid ->
    8192 int8 bytes + 1 scale."""
    assert compression.wire_bits(7300, "int8") == 8192 * 8 + 32
    rows, cols = compression.flat_tile_shape(7300)
    assert (rows, cols) == (8, 1024)


def test_validate_mode_rejects_unknown():
    with pytest.raises(ValueError, match="compress"):
        compression.validate_mode("fp8")


# ---------------------------------------------------------------------------
# int8 round trip: Pallas kernels vs jnp oracle on the engine layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", [96, 2762, 8192])
def test_qdq_rows_kernel_matches_ref(p):
    """The fused engine's Pallas round trip and the reference engine's
    oracle round trip agree to 1 ulp on ŷ (the dequantize multiply may
    compile differently under vmap), and the wire payload itself —
    (q, scales) — is bit-identical (checked on the 2D layout below)."""
    z = jax.random.normal(KEY, (6, p)) * 0.3
    want = compression.qdq_rows(z, use_kernel=False)
    got = compression.qdq_rows(z, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-7, rtol=0)
    # round trip bounded by half an int8 step of each tile's scale
    assert float(jnp.max(jnp.abs(want - z))) <= \
        float(jnp.max(jnp.abs(z))) / 127.0 * 0.51


def test_quantize_2d_kernel_payload_bitwise():
    """Pallas kernel and jnp oracle produce the identical wire payload."""
    from repro.kernels.quantize_block import quantize_block_2d
    z = jax.random.normal(KEY, (8, 1024)) * 0.3
    qk, sk = quantize_block_2d(z, interpret=True)
    qr, sr = compression.quantize_2d_ref(z)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))


def test_compress_decompress_residual_identity():
    """e' = z - ŷ exactly (EF on); EF off leaves the residual untouched
    and quantizes the raw params."""
    flat = jax.random.normal(KEY, (4, 500))
    err = jax.random.normal(jax.random.fold_in(KEY, 1), (4, 500)) * 0.01
    yhat, new_err = compression.compress_decompress(flat, err)
    np.testing.assert_allclose(np.asarray(new_err),
                               np.asarray(flat + err - yhat), atol=0)
    yhat2, err2 = compression.compress_decompress(flat, err,
                                                  error_feedback=False)
    assert err2 is err
    np.testing.assert_array_equal(
        np.asarray(yhat2),
        np.asarray(compression.qdq_rows(flat)))


def test_quantize_flat_roundtrip_matches_rows():
    """The collectives' per-shard path (quantize_flat/dequantize_flat)
    and the engines' row path share one wire format."""
    n = 2762
    z = jax.random.normal(KEY, (n,)) * 2.0
    q, s = compression.quantize_flat(z)
    y = compression.dequantize_flat(q, s, n)
    want = compression.qdq_rows(z[None])[0]
    np.testing.assert_array_equal(np.asarray(y), np.asarray(want))


# ---------------------------------------------------------------------------
# error feedback: the property the scheme exists for
# ---------------------------------------------------------------------------

def _time_averaged_mix(x0, mix, error_feedback, steps=300, burn=100):
    flat, err = x0, jnp.zeros_like(x0)
    acc = np.zeros(x0.shape)
    for t in range(steps):
        flat, err = compression.compressed_gossip_ref(
            flat, err, mix, error_feedback=error_feedback)
        if t >= burn:
            acc += np.asarray(flat)
    return acc / (steps - burn)


def test_error_feedback_converges_naive_biases():
    """Fixed ring topology, doubly stochastic Metropolis mix: the
    residual-compensated iterates converge (in time average) to the
    uncompressed network mean; naive quantized mixing freezes at a
    quantization-grid point biased ~an int8 step away (measured: EF
    ~5e-5 vs naive ~6e-3 for unit-scale models — a >100x gap)."""
    w, p = 8, 600
    rng = np.random.default_rng(0)
    x0 = jnp.asarray(rng.normal(size=(w, p)), jnp.float32)
    mix = jnp.asarray(
        topo.mixing_matrix_metropolis(topo.ring_topology(w)), jnp.float32)
    target = np.asarray(x0).mean(0)

    ef = _time_averaged_mix(x0, mix, True)
    naive = _time_averaged_mix(x0, mix, False)
    dev_ef = np.abs(ef - target).max()
    dev_naive = np.abs(naive - target).max()
    assert dev_ef < 5e-4, dev_ef
    assert dev_naive > 1e-3, dev_naive
    assert dev_naive > 10 * dev_ef


def test_compressed_gossip_preserves_mean():
    """Doubly stochastic mixing of ŷ preserves the fleet average of x
    exactly (per-round invariant behind the convergence property)."""
    w, p = 6, 400
    x = jax.random.normal(KEY, (w, p))
    err = jax.random.normal(jax.random.fold_in(KEY, 2), (w, p)) * 0.01
    mix = jnp.asarray(
        topo.mixing_matrix_uniform(topo.ring_topology(w)), jnp.float32)
    mixed, _ = compression.compressed_gossip_ref(x, err, mix)
    np.testing.assert_allclose(np.asarray(mixed.mean(0)),
                               np.asarray(x.mean(0)), atol=1e-5)


def test_identity_mix_is_exact_noop():
    """A round through an identity mix returns x bit-for-bit (the fused
    engine's no-communication gating relies on the same cancellation)."""
    w, p = 4, 300
    x = jax.random.normal(KEY, (w, p))
    mixed, _ = compression.compressed_gossip_ref(
        x, jnp.zeros_like(x), jnp.eye(w, dtype=jnp.float32))
    np.testing.assert_array_equal(np.asarray(mixed), np.asarray(x))


# ---------------------------------------------------------------------------
# sparse codecs: parsing + wire accounting
# ---------------------------------------------------------------------------

def test_parse_mode_sparse():
    """"topk:<k>" / "randk:<k>" parse to sparse codecs with fractional
    (< 1) or absolute (>= 1) keep specs; a Codec passes through."""
    c = compression.parse_mode("topk:0.1")
    assert (c.kind, c.k, c.is_sparse) == ("topk", 0.1, True)
    assert c.resolve_k(1000) == 100
    assert c.mode == "topk:0.1"
    c2 = compression.parse_mode("randk:64")
    assert c2.resolve_k(1000) == 64
    assert compression.parse_mode(c2) is c2
    assert c.with_k(0.05).resolve_k(1000) == 50
    assert not compression.parse_mode("int8").is_sparse
    for bad in ("topk", "topk:", "topk:-1", "randk:0", "sparse:9", "fp8"):
        with pytest.raises(ValueError, match="compress"):
            compression.parse_mode(bad)


def test_sparse_wire_accounting():
    """top-k ships k (value, index) pairs; rand-k ships k values plus the
    shared mask seed, so it is ~2x cheaper at equal k; both ratios are
    monotone in k (tightening k always shrinks the payload)."""
    p = 7300
    topk = compression.parse_mode("topk:0.1")
    k = topk.resolve_k(p)
    assert topk.wire_bits(p) == k * (compression.FP32_BITS
                                     + compression.INDEX_BITS)
    randk = compression.parse_mode("randk:0.1")
    assert randk.wire_bits(p) == k * compression.FP32_BITS \
        + compression.SEED_BITS
    assert randk.wire_ratio(p) > topk.wire_ratio(p)
    assert compression.wire_ratio(p, "topk:0.1") >= 4.0   # the CI gate
    ratios = [compression.wire_ratio(p, f"topk:{f}")
              for f in (0.5, 0.25, 0.125, 0.0625)]
    assert ratios == sorted(ratios)
    # module-level helpers agree with the codec methods
    assert compression.wire_bits(p, "randk:0.1") == randk.wire_bits(p)


# ---------------------------------------------------------------------------
# sparse round trips: kernel vs oracle on the engine layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", [96, 2762, 8192])
def test_sparsify_rows_kernel_matches_oracle(p):
    """The Pallas mask-and-pack path and the jnp oracle are pure selects
    of the same mask — outputs bit-identical, exactly k kept per row."""
    kkey = compression.sparsify_base_key(7)
    z = jax.random.normal(KEY, (5, p)) * 0.3
    k = max(p // 10, 1)
    for kind, kw in (("topk", {}),
                     ("randk", dict(key=kkey, step=jnp.int32(3)))):
        want = compression.sparsify_rows(z, kind, k, **kw)
        got = compression.sparsify_rows(z, kind, k, use_kernel=True,
                                        interpret=True, **kw)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=kind)
        assert (np.asarray(want != 0).sum(axis=1) <= k).all()


def test_sparsify_topk_keeps_largest():
    """Every kept coordinate dominates every dropped one in |z|."""
    z = jax.random.normal(KEY, (3, 500))
    y = np.asarray(compression.sparsify_rows(z, "topk", 50))
    za = np.abs(np.asarray(z))
    for r in range(3):
        kept = za[r][y[r] != 0]
        dropped = za[r][y[r] == 0]
        assert len(kept) == 50
        assert kept.min() >= dropped.max()


def test_sparsify_block_kernel_parity():
    """sparsify_block_2d == the ref.py oracle on values AND per-tile
    survivor counts (the pack accounting)."""
    from repro.kernels import ref
    from repro.kernels.sparsify_block import sparsify_block_2d
    x = jax.random.normal(KEY, (8, 1024))
    gate = jnp.abs(x)
    t = 0.7
    yk, nk = sparsify_block_2d(x, gate, t, interpret=True)
    yr, nr = ref.sparsify_block_ref(x, gate, t)
    np.testing.assert_array_equal(np.asarray(yk), np.asarray(yr))
    np.testing.assert_array_equal(np.asarray(nk), np.asarray(nr))
    assert int(np.asarray(nk).sum()) == int((np.abs(np.asarray(x)) >= t).sum())


def test_randk_mask_shared_and_step_varied():
    """The rand-k draw is one shared mask per step (every row keeps the
    same coordinates — what lets the wire ship no indices) and changes
    with the step."""
    kkey = compression.sparsify_base_key(3)
    z = jnp.ones((4, 400))
    y1 = np.asarray(compression.sparsify_rows(z, "randk", 40, key=kkey,
                                              step=jnp.int32(5)))
    y2 = np.asarray(compression.sparsify_rows(z, "randk", 40, key=kkey,
                                              step=jnp.int32(5)))
    y3 = np.asarray(compression.sparsify_rows(z, "randk", 40, key=kkey,
                                              step=jnp.int32(6)))
    np.testing.assert_array_equal(y1, y2)
    assert not np.array_equal(y1, y3)
    assert (np.all(y1 == y1[0], axis=0)).all()   # same mask on every row


# ---------------------------------------------------------------------------
# sparse codecs: the convergence properties the designs exist for
# ---------------------------------------------------------------------------

def _sparse_mix(x0, mix, kind, k, error_feedback, steps=400, gamma=0.25):
    key = compression.sparsify_base_key(0)
    flat = x0
    err = compression.state_init(x0, kind, error_feedback)
    for t in range(steps):
        flat, err = compression.compressed_gossip_ref(
            flat, err, mix, error_feedback=error_feedback, kind=kind,
            k=k, key=key, step=jnp.int32(t), gamma=gamma)
    return np.asarray(flat)


def test_topk_xhat_tracking_converges_naive_freezes():
    """x̂-tracked top-k contracts to exact consensus (the ChocoSGD form;
    a damped step on tracked public copies), while naive top-k (EF off)
    never ships small coordinates, so they stay frozen at their initial
    values — the property the x̂ state exists for."""
    w, p, k = 8, 600, 60
    rng = np.random.default_rng(0)
    x0 = jnp.asarray(rng.normal(size=(w, p)), jnp.float32)
    mix = jnp.asarray(
        topo.mixing_matrix_metropolis(topo.ring_topology(w)), jnp.float32)
    target = np.asarray(x0).mean(0)

    tracked = _sparse_mix(x0, mix, "topk", k, True)
    assert np.abs(tracked - target).max() < 1e-3
    naive = _sparse_mix(x0, mix, "topk", k, False, steps=100)
    # small coordinates never go on the wire -> rows stay apart
    assert np.abs(naive - target[None]).max() > 0.5


def test_randk_shared_mask_converges():
    """Shared-mask rand-k is intermittent exact gossip: every coordinate
    is drawn eventually, so the iterates contract to the true mean with
    no state at all."""
    w, p, k = 8, 600, 120
    rng = np.random.default_rng(1)
    x0 = jnp.asarray(rng.normal(size=(w, p)), jnp.float32)
    mix = jnp.asarray(
        topo.mixing_matrix_metropolis(topo.ring_topology(w)), jnp.float32)
    target = np.asarray(x0).mean(0)
    out = _sparse_mix(x0, mix, "randk", k, True)
    assert np.abs(out - target).max() < 1e-3


@pytest.mark.parametrize("kind,k", [("topk", 80), ("randk", 80)])
def test_sparse_gossip_preserves_mean(kind, k):
    """Doubly stochastic mixing preserves the fleet average exactly for
    both sparse forms (x̂-tracked and shared-mask)."""
    w, p = 6, 400
    x = jax.random.normal(KEY, (w, p))
    err = compression.state_init(x, kind, True)
    mix = jnp.asarray(
        topo.mixing_matrix_uniform(topo.ring_topology(w)), jnp.float32)
    mixed, _ = compression.compressed_gossip_ref(
        x, err, mix, kind=kind, k=k,
        key=compression.sparsify_base_key(2), step=jnp.int32(0))
    np.testing.assert_allclose(np.asarray(mixed.mean(0)),
                               np.asarray(x.mean(0)), atol=1e-5)


def test_sparse_pair_preserves_sum():
    """The pairwise (AD-PSGD) forms preserve x_i + x_j for both sparse
    codecs, like the int8 exchange."""
    p = 500
    xi = jax.random.normal(KEY, (p,))
    xj = jax.random.normal(jax.random.fold_in(KEY, 3), (p,))
    for kind in ("topk", "randk"):
        s0 = compression.state_init(jnp.stack([xi, xj]), kind, True)
        xi2, xj2, *_ = compression.compressed_pair_ref(
            xi, xj, s0[0], s0[1], kind=kind, k=50,
            key=compression.sparsify_base_key(4), step=jnp.int32(9),
            gamma=0.25)
        np.testing.assert_allclose(np.asarray(xi2 + xj2),
                                   np.asarray(xi + xj), atol=1e-5,
                                   err_msg=kind)
