"""Compressed-gossip subsystem tests (core/compression.py).

Covers the wire-format accounting the Eq. 10 timing extension relies on,
kernel-vs-oracle parity of the int8 round trip on the engines' [W, P]
layout, and the error-feedback property the scheme exists for: with
residual compensation the compressed mixing converges (in time average)
to the uncompressed network mean, while naive quantized mixing stalls at
a biased quantization-grid fixed point.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression, topology as topo

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# wire accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", [96, 1000, 2762, 7300, 8192, 100_000])
def test_wire_ratio_bounds(p):
    """int8 + per-tile f32 scales land between 2x and 4x smaller than raw
    f32 for any realistic parameter count (the acceptance floor is 2x)."""
    ratio = compression.wire_ratio(p)
    assert 2.0 < ratio <= 4.0
    assert compression.wire_bits(p, "none") == 32 * p


def test_wire_bits_accounting_exact():
    """P=7300 (the simulated MLP payload): pads to one [8, 1024] grid ->
    8192 int8 bytes + 1 scale."""
    assert compression.wire_bits(7300, "int8") == 8192 * 8 + 32
    rows, cols = compression.flat_tile_shape(7300)
    assert (rows, cols) == (8, 1024)


def test_validate_mode_rejects_unknown():
    with pytest.raises(ValueError, match="compress"):
        compression.validate_mode("fp8")


# ---------------------------------------------------------------------------
# int8 round trip: Pallas kernels vs jnp oracle on the engine layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", [96, 2762, 8192])
def test_qdq_rows_kernel_matches_ref(p):
    """The fused engine's Pallas round trip and the reference engine's
    oracle round trip agree to 1 ulp on ŷ (the dequantize multiply may
    compile differently under vmap), and the wire payload itself —
    (q, scales) — is bit-identical (checked on the 2D layout below)."""
    z = jax.random.normal(KEY, (6, p)) * 0.3
    want = compression.qdq_rows(z, use_kernel=False)
    got = compression.qdq_rows(z, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-7, rtol=0)
    # round trip bounded by half an int8 step of each tile's scale
    assert float(jnp.max(jnp.abs(want - z))) <= \
        float(jnp.max(jnp.abs(z))) / 127.0 * 0.51


def test_quantize_2d_kernel_payload_bitwise():
    """Pallas kernel and jnp oracle produce the identical wire payload."""
    from repro.kernels.quantize_block import quantize_block_2d
    z = jax.random.normal(KEY, (8, 1024)) * 0.3
    qk, sk = quantize_block_2d(z, interpret=True)
    qr, sr = compression.quantize_2d_ref(z)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))


def test_compress_decompress_residual_identity():
    """e' = z - ŷ exactly (EF on); EF off leaves the residual untouched
    and quantizes the raw params."""
    flat = jax.random.normal(KEY, (4, 500))
    err = jax.random.normal(jax.random.fold_in(KEY, 1), (4, 500)) * 0.01
    yhat, new_err = compression.compress_decompress(flat, err)
    np.testing.assert_allclose(np.asarray(new_err),
                               np.asarray(flat + err - yhat), atol=0)
    yhat2, err2 = compression.compress_decompress(flat, err,
                                                  error_feedback=False)
    assert err2 is err
    np.testing.assert_array_equal(
        np.asarray(yhat2),
        np.asarray(compression.qdq_rows(flat)))


def test_quantize_flat_roundtrip_matches_rows():
    """The collectives' per-shard path (quantize_flat/dequantize_flat)
    and the engines' row path share one wire format."""
    n = 2762
    z = jax.random.normal(KEY, (n,)) * 2.0
    q, s = compression.quantize_flat(z)
    y = compression.dequantize_flat(q, s, n)
    want = compression.qdq_rows(z[None])[0]
    np.testing.assert_array_equal(np.asarray(y), np.asarray(want))


# ---------------------------------------------------------------------------
# error feedback: the property the scheme exists for
# ---------------------------------------------------------------------------

def _time_averaged_mix(x0, mix, error_feedback, steps=300, burn=100):
    flat, err = x0, jnp.zeros_like(x0)
    acc = np.zeros(x0.shape)
    for t in range(steps):
        flat, err = compression.compressed_gossip_ref(
            flat, err, mix, error_feedback=error_feedback)
        if t >= burn:
            acc += np.asarray(flat)
    return acc / (steps - burn)


def test_error_feedback_converges_naive_biases():
    """Fixed ring topology, doubly stochastic Metropolis mix: the
    residual-compensated iterates converge (in time average) to the
    uncompressed network mean; naive quantized mixing freezes at a
    quantization-grid point biased ~an int8 step away (measured: EF
    ~5e-5 vs naive ~6e-3 for unit-scale models — a >100x gap)."""
    w, p = 8, 600
    rng = np.random.default_rng(0)
    x0 = jnp.asarray(rng.normal(size=(w, p)), jnp.float32)
    mix = jnp.asarray(
        topo.mixing_matrix_metropolis(topo.ring_topology(w)), jnp.float32)
    target = np.asarray(x0).mean(0)

    ef = _time_averaged_mix(x0, mix, True)
    naive = _time_averaged_mix(x0, mix, False)
    dev_ef = np.abs(ef - target).max()
    dev_naive = np.abs(naive - target).max()
    assert dev_ef < 5e-4, dev_ef
    assert dev_naive > 1e-3, dev_naive
    assert dev_naive > 10 * dev_ef


def test_compressed_gossip_preserves_mean():
    """Doubly stochastic mixing of ŷ preserves the fleet average of x
    exactly (per-round invariant behind the convergence property)."""
    w, p = 6, 400
    x = jax.random.normal(KEY, (w, p))
    err = jax.random.normal(jax.random.fold_in(KEY, 2), (w, p)) * 0.01
    mix = jnp.asarray(
        topo.mixing_matrix_uniform(topo.ring_topology(w)), jnp.float32)
    mixed, _ = compression.compressed_gossip_ref(x, err, mix)
    np.testing.assert_allclose(np.asarray(mixed.mean(0)),
                               np.asarray(x.mean(0)), atol=1e-5)


def test_identity_mix_is_exact_noop():
    """A round through an identity mix returns x bit-for-bit (the fused
    engine's no-communication gating relies on the same cancellation)."""
    w, p = 4, 300
    x = jax.random.normal(KEY, (w, p))
    mixed, _ = compression.compressed_gossip_ref(
        x, jnp.zeros_like(x), jnp.eye(w, dtype=jnp.float32))
    np.testing.assert_array_equal(np.asarray(mixed), np.asarray(x))
