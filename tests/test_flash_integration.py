"""Flash-kernel integration: models with cfg.use_flash_kernel=True match
the jnp reference path (interpret mode on CPU; TPU is the target)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_smoke_config
from repro.models import registry

SHAPE = dataclasses.replace(SHAPES["train_4k"], seq_len=128, global_batch=2)


@pytest.mark.parametrize("arch", ["smollm-360m", "olmoe-1b-7b",
                                  "zamba2-7b", "gemma3-27b"])
def test_flash_forward_matches_reference(arch):
    cfg = get_smoke_config(arch)
    # flash path needs MXU-aligned head_dim; lift the smoke dims
    cfg = dataclasses.replace(cfg, d_model=128, num_heads=2, num_kv_heads=2,
                              head_dim=64,
                              **({"sliding_window": 64}
                                 if cfg.sliding_window else {}))
    rng = jax.random.PRNGKey(0)
    params = registry.init_params(cfg, rng)
    batch = registry.make_batch(cfg, SHAPE, rng)

    loss_ref, _ = registry.loss_fn(cfg, params, batch)
    cfg_flash = dataclasses.replace(cfg, use_flash_kernel=True)
    loss_flash, _ = registry.loss_fn(cfg_flash, params, batch)
    np.testing.assert_allclose(np.asarray(loss_ref),
                               np.asarray(loss_flash), rtol=2e-4, atol=2e-4)


def test_flash_grads_match_reference():
    cfg = get_smoke_config("smollm-360m")
    cfg = dataclasses.replace(cfg, d_model=128, num_heads=2, num_kv_heads=2,
                              head_dim=64)
    rng = jax.random.PRNGKey(1)
    params = registry.init_params(cfg, rng)
    batch = registry.make_batch(cfg, SHAPE, rng)

    def loss_of(c):
        return lambda p: registry.loss_fn(c, p, batch)[0]

    g_ref = jax.grad(loss_of(cfg))(params)
    g_flash = jax.grad(loss_of(
        dataclasses.replace(cfg, use_flash_kernel=True)))(params)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_flash)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-3)
