"""Substrate tests: data partitioner (paper's p-skew), optimizers,
checkpoint store, SSM/mLSTM kernels-vs-oracles, consensus machinery —
with hypothesis property tests on the system invariants."""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
# hypothesis is optional (dev dependency): the guard skips only the
# property tests when it is absent, plain tests still run
from _hypothesis_compat import given, settings, st

from repro.data.partition import label_histogram, pskew_partition
from repro.data.synthetic import (make_classification_data, make_token_data,
                                  worker_batch_iterator)

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# p-skew partitioner (Sec. V-A)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(p=st.floats(0.0, 0.9), n=st.sampled_from([6, 12, 30]))
def test_pskew_partition_covers_all_samples(p, n):
    labels = np.repeat(np.arange(10), 60)
    rng = np.random.default_rng(0)
    shards = pskew_partition(labels, n, p, rng)
    allix = np.sort(np.concatenate(shards))
    assert np.array_equal(allix, np.arange(len(labels)))  # exact partition


def test_pskew_skew_increases_with_p():
    """Higher p => more concentrated class mass on the pinned group."""
    labels = np.repeat(np.arange(10), 300)
    rng = np.random.default_rng(1)

    def peak_mass(p):
        shards = pskew_partition(labels, 30, p, np.random.default_rng(2))
        h = label_histogram(labels, shards, 10).astype(float)
        h /= h.sum(0, keepdims=True)
        return np.sort(h, axis=0)[-3:].sum(0).mean()   # top-3 worker mass

    assert peak_mass(0.8) > peak_mass(0.4) > peak_mass(0.1)


def test_worker_iterator_batches():
    data = make_classification_data(600, 16, 5, seed=0)
    shards = pskew_partition(data.y, 6, 0.4, np.random.default_rng(0))
    it = worker_batch_iterator(data, shards[0], 32, seed=0)
    b = next(it)
    assert b["x"].shape == (32, 16) and b["y"].shape == (32,)


def test_token_data_class_structure():
    d = make_token_data(64, 64, 128, num_classes=4, seed=0)
    assert d.x.shape == (64, 64)
    assert d.x.max() < 128 and d.x.min() >= 0


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quad_problem():
    target = jnp.array([1.0, -2.0, 3.0])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    return loss, {"w": jnp.zeros(3)}


@pytest.mark.parametrize("maker", ["sgd", "momentum", "adamw"])
def test_optimizers_converge(maker):
    from repro import optim
    loss, params = _quad_problem()
    opt = {"sgd": lambda: optim.sgd(0.1),
           "momentum": lambda: optim.momentum_sgd(0.05, 0.9),
           "adamw": lambda: optim.adamw(0.2)}[maker]()
    state = opt.init(params)
    for _ in range(120):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    assert float(loss(params)) < 1e-2


def test_exponential_decay_schedule():
    from repro.optim import exponential_decay
    s = exponential_decay(0.1, 0.98)
    assert np.isclose(float(s(jnp.asarray(0))), 0.1)
    assert np.isclose(float(s(jnp.asarray(10))), 0.1 * 0.98 ** 10)


# ---------------------------------------------------------------------------
# checkpoint retention / atomicity
# ---------------------------------------------------------------------------

def test_checkpoint_manager_retention():
    from repro.checkpoint import CheckpointManager
    state = {"a": np.arange(6).reshape(2, 3), "b": {"c": np.ones(4)}}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in range(5):
            mgr.save(s, state, meta={"s": s})
        from repro.checkpoint.store import list_steps
        assert list_steps(d) == [3, 4]
        restored, meta = mgr.restore(state)
        assert meta["step"] == 4
        assert np.array_equal(restored["a"], state["a"])
        assert not any(f.startswith("tmp") for f in os.listdir(d))


# ---------------------------------------------------------------------------
# SSD / mLSTM chunked-vs-sequential oracles (property sweep)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([32, 64, 96]), h=st.sampled_from([1, 2]),
       n=st.sampled_from([8, 16]), chunk=st.sampled_from([16, 32]))
def test_ssd_chunked_matches_sequential(s, h, n, chunk):
    from repro.models.ssm import ssd_chunked, ssd_ref
    b, p = 2, 16
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    xh = jax.random.normal(k1, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(k2, (b, s, h)))
    a_log = jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32))
    bb = jax.random.normal(k3, (b, s, n)) * 0.3
    cc = jax.random.normal(k4, (b, s, n)) * 0.3
    y1, st1 = ssd_chunked(xh, dt, a_log, bb, cc, chunk=chunk)
    y2, st2 = ssd_ref(xh, dt, a_log, bb, cc)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2),
                               atol=2e-3, rtol=2e-3)


def test_mlstm_parallel_matches_recurrent_decode():
    """Chunked-parallel mLSTM (train path) == recurrent decode (serve path)
    on the same sequence — the xLSTM parallel/recurrent equivalence."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models import xlstm
    cfg = get_smoke_config("xlstm-1.3b")
    p = xlstm.init_mlstm_block(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 16, cfg.d_model),
                          jnp.float32) * 0.5
    y_par = xlstm.apply_mlstm(p, x, cfg, chunk=8)
    cache = xlstm.init_mlstm_cache(cfg, 1)
    outs = []
    for t in range(16):
        y, cache = xlstm.decode_mlstm(p, x[:, t:t + 1], cache, cfg)
        outs.append(y)
    y_rec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec),
                               atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# consensus machinery (Eq. 36-39)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([4, 8, 12]))
def test_floyd_warshall_upper_bounds_true_distance(n):
    """Triangle-inequality estimates never UNDER-estimate (Eq. 37)."""
    from repro.core.consensus import (floyd_warshall_estimate,
                                      measured_distance_matrix,
                                      pairwise_distances)
    from repro.core.topology import ring_topology
    rng = np.random.default_rng(n)
    x = rng.normal(size=(n, 20))
    true = pairwise_distances(x)
    est = floyd_warshall_estimate(
        measured_distance_matrix(ring_topology(n), true))
    assert (est >= true - 1e-9).all()
    # measured edges are exact
    ring = ring_topology(n)
    assert np.allclose(est[ring > 0], true[ring > 0])
