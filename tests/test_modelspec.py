"""ModelAdapter (core/modelspec.py): the model bridge the engines train
through.

Covers the PR-8 contract from four sides:
- spec parsing / canonicalization (equivalent spellings hash to the same
  jit cache entry, non-token families are rejected);
- the flat layout: ``unflatten_one(flatten_one(p)) == p`` bit-exactly
  per registry family (hypothesis over init seeds), and the
  ``leaf_offsets()`` table agrees with ``jax.flatten_util.ravel_pytree``;
- per-leaf codec maps: compiled-segment wire accounting equals a manual
  per-segment recomputation straight off the leaf table;
- registry pytrees through BOTH engines (reference vs fused scan):
  exact host-replayed fields, <= 1e-5 device drift, and checkpoint
  save -> load -> resume through ``History.final_params``.
"""
from __future__ import annotations

from dataclasses import replace

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import FedHPConfig
from repro.core import compression, modelspec
from repro.core.experiment import run_algorithm

FAMILIES = ("mlp", "dense", "moe", "hybrid", "xlstm")
LEAFMAP = "leafmap:embed=randk:0.05,ln=none,default=int8"

CFG = FedHPConfig(num_workers=4, rounds=4, tau_init=2, tau_max=6,
                  lr=0.05, batch_size=16, seed=3)

# host-replayed fields must match bit-exactly between the engines;
# device metrics go through one fused XLA program and may re-associate
EXACT = ("round", "round_time", "waiting_time", "mean_tau", "num_links",
         "cumulative_time")
DEVICE_TOL = {"accuracy": 1e-5, "loss": 1e-4, "consensus": 1e-4}


# ---------------------------------------------------------------------------
# spec parsing / identity
# ---------------------------------------------------------------------------

def test_spec_canonicalization_and_hash():
    """Equivalent key spellings resolve to the same canonical spec, so
    the adapters compare equal and share a jit cache entry."""
    a = modelspec.get_adapter("dense:d=32,layers=2")
    b = modelspec.get_adapter("dense:d_model=32,l=2")
    assert a.spec == b.spec
    assert a == b and hash(a) == hash(b)
    c = modelspec.get_adapter("dense:d=48")
    assert a != c
    m1 = modelspec.get_adapter("mlp")
    m2 = modelspec.get_adapter("mlp", dim=32, hidden=64, num_classes=10)
    assert m1 == m2 and hash(m1) == hash(m2)
    assert m1 != a


def test_non_token_families_rejected():
    """encdec / vlm need modality inputs the DFL batch pipeline does not
    carry; unknown spec keys are named in the error."""
    with pytest.raises(ValueError, match="cannot train under DFL"):
        modelspec.get_adapter("vlm")
    with pytest.raises(ValueError, match="cannot train under DFL"):
        modelspec.get_adapter("encdec:d=32")
    with pytest.raises(ValueError, match="unknown model spec keys"):
        modelspec.get_adapter("dense:bogus=3")


def test_adapter_for_takes_mlp_dims_from_data():
    """The engines' call pattern: MLP shapes come from the dataset."""
    cfg = CFG
    adapter = modelspec.get_adapter("mlp", dim=12, num_classes=4)
    data = adapter.make_data(256, seed=0)
    got = modelspec.adapter_for(cfg, data)
    assert got.dim == 12 and got.num_classes == 4
    reg = modelspec.adapter_for(replace(cfg, model="dense"), data)
    assert reg.spec.startswith("dense:")


# ---------------------------------------------------------------------------
# flat layout: round trip + leaf offsets
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", FAMILIES)
def test_flatten_round_trip_exact(family):
    """``unflatten_one(flatten_one(p))`` reproduces every leaf bit-
    exactly (same treedef, shape, dtype, bytes) for each DFL family."""
    adapter = modelspec.get_adapter(family)
    params = adapter.init(jax.random.PRNGKey(7))
    back = adapter.unflatten_one(adapter.flatten_one(params))
    assert (jax.tree.structure(back) == jax.tree.structure(params))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       family=st.sampled_from(("dense", "moe", "hybrid", "xlstm")))
def test_flatten_round_trip_property(seed, family):
    """Property form over init seeds: the layout is seed-independent
    (it only depends on the template), so the round trip is exact for
    every draw."""
    adapter = modelspec.get_adapter(family)
    params = adapter.init(jax.random.PRNGKey(seed))
    back = adapter.unflatten_one(adapter.flatten_one(params))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("family", FAMILIES)
def test_leaf_offsets_match_ravel_pytree(family):
    """The offset table IS the layout: ``flat[start:stop]`` holds each
    leaf row-major in ``jax.tree`` order — the same order
    ``jax.flatten_util.ravel_pytree`` concatenates in — and the sizes
    tile [0, P) exactly."""
    adapter = modelspec.get_adapter(family)
    params = adapter.init(jax.random.PRNGKey(0))
    flat = np.asarray(adapter.flatten_one(params))
    ravel, _ = jax.flatten_util.ravel_pytree(params)
    np.testing.assert_array_equal(flat, np.asarray(ravel,
                                                   dtype=np.float32))
    infos = adapter.leaf_offsets()
    assert infos[0].start == 0
    assert all(a.stop == b.start for a, b in zip(infos, infos[1:]))
    assert infos[-1].stop == adapter.param_count == flat.shape[0]
    assert adapter.model_bits == 32.0 * adapter.param_count
    for info, leaf in zip(infos, jax.tree.leaves(params)):
        assert info.shape == tuple(leaf.shape)
        assert info.dtype == str(leaf.dtype)
        np.testing.assert_array_equal(
            flat[info.start:info.stop].reshape(info.shape),
            np.asarray(leaf, dtype=np.float32))


# ---------------------------------------------------------------------------
# per-leaf codec maps: wire accounting
# ---------------------------------------------------------------------------

def test_leafmap_wire_accounting_matches_manual():
    """The compiled map's wire bits equal a manual recomputation
    straight off the leaf table: walk the leaves, assign first-match
    codecs, merge adjacent same-codec runs, sum each run's own uniform
    accounting. Also: the map must always beat its default codec alone
    here (embed rand-k ships fewer bits than int8 would)."""
    adapter = modelspec.get_adapter("dense")
    lcodec = compression.parse_mode(LEAFMAP)
    with pytest.raises(ValueError, match="compiled"):
        lcodec.wire_bits()
    compiled = lcodec.compile(adapter.leaf_offsets())

    runs: list[list] = []                  # manual re-derivation
    for leaf in adapter.leaf_offsets():
        codec = lcodec.codec_for(leaf.name)
        if runs and runs[-1][2] == codec:
            runs[-1][1] = leaf.stop
        else:
            runs.append([leaf.start, leaf.stop, codec])
    manual = sum(c.wire_bits(b - a) for a, b, c in runs)
    assert len(compiled.segments) == len(runs)
    assert compiled.wire_bits() == manual
    P = adapter.param_count
    assert compiled.wire_ratio() == pytest.approx(32 * P / manual)
    assert compiled.wire_ratio() >= compression.wire_ratio(P, "int8")
    # segment k resolves against the MERGED segment length
    for seg, (a, b, c) in zip(compiled.segments, runs):
        assert (seg.start, seg.stop) == (a, b)
        assert seg.k_abs == c.resolve_k(b - a)


def test_leafmap_mode_round_trip():
    """mode string -> parse -> mode string is stable (config echo)."""
    lcodec = compression.parse_mode(LEAFMAP)
    assert compression.parse_mode(lcodec.mode).mode == lcodec.mode


# ---------------------------------------------------------------------------
# registry pytrees through both engines
# ---------------------------------------------------------------------------

def _pair(algo, cfg, rounds=4):
    h_ref = run_algorithm(algo, cfg, non_iid_p=0.4, rounds=rounds,
                          num_samples=1200)
    h_fus = run_algorithm(algo, cfg, non_iid_p=0.4, rounds=rounds,
                          num_samples=1200, fused=True)
    return h_ref, h_fus


def _assert_equivalent(h_ref, h_fus):
    assert len(h_ref.records) == len(h_fus.records)
    a, b = h_ref.as_arrays(), h_fus.as_arrays()
    for k in EXACT:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    for k, tol in DEVICE_TOL.items():
        np.testing.assert_allclose(a[k], b[k], rtol=tol, atol=tol,
                                   err_msg=k)


@pytest.mark.slow
def test_dense_fedhp_leafmap_ref_vs_fused():
    """A dense transformer LM under fedhp with the per-leaf codec map:
    the engines share the oracle leafmap payload math, so host fields
    match exactly and device metrics agree to float tolerance."""
    cfg = replace(CFG, model="dense", compress=LEAFMAP)
    h_ref, h_fus = _pair("fedhp", cfg)
    _assert_equivalent(h_ref, h_fus)
    assert h_ref.final_params is not None
    assert h_fus.final_params is not None


@pytest.mark.slow
def test_xlstm_dpsgd_ref_vs_fused():
    """Second registry family (xLSTM), uncompressed D-PSGD."""
    cfg = replace(CFG, model="xlstm")
    _assert_equivalent(*_pair("dpsgd", cfg))


@pytest.mark.slow
def test_mlp_unchanged_as_adapter():
    """The synthetic MLP rides the same adapter path; the engines still
    agree on it (regression guard for the refactor itself)."""
    _assert_equivalent(*_pair("fedhp", CFG))


# ---------------------------------------------------------------------------
# checkpoint: save -> load -> resume on nested pytrees
# ---------------------------------------------------------------------------

def test_checkpoint_round_trips_nested_pytrees(tmp_path):
    """Nested registry pytrees round-trip with shape AND dtype
    preserved — including bfloat16 leaves, which npz cannot store
    natively (they ride as uint16 views + a dtype sidecar)."""
    from repro.checkpoint.store import load_checkpoint, save_checkpoint
    from repro.models import registry

    adapter = modelspec.get_adapter("dense")
    cfg_bf16 = replace(adapter.cfg, dtype="bfloat16")
    params = registry.init_params(cfg_bf16, jax.random.PRNGKey(1))
    state = jax.tree.map(np.asarray, params)
    assert any(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(state))
    save_checkpoint(str(tmp_path), 3, state, meta={"arch": "dense"})
    loaded, meta = load_checkpoint(str(tmp_path), state)
    assert meta["step"] == 3 and meta["arch"] == "dense"
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(loaded)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(a.view(np.uint8), b.view(np.uint8))


def test_checkpoint_load_validates_shape_and_dtype(tmp_path):
    """Corrupted/mismatched templates are named, not silently cast."""
    from repro.checkpoint.store import load_checkpoint, save_checkpoint

    state = {"w": np.ones((4, 3), np.float32), "b": np.zeros(3, np.int32)}
    save_checkpoint(str(tmp_path), 0, state)
    with pytest.raises(ValueError, match="shape"):
        load_checkpoint(str(tmp_path), {"w": np.ones((4, 5), np.float32),
                                        "b": state["b"]})
    with pytest.raises(ValueError, match="dtype"):
        load_checkpoint(str(tmp_path), {"w": state["w"],
                                        "b": np.zeros(3, np.int64)})
    # elastic restore: a different leading (worker) dim is fine
    loaded, _ = load_checkpoint(
        str(tmp_path), {"w": np.ones((9, 3), np.float32), "b": state["b"]})
    assert loaded["w"].shape == (4, 3)


@pytest.mark.slow
def test_checkpoint_save_load_resume_dfl(tmp_path):
    """End to end: short DFL run -> save ``History.final_params`` ->
    load -> resume via ``init_params=``. The resumed fleet starts from
    the checkpointed weights exactly (round-0 consensus of a resumed
    run equals the saved fleet's spread, not a fresh init's)."""
    from repro.checkpoint.store import load_checkpoint, save_checkpoint

    cfg = replace(CFG, model="dense")
    h1 = run_algorithm("dpsgd", cfg, non_iid_p=0.4, rounds=3,
                       num_samples=1200)
    state = jax.tree.map(np.asarray, h1.final_params)
    save_checkpoint(str(tmp_path), 2, state)
    loaded, meta = load_checkpoint(str(tmp_path), state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(a, b)

    from repro.core import engine
    from repro.core.experiment import setup_experiment
    from repro.core.topology import make_base_topology
    from repro.core.algorithms import make_strategy

    cfg2 = replace(cfg, algorithm="dpsgd")
    train, tx, ty, shards, cluster = setup_experiment(
        cfg2, non_iid_p=0.4, num_samples=1200)
    base = make_base_topology(cfg2.num_workers, cfg2.base_topology,
                              cfg2.seed)
    h2 = engine.run_dfl(train, tx, ty, shards, cluster, cfg2,
                        make_strategy(cfg2, base), rounds=2,
                        init_params=loaded)
    assert len(h2.records) == 2
    assert np.isfinite(h2.final_accuracy)
    # the resumed run really started from the checkpoint: its params
    # moved away from the saved state by training, but share the layout
    adapter = modelspec.get_adapter(cfg.model)
    f_saved = np.asarray(jax.vmap(adapter.flatten_one)(
        jax.tree.map(jnp.asarray, loaded)))
    f_new = np.asarray(jax.vmap(adapter.flatten_one)(h2.final_params))
    assert f_saved.shape == f_new.shape
    assert not np.allclose(f_saved, f_new)                # it trained
    # ...and from the checkpoint, not a fresh init: a fresh run over the
    # same cluster/batch streams lands on different round-0 metrics
    cluster2 = setup_experiment(cfg2, non_iid_p=0.4, num_samples=1200)[4]
    h_fresh = engine.run_dfl(train, tx, ty, shards, cluster2, cfg2,
                             make_strategy(cfg2, base), rounds=1)
    assert h2.records[0].accuracy != h_fresh.records[0].accuracy
