"""Byzantine-robust gossip: attack/robust spec parsing, the robust
aggregation primitives against a numpy oracle, the Pallas gather-sort-trim
kernel against its jnp oracle (ragged and padded neighborhoods included),
the engine guards and the robust x compress contract, the adversarial
differential matrix (fused lowering vs the reference engine across
strategies x churn x gossip representation x topology family), AD-PSGD
accept/reject screening (``robust="screen:<z>"``) in both the reference
event loop and the fused scan, and the end-to-end recovery story
(trimmed-mean gossip under sign-flip attackers recovers clean-run
accuracy while plain uniform mixing collapses).

Threat model (core/robust.py): attackers run honest local SGD but lie on
the wire — every transmitted copy of their row is corrupted — so the
defense must live in the aggregation rule, not in the local update.
"""
from __future__ import annotations

from dataclasses import replace

import jax.numpy as jnp
import numpy as np
import pytest
# hypothesis is optional (dev dependency): the guard skips only the
# property tests when it is absent, plain tests still run
from _hypothesis_compat import given, settings, st

from repro.configs.base import FedHPConfig
from repro.core import robust, topology as topo
from repro.core.experiment import run_algorithm
from repro.kernels.ref import robust_gossip_ref
from repro.kernels.robust_gossip import robust_gossip
from repro.simulation.cluster import ChurnEvent, ChurnSchedule

CFG = FedHPConfig(num_workers=8, rounds=10, tau_init=4, tau_max=20,
                  lr=0.1, batch_size=32, seed=3)

# joins, a crash and a straggler spike inside the differential horizon
SCHED = ChurnSchedule((
    ChurnEvent(2, "crash", 6),
    ChurnEvent(3, "straggle", 2, factor=5.0, duration=3),
    ChurnEvent(5, "join", 1),
))

# host-replayed fields must be bit-identical between the reference and
# fused engines; device metrics go through one fused XLA program so
# reductions re-associate (same contract as test_fused_equivalence.py)
EXACT = ("round", "round_time", "waiting_time", "mean_tau", "num_links",
         "cumulative_time", "staleness")
DEVICE_TOL = {"accuracy": 1e-5, "loss": 1e-4, "consensus": 1e-4}


def _assert_equivalent(h_ref, h_fus, device_tol=DEVICE_TOL):
    assert len(h_ref.records) == len(h_fus.records)
    a, b = h_ref.as_arrays(), h_fus.as_arrays()
    for k in EXACT:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    for k, tol in device_tol.items():
        np.testing.assert_allclose(a[k], b[k], rtol=tol, atol=tol,
                                   err_msg=k)


# ---------------------------------------------------------------------------
# spec parsing + masks
# ---------------------------------------------------------------------------

def test_parse_attack():
    assert robust.parse_attack("signflip") == ("signflip", 1.0)
    assert robust.parse_attack("signflip:2.5") == ("signflip", 2.5)
    assert robust.parse_attack("largenorm") == ("largenorm", 10.0)
    assert robust.parse_attack("largenorm:100") == ("largenorm", 100.0)
    with pytest.raises(ValueError):
        robust.parse_attack("gaussian")


def test_parse_robust():
    assert robust.parse_robust("none") == ("none", 0.0)
    assert robust.parse_robust("median") == ("median", 0.0)
    assert robust.parse_robust("trimmed:2") == ("trimmed", 2.0)
    assert robust.parse_robust("trimmed:0.25") == ("trimmed", 0.25)
    assert robust.parse_robust("screen:4") == ("screen", 4.0)
    assert robust.parse_robust("screen:2.5") == ("screen", 2.5)
    with pytest.raises(ValueError):
        robust.parse_robust("krum")
    with pytest.raises(ValueError):
        robust.parse_robust("trimmed:-1")
    with pytest.raises(ValueError):
        robust.parse_robust("screen:0")
    with pytest.raises(ValueError):
        robust.parse_robust("screen:-3")


def test_byzantine_mask_validates():
    m = robust.byzantine_mask((1, 3), 5)
    np.testing.assert_array_equal(m, [False, True, False, True, False])
    with pytest.raises(ValueError):
        robust.byzantine_mask((5,), 5)
    with pytest.raises(ValueError):
        robust.byzantine_mask((-1,), 5)


def test_apply_attack_corrupts_only_byzantine_rows():
    rng = np.random.default_rng(0)
    flat = jnp.asarray(rng.normal(size=(6, 7)).astype(np.float32))
    byz = jnp.asarray(robust.byzantine_mask((2, 4), 6))
    t = np.asarray(robust.apply_attack(flat, byz, 1.0, kind="signflip"))
    f = np.asarray(flat)
    np.testing.assert_allclose(t[[0, 1, 3, 5]], f[[0, 1, 3, 5]])
    np.testing.assert_allclose(t[[2, 4]], -f[[2, 4]])
    t = np.asarray(robust.apply_attack(flat, byz, 10.0, kind="largenorm"))
    np.testing.assert_allclose(t[[2, 4]], 10.0 * f[[2, 4]], rtol=1e-6)


# ---------------------------------------------------------------------------
# robust primitives vs a numpy oracle
# ---------------------------------------------------------------------------

def _oracle(flat, transmitted, adj, b, mode):
    """Per-coordinate trimmed-mean/median over each closed neighborhood
    multiset {x_i} u {T_j : j in N(i)}, plain python."""
    n, p = flat.shape
    out = flat.copy()
    for i in range(n):
        nbrs = np.nonzero(adj[i])[0]
        if nbrs.size == 0:
            continue
        vals = np.concatenate([flat[i:i + 1], transmitted[nbrs]], axis=0)
        cnt = vals.shape[0]
        sv = np.sort(vals, axis=0)
        if mode == "median":
            out[i] = (sv[(cnt - 1) // 2] + sv[cnt // 2]) / 2.0
        else:
            bi = int(b * cnt) if b < 1.0 else int(b)
            bi = min(bi, (cnt - 1) // 2)
            out[i] = sv[bi:cnt - bi].mean(axis=0)
    return out


@pytest.mark.parametrize("mode,b", [("trimmed", 1.0), ("trimmed", 2.0),
                                    ("trimmed", 0.25), ("median", 0.0)],
                         ids=["trim1", "trim2", "trim25pct", "median"])
def test_robust_dense_matches_oracle(mode, b):
    rng = np.random.default_rng(1)
    for trial in range(5):
        n = int(rng.integers(4, 12))
        adj = topo.barabasi_albert_topology(n, 2, rng) if n > 3 \
            else topo.full_topology(n)
        flat = rng.normal(size=(n, 5)).astype(np.float32)
        byz = robust.byzantine_mask(tuple(rng.choice(n, 2, replace=False)),
                                    n)
        transmitted = np.where(byz[:, None], -3.0 * flat, flat)
        nbr, deg = robust.neighbor_table(adj)
        got = robust.robust_gossip_dense(jnp.asarray(flat),
                                         jnp.asarray(transmitted),
                                         jnp.asarray(nbr),
                                         jnp.asarray(deg), b=b, mode=mode)
        want = _oracle(flat, transmitted, adj, b, mode)
        np.testing.assert_allclose(np.asarray(got), want, atol=2e-5,
                                   err_msg=f"trial {trial}")


@pytest.mark.parametrize("b", [1.0, 2.0, 0.25], ids=["b1", "b2", "b25pct"])
def test_trimmed_edges_matches_dense(b):
    """The segment-op trimmed mean (no dense [W, D_max] gather) must
    agree with the gathered dense form on the same graph."""
    rng = np.random.default_rng(2)
    for trial in range(5):
        n = int(rng.integers(5, 14))
        adj = topo.make_base_topology(n, "erdos:0.5", int(rng.integers(1e6)))
        flat = rng.normal(size=(n, 4)).astype(np.float32)
        byz = robust.byzantine_mask(tuple(rng.choice(n, 2, replace=False)),
                                    n)
        transmitted = np.where(byz[:, None], -5.0 * flat, flat)
        nbr, deg = robust.neighbor_table(adj)
        want = robust.robust_gossip_dense(jnp.asarray(flat),
                                          jnp.asarray(transmitted),
                                          jnp.asarray(nbr),
                                          jnp.asarray(deg), b=b,
                                          mode="trimmed")
        e = topo.edges_from_adj(adj)
        src, dst, _ = topo.directed_edges(e, np.zeros(len(e)))
        cnt = adj.sum(axis=1) + 1
        bi = np.minimum(np.floor(b * cnt) if b < 1.0
                        else np.full(n, b), (cnt - 1) // 2)
        got = robust.trimmed_mean_edges(
            jnp.asarray(flat), jnp.asarray(transmitted),
            jnp.asarray(src), jnp.asarray(dst), b=b, num_workers=n,
            b_max=max(int(bi.max()), 0))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, err_msg=f"trial {trial}")


def test_byz_plain_mixing_dense_matches_edges():
    """Plain (non-robust) gossip with a lying wire: the dense tensordot
    form and the segment_sum edge form agree."""
    rng = np.random.default_rng(3)
    for _ in range(5):
        n = int(rng.integers(4, 12))
        adj = topo.make_base_topology(n, "erdos:0.5", int(rng.integers(1e6)))
        flat = rng.normal(size=(n, 6)).astype(np.float32)
        byz = robust.byzantine_mask((0,), n)
        transmitted = np.where(byz[:, None], -flat, flat)
        mix = topo.mixing_matrix_uniform(adj)
        want = robust.gossip_byz_dense(jnp.asarray(flat),
                                       jnp.asarray(transmitted),
                                       jnp.asarray(mix))
        e = topo.edges_from_adj(adj)
        w = topo.edge_mixing_weights(e, n, "uniform")
        src, dst, ww = topo.directed_edges(e, w)
        got = robust.gossip_byz_edges(jnp.asarray(flat),
                                      jnp.asarray(transmitted),
                                      jnp.asarray(src), jnp.asarray(dst),
                                      jnp.asarray(ww))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)


def test_robust_no_neighbors_keeps_own_row():
    """A worker with zero live neighbors must keep its own (honest) row
    under every robust mode."""
    flat = np.arange(8, dtype=np.float32).reshape(2, 4)
    transmitted = -flat
    adj = np.zeros((2, 2), np.int8)
    nbr, deg = robust.neighbor_table(adj)
    for mode, b in (("trimmed", 1.0), ("median", 0.0)):
        got = robust.robust_gossip_dense(jnp.asarray(flat),
                                         jnp.asarray(transmitted),
                                         jnp.asarray(nbr),
                                         jnp.asarray(deg), b=b, mode=mode)
        np.testing.assert_allclose(np.asarray(got), flat, err_msg=mode)


def test_trimmed_mean_breaks_ties_once_per_side():
    """Duplicated extremes: each peel step removes exactly ONE attaining
    value per side (multiset semantics), not every tied copy."""
    n = 5
    adj = np.zeros((n, n), np.int8)
    adj[0, 1:] = adj[1:, 0] = 1
    flat = np.array([[0.0], [5.0], [5.0], [-5.0], [-5.0]], np.float32)
    transmitted = flat.copy()
    nbr, deg = robust.neighbor_table(adj)
    got = robust.robust_gossip_dense(jnp.asarray(flat),
                                     jnp.asarray(transmitted),
                                     jnp.asarray(nbr), jnp.asarray(deg),
                                     b=1.0, mode="trimmed")
    # worker 0's multiset {0, 5, 5, -5, -5}: trim one 5 and one -5,
    # mean of {0, 5, -5} = 0
    assert float(got[0, 0]) == pytest.approx(0.0, abs=1e-6)
    e = topo.edges_from_adj(adj)
    src, dst, _ = topo.directed_edges(e, np.zeros(len(e)))
    got_e = robust.trimmed_mean_edges(jnp.asarray(flat),
                                      jnp.asarray(transmitted),
                                      jnp.asarray(src), jnp.asarray(dst),
                                      b=1.0, num_workers=n, b_max=1)
    assert float(got_e[0, 0]) == pytest.approx(0.0, abs=1e-6)


# ---------------------------------------------------------------------------
# the Pallas gather-sort-trim kernel vs its jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,b", [("trimmed", 1.0), ("trimmed", 0.25),
                                    ("median", 0.0)],
                         ids=["trim1", "trim25pct", "median"])
@pytest.mark.parametrize("spec,n,c", [("erdos:0.5", 6, 37),
                                      ("ba:2", 13, 64),
                                      ("ws:4:0.3", 8, 300),
                                      ("geo:2", 9, 5)],
                         ids=["erdos", "ba", "ws", "geo"])
def test_robust_kernel_matches_oracle(spec, n, c, mode, b):
    """kernels/robust_gossip vs kernels/ref.robust_gossip_ref on ragged
    graphs whose W / C are NOT tile multiples — the padding rows and the
    +inf column sinks must be invisible."""
    rng = np.random.default_rng(n * 1000 + c + len(mode))
    adj = topo.make_base_topology(n, spec, int(rng.integers(1e6)))
    flat = rng.normal(size=(n, c)).astype(np.float32)
    byz = robust.byzantine_mask(tuple(rng.choice(n, 2, replace=False)), n)
    transmitted = np.where(byz[:, None], -3.0 * flat, flat)
    nbr, deg = robust.neighbor_table(adj)
    got = robust_gossip(jnp.asarray(flat), jnp.asarray(transmitted),
                        jnp.asarray(nbr), jnp.asarray(deg), b=b,
                        mode=mode, interpret=True)
    want = robust_gossip_ref(jnp.asarray(flat), jnp.asarray(transmitted),
                             jnp.asarray(nbr), jnp.asarray(deg), b=b,
                             mode=mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6)
    # and the oracle itself agrees with the plain-python neighborhood walk
    np.testing.assert_allclose(np.asarray(want),
                               _oracle(flat, transmitted, adj, b, mode),
                               atol=2e-5)


def test_robust_kernel_isolated_rows_exact():
    """Degree-0 workers (and the implicit row padding up to the tile
    multiple) keep their own row BIT-exactly through the kernel."""
    rng = np.random.default_rng(11)
    n, c = 6, 10                     # pads to 8 rows x 256-wide tile
    adj = np.zeros((n, n), np.int8)
    adj[0, 1] = adj[1, 0] = 1        # workers 2..5 are isolated
    flat = rng.normal(size=(n, c)).astype(np.float32)
    transmitted = -flat
    nbr, deg = robust.neighbor_table(adj)
    for mode, b in (("trimmed", 1.0), ("median", 0.0)):
        got = np.asarray(robust_gossip(
            jnp.asarray(flat), jnp.asarray(transmitted), jnp.asarray(nbr),
            jnp.asarray(deg), b=b, mode=mode, interpret=True))
        np.testing.assert_array_equal(got[2:], flat[2:], err_msg=mode)


# ---------------------------------------------------------------------------
# engine guards + the robust x compress contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fused", [False, True], ids=["ref", "fused"])
def test_engine_guards_raise(fused):
    # trimmed/median have no 2-sample pairwise form: AD-PSGD rejects them
    with pytest.raises(ValueError, match="screen:<z>"):
        run_algorithm("adpsgd", replace(CFG, robust="trimmed:1"),
                      rounds=3, fused=fused)
    with pytest.raises(ValueError, match="screen:<z>"):
        run_algorithm("adpsgd", replace(CFG, robust="median"),
                      rounds=3, fused=fused)
    # screen is the AD-PSGD rule: the synchronous engines reject it
    with pytest.raises(ValueError, match="accept/reject"):
        run_algorithm("dpsgd", replace(CFG, robust="screen:4"),
                      rounds=3, fused=fused)


@pytest.mark.parametrize("algo,fused,robust_spec",
                         [("dpsgd", False, "trimmed:1"),
                          ("dpsgd", True, "trimmed:1"),
                          ("adpsgd", False, "screen:4"),
                          ("adpsgd", True, "screen:4")],
                         ids=["sync-ref", "sync-fused",
                              "adpsgd-ref", "adpsgd-fused"])
def test_robust_compress_rejected_everywhere(algo, fused, robust_spec):
    """The contract: the Byzantine axis does not compose with compressed
    gossip (screening/trimming needs the raw payload) — every engine
    rejects loudly instead of silently screening decoded rows."""
    cfg = replace(CFG, byzantine=(1,), robust=robust_spec, compress="int8")
    with pytest.raises(ValueError, match="compress"):
        run_algorithm(algo, cfg, rounds=3, fused=fused)
    # byzantine alone (no defense) is still a lying wire: same contract
    cfg = replace(CFG, byzantine=(1,), compress="int8")
    with pytest.raises(ValueError, match="compress"):
        run_algorithm(algo, cfg, rounds=3, fused=fused)


def test_robust_sharded_rejected():
    cfg = replace(CFG, sharded=True, byzantine=(1,), robust="trimmed:1")
    with pytest.raises(ValueError, match="sharded"):
        run_algorithm("dpsgd", cfg, rounds=3, fused=True)


# ---------------------------------------------------------------------------
# the adversarial differential matrix: fused lowering vs the reference
# ---------------------------------------------------------------------------

def _pair(algo, cfg, churn=None, rounds=10):
    h_ref = run_algorithm(algo, cfg, non_iid_p=0.4, rounds=rounds,
                          churn=churn)
    h_fus = run_algorithm(algo, cfg, non_iid_p=0.4, rounds=rounds,
                          churn=churn, fused=True)
    return h_ref, h_fus


def test_fused_robust_matches_reference_smoke():
    """Fast gate (CI default lane): the lowered trimmed-mean mix — not a
    delegation — reproduces the reference engine on the small shape."""
    cfg = replace(CFG, byzantine=(2,), robust="trimmed:1")
    _assert_equivalent(*_pair("dpsgd", cfg, rounds=5))


def test_fused_byz_plain_matches_reference_smoke():
    """Lying wire with NO defense, fused vs reference (fast lane)."""
    cfg = replace(CFG, byzantine=(2,), byzantine_attack="signflip:1.0")
    _assert_equivalent(*_pair("dpsgd", cfg, rounds=5))


@pytest.mark.slow
@pytest.mark.parametrize("robust_spec", ["trimmed:1", "median", "none"],
                         ids=["trimmed", "median", "plain"])
@pytest.mark.parametrize("algo", ["dpsgd", "ldsgd", "fedhp"])
def test_fused_robust_matrix_strategies_churn(algo, robust_spec):
    """strategies x robust mode, all under churn: crashes shrink the
    trim windows round to round, joins re-enter the neighbor tables."""
    cfg = replace(CFG, byzantine=(1, 4), robust=robust_spec)
    _assert_equivalent(*_pair(algo, cfg, churn=SCHED))


@pytest.mark.slow
@pytest.mark.parametrize("robust_spec", ["trimmed:1", "median"],
                         ids=["trimmed", "median"])
def test_fused_robust_matrix_sparse(robust_spec):
    """Edge-list gossip representation: the reference routes trimming
    through the segment-op form, the fused scan through the gathered
    kernel window — same answer."""
    cfg = replace(CFG, byzantine=(1, 5), robust=robust_spec,
                  gossip="sparse")
    _assert_equivalent(*_pair("dpsgd", cfg))


@pytest.mark.slow
@pytest.mark.parametrize("spec", ["ba:2", "ws:4:0.3", "geo:2"],
                         ids=["ba", "ws", "geo"])
def test_fused_robust_matrix_topologies(spec):
    """Complex-network families: heterogeneous degrees mean per-worker
    trim counts and ragged padded neighbor tables inside the scan."""
    cfg = replace(CFG, base_topology=spec, byzantine=(1, 5),
                  robust="trimmed:1")
    _assert_equivalent(*_pair("dpsgd", cfg))
    _assert_equivalent(*_pair("dpsgd", replace(cfg, gossip="sparse")))


def test_no_byzantine_config_is_noop():
    """byzantine=() + robust="none" must reproduce the pre-robust engine
    bit-for-bit (the differential suites depend on it)."""
    h_a = run_algorithm("dpsgd", CFG, non_iid_p=0.4, rounds=5)
    h_b = run_algorithm("dpsgd", replace(CFG, byzantine=(),
                                         robust="none"),
                        non_iid_p=0.4, rounds=5)
    a, b = h_a.as_arrays(), h_b.as_arrays()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


# ---------------------------------------------------------------------------
# AD-PSGD screening (robust="screen:<z>")
# ---------------------------------------------------------------------------

def test_adpsgd_screen_honest_is_plain():
    """With every worker honest, screening is invisible: record streams
    bit-identical to the unscreened run and zero rejections — in BOTH
    the reference event loop and the fused scan (fast lane)."""
    scfg = replace(CFG, robust="screen:8.0")
    for fused in (False, True):
        h_plain = run_algorithm("adpsgd", CFG, non_iid_p=0.4, rounds=6,
                                fused=fused)
        h_scr = run_algorithm("adpsgd", scfg, non_iid_p=0.4, rounds=6,
                              fused=fused)
        a, b = h_plain.as_arrays(), h_scr.as_arrays()
        for k in a:
            np.testing.assert_array_equal(a[k], b[k],
                                          err_msg=f"{k} fused={fused}")
        assert h_scr.screen_rejects == [0] * 6
        assert h_plain.screen_rejects is None


def test_adpsgd_screen_fused_matches_reference():
    """Under attack the fused scan makes the SAME accept/reject decisions
    as the reference loop: identical per-round reject counts, identical
    host fields, device metrics within tolerance (fast lane)."""
    cfg = replace(CFG, robust="screen:8.0", byzantine=(0, 5),
                  byzantine_attack="signflip:1.0")
    h_ref, h_fus = _pair("adpsgd", cfg, rounds=8)
    _assert_equivalent(h_ref, h_fus)
    assert h_ref.screen_rejects == h_fus.screen_rejects
    assert sum(h_ref.screen_rejects) > 0


@pytest.mark.slow
def test_adpsgd_byz_no_screen_fused_matches_reference():
    """The undefended lying wire is its own differential cell."""
    cfg = replace(CFG, byzantine=(2,), byzantine_attack="signflip:1.0")
    _assert_equivalent(*_pair("adpsgd", cfg))


@pytest.mark.slow
def test_adpsgd_screen_rejections_grow_with_attack_scale():
    """End-to-end monotonicity: scaling the sign-flip attack up pushes
    payloads further from the victim's model, so the screen fires at
    least as often (widely separated scales keep the coupled-trajectory
    comparison stable)."""
    totals = []
    for s in (0.5, 2.0, 8.0):
        cfg = replace(CFG, robust="screen:8.0", byzantine=(0, 5),
                      byzantine_attack=f"signflip:{s}")
        h = run_algorithm("adpsgd", cfg, non_iid_p=0.4, rounds=8)
        totals.append(sum(h.screen_rejects))
    assert totals[0] <= totals[1] <= totals[2], totals


@pytest.mark.slow
def test_adpsgd_screen_recovers_under_signflip():
    """The AD-PSGD headline: 2/10 sign-flip attackers collapse the plain
    pairwise exchange, screening recovers >= 85% of clean accuracy (the
    scenarios benchmark gates the same separation)."""
    cfg = replace(CFG, num_workers=10)
    rounds = 20
    clean = run_algorithm("adpsgd", cfg, non_iid_p=0.4,
                          rounds=rounds).final_accuracy
    byz = replace(cfg, byzantine=(3, 7), byzantine_attack="signflip:1.0")
    plain = run_algorithm("adpsgd", byz, non_iid_p=0.4,
                          rounds=rounds).final_accuracy
    scr = run_algorithm("adpsgd", replace(byz, robust="screen:8.0"),
                        non_iid_p=0.4, rounds=rounds).final_accuracy
    assert scr >= 0.85 * clean, (scr, clean)
    assert clean - plain >= 0.05, (clean, plain)


# ---------------------------------------------------------------------------
# property tests (hypothesis; skipped when the dev dep is absent)
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(vals=st.lists(st.floats(-100.0, 100.0, allow_nan=False, width=32),
                     min_size=1, max_size=12),
       b=st.integers(0, 6))
def test_trimmed_mean_property_vs_numpy(vals, b):
    """Arbitrary 1-d multisets through a star graph: the oracle's trimmed
    mean is numpy sort-and-slice with the trim clamped below half the
    closed neighborhood."""
    n = len(vals)
    adj = np.zeros((n, n), np.int8)
    adj[0, 1:] = adj[1:, 0] = 1            # worker 0 sees the whole multiset
    x = np.asarray(vals, np.float32)[:, None]
    nbr, deg = robust.neighbor_table(adj) if n > 1 else \
        (np.zeros((1, 1), np.int32), np.zeros(1, np.int32))
    got = robust_gossip_ref(jnp.asarray(x), jnp.asarray(x),
                            jnp.asarray(nbr), jnp.asarray(deg),
                            b=float(b), mode="trimmed")
    kern = robust_gossip(jnp.asarray(x), jnp.asarray(x),
                         jnp.asarray(nbr), jnp.asarray(deg),
                         b=float(b), mode="trimmed", interpret=True)
    bi = min(b, (n - 1) // 2)
    want = np.sort(np.asarray(vals))[bi:n - bi].mean() if n > 1 else vals[0]
    np.testing.assert_allclose(float(got[0, 0]), want, rtol=1e-4,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(got),
                               atol=1e-5)


@settings(max_examples=100, deadline=None)
@given(data=st.lists(st.floats(-10.0, 10.0, allow_nan=False, width=32),
                     min_size=8, max_size=8),
       s1=st.floats(0.1, 4.0), factor=st.floats(1.0, 8.0),
       h=st.floats(0.01, 10.0), z=st.floats(0.5, 16.0))
def test_screen_reject_monotone_in_scale_property(data, s1, factor, h, z):
    """Per-decision monotonicity of the screen under sign-flip: once the
    EMA is seeded, if the screen accepts the LARGER-scale payload it must
    accept the smaller one (payloads aligned against the victim drift
    monotonically away as the scale grows)."""
    x_self = jnp.asarray(data[:4], jnp.float32)
    x_peer = jnp.asarray(data[4:], jnp.float32)
    if float(jnp.vdot(x_peer, x_self)) < 0:
        x_peer = -x_peer               # relabel: keep the aligned branch
    s2 = s1 * factor
    hh = jnp.float32(h)
    acc_big = bool(robust.screen_accept(x_self, -s2 * x_peer, hh, z))
    acc_small = bool(robust.screen_accept(x_self, -s1 * x_peer, hh, z))
    if acc_big:
        assert acc_small


@settings(max_examples=50, deadline=None)
@given(norms=st.lists(st.floats(0.0, 100.0, allow_nan=False, width=32),
                      min_size=1, max_size=20))
def test_screen_fold_stays_in_hull(norms):
    """The own-delta-norm EMA never leaves the hull of what it saw: an
    attacker cannot inflate a victim's threshold (it only folds the
    victim's OWN deltas)."""
    h = jnp.float32(0.0)
    for nd in norms:
        h = robust.screen_fold(h, jnp.float32(nd))
        assert float(h) <= max(norms) + 1e-4
        assert float(h) >= 0.0


@pytest.mark.slow
@settings(max_examples=3, deadline=None)
@given(z=st.floats(8.0, 32.0), seed=st.integers(0, 10))
def test_screen_accepts_all_honest_property(z, seed):
    """Any reasonable threshold, any seed: an all-honest fleet is never
    screened — the run is bit-identical to plain AD-PSGD."""
    cfg = replace(CFG, num_workers=6, seed=seed)
    h_plain = run_algorithm("adpsgd", cfg, non_iid_p=0.4, rounds=4)
    h_scr = run_algorithm("adpsgd", replace(cfg, robust=f"screen:{z}"),
                          non_iid_p=0.4, rounds=4)
    a, b = h_plain.as_arrays(), h_scr.as_arrays()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    assert sum(h_scr.screen_rejects) == 0


# ---------------------------------------------------------------------------
# end-to-end recovery (synchronous engines)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_trimmed_mean_recovers_under_signflip():
    """The headline property: 2/10 sign-flip attackers collapse plain
    uniform mixing, trimmed-mean gossip recovers >= 90% of clean
    accuracy (the scenarios benchmark gates the same separation)."""
    cfg = replace(CFG, num_workers=10, byzantine_attack="signflip")
    rounds = 25
    clean = run_algorithm("dpsgd", replace(cfg, byzantine=()),
                          non_iid_p=0.4, rounds=rounds).final_accuracy
    byz = (3, 7)
    plain = run_algorithm("dpsgd", replace(cfg, byzantine=byz),
                          non_iid_p=0.4, rounds=rounds).final_accuracy
    trimmed = run_algorithm(
        "dpsgd", replace(cfg, byzantine=byz, robust="trimmed:2"),
        non_iid_p=0.4, rounds=rounds).final_accuracy
    assert trimmed >= 0.9 * clean, (trimmed, clean)
    assert clean - plain >= 0.05, (clean, plain)


@pytest.mark.slow
def test_trimmed_mean_fused_recovers_under_signflip():
    """Same separation through the LOWERED path: the fused scan's kernel
    mix defends as well as the reference it mirrors."""
    cfg = replace(CFG, num_workers=10, byzantine_attack="signflip")
    rounds = 25
    clean = run_algorithm("dpsgd", replace(cfg, byzantine=()),
                          non_iid_p=0.4, rounds=rounds,
                          fused=True).final_accuracy
    trimmed = run_algorithm(
        "dpsgd", replace(cfg, byzantine=(3, 7), robust="trimmed:2"),
        non_iid_p=0.4, rounds=rounds, fused=True).final_accuracy
    assert trimmed >= 0.9 * clean, (trimmed, clean)


@pytest.mark.slow
def test_median_recovers_under_largenorm():
    cfg = replace(CFG, num_workers=10, byzantine=(0, 6),
                  byzantine_attack="largenorm:10", robust="median")
    h = run_algorithm("dpsgd", cfg, non_iid_p=0.4, rounds=25)
    clean = run_algorithm(
        "dpsgd", replace(cfg, byzantine=(), robust="none"),
        non_iid_p=0.4, rounds=25).final_accuracy
    assert h.final_accuracy >= 0.9 * clean
