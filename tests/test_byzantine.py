"""Byzantine-robust gossip: attack/robust spec parsing, the robust
aggregation primitives against a numpy oracle, dense-vs-edge-list parity,
the engine guards, and the end-to-end recovery story (trimmed-mean gossip
under sign-flip attackers recovers clean-run accuracy while plain uniform
mixing collapses).

Threat model (core/robust.py): attackers run honest local SGD but lie on
the wire — every transmitted copy of their row is corrupted — so the
defense must live in the aggregation rule, not in the local update.
"""
from __future__ import annotations

from dataclasses import replace

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedHPConfig
from repro.core import robust, topology as topo
from repro.core.experiment import run_algorithm

CFG = FedHPConfig(num_workers=8, rounds=10, tau_init=4, tau_max=20,
                  lr=0.1, batch_size=32, seed=3)


# ---------------------------------------------------------------------------
# spec parsing + masks
# ---------------------------------------------------------------------------

def test_parse_attack():
    assert robust.parse_attack("signflip") == ("signflip", 1.0)
    assert robust.parse_attack("signflip:2.5") == ("signflip", 2.5)
    assert robust.parse_attack("largenorm") == ("largenorm", 10.0)
    assert robust.parse_attack("largenorm:100") == ("largenorm", 100.0)
    with pytest.raises(ValueError):
        robust.parse_attack("gaussian")


def test_parse_robust():
    assert robust.parse_robust("none") == ("none", 0.0)
    assert robust.parse_robust("median") == ("median", 0.0)
    assert robust.parse_robust("trimmed:2") == ("trimmed", 2.0)
    assert robust.parse_robust("trimmed:0.25") == ("trimmed", 0.25)
    with pytest.raises(ValueError):
        robust.parse_robust("krum")
    with pytest.raises(ValueError):
        robust.parse_robust("trimmed:-1")


def test_byzantine_mask_validates():
    m = robust.byzantine_mask((1, 3), 5)
    np.testing.assert_array_equal(m, [False, True, False, True, False])
    with pytest.raises(ValueError):
        robust.byzantine_mask((5,), 5)
    with pytest.raises(ValueError):
        robust.byzantine_mask((-1,), 5)


def test_apply_attack_corrupts_only_byzantine_rows():
    rng = np.random.default_rng(0)
    flat = jnp.asarray(rng.normal(size=(6, 7)).astype(np.float32))
    byz = jnp.asarray(robust.byzantine_mask((2, 4), 6))
    t = np.asarray(robust.apply_attack(flat, byz, 1.0, kind="signflip"))
    f = np.asarray(flat)
    np.testing.assert_allclose(t[[0, 1, 3, 5]], f[[0, 1, 3, 5]])
    np.testing.assert_allclose(t[[2, 4]], -f[[2, 4]])
    t = np.asarray(robust.apply_attack(flat, byz, 10.0, kind="largenorm"))
    np.testing.assert_allclose(t[[2, 4]], 10.0 * f[[2, 4]], rtol=1e-6)


# ---------------------------------------------------------------------------
# robust primitives vs a numpy oracle
# ---------------------------------------------------------------------------

def _oracle(flat, transmitted, adj, b, mode):
    """Per-coordinate trimmed-mean/median over each closed neighborhood
    multiset {x_i} u {T_j : j in N(i)}, plain python."""
    n, p = flat.shape
    out = flat.copy()
    for i in range(n):
        nbrs = np.nonzero(adj[i])[0]
        if nbrs.size == 0:
            continue
        vals = np.concatenate([flat[i:i + 1], transmitted[nbrs]], axis=0)
        cnt = vals.shape[0]
        sv = np.sort(vals, axis=0)
        if mode == "median":
            out[i] = (sv[(cnt - 1) // 2] + sv[cnt // 2]) / 2.0
        else:
            bi = int(b * cnt) if b < 1.0 else int(b)
            bi = min(bi, (cnt - 1) // 2)
            out[i] = sv[bi:cnt - bi].mean(axis=0)
    return out


@pytest.mark.parametrize("mode,b", [("trimmed", 1.0), ("trimmed", 2.0),
                                    ("trimmed", 0.25), ("median", 0.0)],
                         ids=["trim1", "trim2", "trim25pct", "median"])
def test_robust_dense_matches_oracle(mode, b):
    rng = np.random.default_rng(1)
    for trial in range(5):
        n = int(rng.integers(4, 12))
        adj = topo.barabasi_albert_topology(n, 2, rng) if n > 3 \
            else topo.full_topology(n)
        flat = rng.normal(size=(n, 5)).astype(np.float32)
        byz = robust.byzantine_mask(tuple(rng.choice(n, 2, replace=False)),
                                    n)
        transmitted = np.where(byz[:, None], -3.0 * flat, flat)
        nbr, deg = robust.neighbor_table(adj)
        got = robust.robust_gossip_dense(jnp.asarray(flat),
                                         jnp.asarray(transmitted),
                                         jnp.asarray(nbr),
                                         jnp.asarray(deg), b=b, mode=mode)
        want = _oracle(flat, transmitted, adj, b, mode)
        np.testing.assert_allclose(np.asarray(got), want, atol=2e-5,
                                   err_msg=f"trial {trial}")


@pytest.mark.parametrize("b", [1.0, 2.0, 0.25], ids=["b1", "b2", "b25pct"])
def test_trimmed_edges_matches_dense(b):
    """The segment-op trimmed mean (no dense [W, D_max] gather) must
    agree with the gathered dense form on the same graph."""
    rng = np.random.default_rng(2)
    for trial in range(5):
        n = int(rng.integers(5, 14))
        adj = topo.make_base_topology(n, "erdos:0.5", int(rng.integers(1e6)))
        flat = rng.normal(size=(n, 4)).astype(np.float32)
        byz = robust.byzantine_mask(tuple(rng.choice(n, 2, replace=False)),
                                    n)
        transmitted = np.where(byz[:, None], -5.0 * flat, flat)
        nbr, deg = robust.neighbor_table(adj)
        want = robust.robust_gossip_dense(jnp.asarray(flat),
                                          jnp.asarray(transmitted),
                                          jnp.asarray(nbr),
                                          jnp.asarray(deg), b=b,
                                          mode="trimmed")
        e = topo.edges_from_adj(adj)
        src, dst, _ = topo.directed_edges(e, np.zeros(len(e)))
        cnt = adj.sum(axis=1) + 1
        bi = np.minimum(np.floor(b * cnt) if b < 1.0
                        else np.full(n, b), (cnt - 1) // 2)
        got = robust.trimmed_mean_edges(
            jnp.asarray(flat), jnp.asarray(transmitted),
            jnp.asarray(src), jnp.asarray(dst), b=b, num_workers=n,
            b_max=max(int(bi.max()), 0))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, err_msg=f"trial {trial}")


def test_byz_plain_mixing_dense_matches_edges():
    """Plain (non-robust) gossip with a lying wire: the dense tensordot
    form and the segment_sum edge form agree."""
    rng = np.random.default_rng(3)
    for _ in range(5):
        n = int(rng.integers(4, 12))
        adj = topo.make_base_topology(n, "erdos:0.5", int(rng.integers(1e6)))
        flat = rng.normal(size=(n, 6)).astype(np.float32)
        byz = robust.byzantine_mask((0,), n)
        transmitted = np.where(byz[:, None], -flat, flat)
        mix = topo.mixing_matrix_uniform(adj)
        want = robust.gossip_byz_dense(jnp.asarray(flat),
                                       jnp.asarray(transmitted),
                                       jnp.asarray(mix))
        e = topo.edges_from_adj(adj)
        w = topo.edge_mixing_weights(e, n, "uniform")
        src, dst, ww = topo.directed_edges(e, w)
        got = robust.gossip_byz_edges(jnp.asarray(flat),
                                      jnp.asarray(transmitted),
                                      jnp.asarray(src), jnp.asarray(dst),
                                      jnp.asarray(ww))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)


def test_robust_no_neighbors_keeps_own_row():
    """A worker with zero live neighbors must keep its own (honest) row
    under every robust mode."""
    flat = np.arange(8, dtype=np.float32).reshape(2, 4)
    transmitted = -flat
    adj = np.zeros((2, 2), np.int8)
    nbr, deg = robust.neighbor_table(adj)
    for mode, b in (("trimmed", 1.0), ("median", 0.0)):
        got = robust.robust_gossip_dense(jnp.asarray(flat),
                                         jnp.asarray(transmitted),
                                         jnp.asarray(nbr),
                                         jnp.asarray(deg), b=b, mode=mode)
        np.testing.assert_allclose(np.asarray(got), flat, err_msg=mode)


def test_trimmed_mean_breaks_ties_once_per_side():
    """Duplicated extremes: each peel step removes exactly ONE attaining
    value per side (multiset semantics), not every tied copy."""
    flat = np.array([[1.0]], np.float32)          # worker 0, 4 neighbors
    n = 5
    adj = np.zeros((n, n), np.int8)
    adj[0, 1:] = adj[1:, 0] = 1
    flat = np.array([[0.0], [5.0], [5.0], [-5.0], [-5.0]], np.float32)
    transmitted = flat.copy()
    nbr, deg = robust.neighbor_table(adj)
    got = robust.robust_gossip_dense(jnp.asarray(flat),
                                     jnp.asarray(transmitted),
                                     jnp.asarray(nbr), jnp.asarray(deg),
                                     b=1.0, mode="trimmed")
    # worker 0's multiset {0, 5, 5, -5, -5}: trim one 5 and one -5,
    # mean of {0, 5, -5} = 0
    assert float(got[0, 0]) == pytest.approx(0.0, abs=1e-6)
    e = topo.edges_from_adj(adj)
    src, dst, _ = topo.directed_edges(e, np.zeros(len(e)))
    got_e = robust.trimmed_mean_edges(jnp.asarray(flat),
                                      jnp.asarray(transmitted),
                                      jnp.asarray(src), jnp.asarray(dst),
                                      b=1.0, num_workers=n, b_max=1)
    assert float(got_e[0, 0]) == pytest.approx(0.0, abs=1e-6)


# ---------------------------------------------------------------------------
# engine integration: guards, delegation, recovery
# ---------------------------------------------------------------------------

def test_engine_guards_raise():
    byz_cfg = replace(CFG, byzantine=(1,))
    with pytest.raises(ValueError, match="synchronous-engine only"):
        run_algorithm("adpsgd", byz_cfg, rounds=3)
    with pytest.raises(ValueError, match="compress"):
        run_algorithm("dpsgd", replace(byz_cfg, compress="int8"), rounds=3)
    with pytest.raises(ValueError):
        run_algorithm("dpsgd", byz_cfg, rounds=3, fused=True,
                      seeds=jnp.asarray((1, 2)))


def test_fused_delegates_to_reference():
    """cfg.byzantine / cfg.robust route run_dfl_fused through the
    reference engine — trajectories must be identical, not just close."""
    cfg = replace(CFG, byzantine=(2,), robust="trimmed:1")
    h_ref = run_algorithm("dpsgd", cfg, non_iid_p=0.4, rounds=5)
    h_fus = run_algorithm("dpsgd", cfg, non_iid_p=0.4, rounds=5,
                          fused=True)
    a, b = h_ref.as_arrays(), h_fus.as_arrays()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_robust_sparse_engine_matches_dense():
    """trimmed-mean gossip through the edge-list engine vs the dense
    engine: host fields exact, device metrics within tolerance."""
    cfg = replace(CFG, byzantine=(1, 5), robust="trimmed:2")
    h_d = run_algorithm("dpsgd", cfg, non_iid_p=0.4, rounds=6)
    h_s = run_algorithm("dpsgd", replace(cfg, gossip="sparse"),
                        non_iid_p=0.4, rounds=6)
    a, b = h_d.as_arrays(), h_s.as_arrays()
    for k in ("round", "round_time", "waiting_time", "mean_tau",
              "num_links", "cumulative_time"):
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    for k, tol in (("accuracy", 1e-5), ("loss", 1e-4), ("consensus", 1e-4)):
        np.testing.assert_allclose(a[k], b[k], rtol=tol, atol=tol,
                                   err_msg=k)


def test_no_byzantine_config_is_noop():
    """byzantine=() + robust="none" must reproduce the pre-robust engine
    bit-for-bit (the differential suites depend on it)."""
    h_a = run_algorithm("dpsgd", CFG, non_iid_p=0.4, rounds=5)
    h_b = run_algorithm("dpsgd", replace(CFG, byzantine=(),
                                         robust="none"),
                        non_iid_p=0.4, rounds=5)
    a, b = h_a.as_arrays(), h_b.as_arrays()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


@pytest.mark.slow
def test_trimmed_mean_recovers_under_signflip():
    """The headline property: 2/10 sign-flip attackers collapse plain
    uniform mixing, trimmed-mean gossip recovers >= 90% of clean
    accuracy (the scenarios benchmark gates the same separation)."""
    cfg = replace(CFG, num_workers=10, byzantine_attack="signflip")
    rounds = 25
    clean = run_algorithm("dpsgd", replace(cfg, byzantine=()),
                          non_iid_p=0.4, rounds=rounds).final_accuracy
    byz = (3, 7)
    plain = run_algorithm("dpsgd", replace(cfg, byzantine=byz),
                          non_iid_p=0.4, rounds=rounds).final_accuracy
    trimmed = run_algorithm(
        "dpsgd", replace(cfg, byzantine=byz, robust="trimmed:2"),
        non_iid_p=0.4, rounds=rounds).final_accuracy
    assert trimmed >= 0.9 * clean, (trimmed, clean)
    assert clean - plain >= 0.05, (clean, plain)


@pytest.mark.slow
def test_median_recovers_under_largenorm():
    cfg = replace(CFG, num_workers=10, byzantine=(0, 6),
                  byzantine_attack="largenorm:10", robust="median")
    h = run_algorithm("dpsgd", cfg, non_iid_p=0.4, rounds=25)
    clean = run_algorithm(
        "dpsgd", replace(cfg, byzantine=(), robust="none"),
        non_iid_p=0.4, rounds=25).final_accuracy
    assert h.final_accuracy >= 0.9 * clean
