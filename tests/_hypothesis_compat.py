"""Degrade-don't-error guard for the hypothesis property tests.

``hypothesis`` is a dev-only dependency (requirements-dev.txt). With it
installed this module is a pure re-export. Without it, importing modules
still collect and their plain tests still run: each ``@given`` test body
is replaced by ``pytest.importorskip("hypothesis")``, so only the
property tests report as skipped instead of the whole module erroring at
collection time.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert placeholder accepted anywhere a SearchStrategy is; every
        operation (call, attribute, map/filter chain) returns itself. Only
        ever constructed at decoration time — the guarded test never runs."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _StrategiesModule:
        def __getattr__(self, name):
            return _Strategy()

    st = _StrategiesModule()

    def given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg replacement: the original signature's hypothesis-
            # injected parameters must not look like pytest fixtures
            def skipper():
                pytest.importorskip(
                    "hypothesis",
                    reason="property test needs hypothesis "
                           "(pip install -r requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*args, **_kwargs):
        if args and callable(args[0]):                 # bare @settings
            return args[0]

        def deco(fn):
            return fn
        return deco
