"""Pallas kernel validation (interpret=True on CPU) against ref.py oracles:
fixed-shape allclose + hypothesis sweeps over shapes/dtypes (deliverable c).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
# hypothesis is optional (dev dependency): the guard skips only the
# property tests when it is absent, plain tests still run
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.consensus_dist import consensus_dist_2d
from repro.kernels.gossip_mix import gossip_mix_2d
from repro.kernels.quantize_block import (BLOCK_COLS, BLOCK_ROWS,
                                          dequantize_block_2d,
                                          quantize_block_2d)

KEY = jax.random.PRNGKey(42)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                           (False, 0)])
def test_flash_attention_vs_ref(dtype, causal, window):
    b, hq, hkv, s, hd = 2, 4, 2, 256, 64
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (b, hq, s, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (b, hkv, s, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (b, hkv, s, hd), jnp.float32).astype(dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              block_q=128, block_k=128, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=8, deadline=None)
@given(
    s_blocks=st.integers(1, 3),
    hq_groups=st.sampled_from([(2, 1), (4, 2), (8, 2)]),
    hd=st.sampled_from([64, 128]),
    causal=st.booleans(),
)
def test_flash_attention_hypothesis(s_blocks, hq_groups, hd, causal):
    hq, hkv = hq_groups
    s = 128 * s_blocks
    q = jax.random.normal(KEY, (1, hq, s, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, hkv, s, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, hkv, s, hd))
    out = flash_attention_fwd(q, k, v, causal=causal, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_ops_wrapper_padding():
    """ops.flash_attention pads ragged seq lens and matches the model-layout
    reference used by the transformer stack."""
    from repro.models import layers as L
    b, s, hq, hkv, hd = 1, 100, 4, 2, 64       # s=100: needs padding
    q = jax.random.normal(KEY, (b, s, hq, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, hkv, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (b, s, hkv, hd))
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    mask = L.gqa_scores_mask(s, s, causal=True, window=0)
    want = L.gqa_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# gossip mix
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(k=st.integers(1, 6), rows=st.integers(1, 3),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_gossip_mix_hypothesis(k, rows, dtype):
    r, c = 8 * rows, 1024
    x = jax.random.normal(KEY, (r, c), jnp.float32).astype(dtype)
    u = jax.random.normal(jax.random.fold_in(KEY, k), (k, r, c),
                          jnp.float32).astype(dtype)
    w = jax.random.uniform(jax.random.fold_in(KEY, 9), (k,),
                           minval=0.0, maxval=1.0 / (k + 1))
    out = gossip_mix_2d(x, u, w, interpret=True)
    want = ref.gossip_mix_ref(x, u, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


def test_gossip_mix_flat_wrapper():
    n = 5000                                  # ragged -> padding path
    x = jax.random.normal(KEY, (n,))
    u = jax.random.normal(jax.random.fold_in(KEY, 1), (3, n))
    w = jnp.array([0.2, 0.3, 0.1])
    out = ops.gossip_mix(x, u, w, interpret=True)
    want = x + jnp.tensordot(w, u - x[None], axes=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_gossip_mix_preserves_average():
    """Doubly-stochastic mixing preserves the network average (the DFL
    invariant behind Eq. 5)."""
    n = ops.TILE
    x0 = jax.random.normal(KEY, (n,))
    x1 = jax.random.normal(jax.random.fold_in(KEY, 1), (n,))
    w = jnp.array([0.5])
    y0 = ops.gossip_mix(x0, x1[None], w, interpret=True)
    y1 = ops.gossip_mix(x1, x0[None], w, interpret=True)
    np.testing.assert_allclose(np.asarray(y0 + y1), np.asarray(x0 + x1),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# consensus distance
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(k=st.integers(1, 5), rows=st.integers(1, 3))
def test_consensus_dist_hypothesis(k, rows):
    r, c = 8 * rows, 1024
    x = jax.random.normal(KEY, (r, c))
    u = jax.random.normal(jax.random.fold_in(KEY, k + 7), (k, r, c))
    out = consensus_dist_2d(x, u, interpret=True)
    want = ref.consensus_dist_ref(x, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4)


def test_consensus_dist_flat_matches_norm():
    n = 3000
    x = jax.random.normal(KEY, (n,))
    u = jax.random.normal(jax.random.fold_in(KEY, 5), (2, n))
    out = ops.consensus_dist(x, u, interpret=True)
    want = jnp.linalg.norm(u - x[None], axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4)


# ---------------------------------------------------------------------------
# int8 block quantize
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(rows=st.integers(1, 4), scale=st.floats(1e-3, 1e3))
def test_quantize_roundtrip_hypothesis(rows, scale):
    r, c = BLOCK_ROWS * rows, BLOCK_COLS
    x = jax.random.normal(KEY, (r, c)) * scale
    q, s = quantize_block_2d(x, interpret=True)
    qr, sr = ref.quantize_block_ref(x, BLOCK_ROWS, BLOCK_COLS)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s).ravel(),
                               np.asarray(sr).ravel(), rtol=1e-6)
    # round trip error bounded by scale/2 per element
    y = dequantize_block_2d(q, s, interpret=True)
    err = np.abs(np.asarray(y) - np.asarray(x))
    bound = np.repeat(np.repeat(np.asarray(s), BLOCK_ROWS, 0),
                      BLOCK_COLS, 1) * 0.5 + 1e-6
    assert (err <= bound).all()


def test_quantize_flat_roundtrip():
    n = 10_000
    x = jax.random.normal(KEY, (n,)) * 3.0
    q, s, n_out = ops.quantize(x, interpret=True)
    y = ops.dequantize(q, s, n, interpret=True)
    assert y.shape == x.shape
    # max error = half an int8 step of the per-tile scale
    assert float(jnp.max(jnp.abs(y - x))) <= float(jnp.max(s)) * 0.51


# ---------------------------------------------------------------------------
# padding shim + dense-gossip parity (the fused engine's hot path)
# ---------------------------------------------------------------------------

def _mixing_rows(n_workers: int, k: int, seed: int) -> np.ndarray:
    """Row-stochastic mixing matrix where each worker has ~k neighbors."""
    rng = np.random.default_rng(seed)
    w = np.zeros((n_workers, n_workers))
    for i in range(n_workers):
        nbrs = rng.choice([j for j in range(n_workers) if j != i],
                          size=min(k, n_workers - 1), replace=False)
        w[i, nbrs] = 1.0 / (n_workers + 1)
    np.fill_diagonal(w, 1.0 - w.sum(1))
    return w


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("k", [1, 2, 4])
@pytest.mark.parametrize("p", [1024 * 8, 5000, 1000])  # aligned + ragged
def test_gossip_kernel_matches_dense_gossip(dtype, k, p):
    """Per-worker gossip_mix_2d over mixing-matrix rows == the reference
    engine's dense ``_gossip`` (tensordot) on the stacked parameters.
    Non-tile-multiple p exercises the kernel's padding shim."""
    from repro.core.engine import _gossip
    n_workers = 6
    mix = _mixing_rows(n_workers, k, seed=p + k)
    x = jax.random.normal(KEY, (n_workers, p), jnp.float32).astype(dtype)

    want = _gossip({"w": x}, jnp.asarray(mix, jnp.float32))["w"]

    cols = min(1024, p)
    rows = -(-p // cols)
    x2 = jnp.pad(x, ((0, 0), (0, rows * cols - p))).reshape(
        n_workers, rows, cols)
    y2 = jax.vmap(lambda xi, wi: gossip_mix_2d(
        xi, x2, wi, interpret=True))(x2, jnp.asarray(mix, jnp.float32))
    got = y2.reshape(n_workers, -1)[:, :p]

    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("shape", [(12, 1000), (7, 1024), (5, 100),
                                   (20, 2100)])
def test_gossip_mix_2d_padding_shim(shape):
    """Shapes off the (8, 1024) tile grid — the case the old
    ``assert r % br == 0`` rejected — still match the jnp oracle."""
    r, c = shape
    k = 3
    x = jax.random.normal(KEY, (r, c))
    u = jax.random.normal(jax.random.fold_in(KEY, r), (k, r, c))
    w = jnp.array([0.25, 0.1, 0.3])
    out = gossip_mix_2d(x, u, w, interpret=True)
    assert out.shape == (r, c)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.gossip_mix_ref(x, u, w)),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=8, deadline=None)
@given(r=st.integers(1, 30), c=st.sampled_from([100, 1000, 1024, 1100]),
       scale=st.floats(1e-2, 1e2))
def test_quantize_padding_shim_roundtrip(r, c, scale):
    """quantize/dequantize round trip with the padding shim: arbitrary
    [R, C] stays within half an int8 step of each tile's scale, and the
    scale grid covers ceil-divided tiles."""
    x = jax.random.normal(KEY, (r, c)) * scale
    q, s = quantize_block_2d(x, interpret=True)
    br, bc = min(BLOCK_ROWS, r), min(BLOCK_COLS, c)
    assert q.shape == (r, c)
    assert s.shape == (-(-r // br), -(-c // bc))
    y = dequantize_block_2d(q, s, interpret=True)
    assert y.shape == (r, c)
    err = np.abs(np.asarray(y) - np.asarray(x))
    # per-tile bound: expand each tile's scale back over its elements
    s_np = np.asarray(s)
    bound = np.repeat(np.repeat(s_np, br, 0), bc, 1)[:r, :c] * 0.5 + 1e-6
    assert (err <= bound).all()
