"""AD-PSGD event-schedule and staleness semantics (engine.adpsgd_schedule
+ the fused event scan).

The schedule is a pure host function, so its staleness accounting can be
tested against the invariants AD-PSGD's convergence analysis needs
(bounded staleness), and hand-built schedules can drive the engines into
degenerate regimes — simultaneous events collapse to synchronous
pairwise gossip — without touching the cluster model. The compressed
pairwise exchange mirrors tests/test_compression.py's error-feedback
property tests for the 2-worker mix.
"""
from __future__ import annotations

from dataclasses import replace
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedHPConfig
from repro.core import compression
from repro.core.engine import (AdpsgdEvent, AdpsgdRound, AdpsgdSchedule,
                               adpsgd_schedule, run_adpsgd)
from repro.core.experiment import setup_experiment
from repro.core.fused import run_adpsgd_fused
from repro.simulation.cluster import ChurnEvent, ChurnSchedule

CFG = FedHPConfig(num_workers=8, rounds=12, tau_init=4, tau_max=20,
                  lr=0.1, batch_size=16, seed=5)
SCHED = ChurnSchedule((
    ChurnEvent(2, "leave", 1),
    ChurnEvent(3, "crash", 6),
    ChurnEvent(6, "join", 1),
))


def _experiment(cfg, churn=None, rounds=None):
    return setup_experiment(cfg, non_iid_p=0.3, churn=churn, rounds=rounds)


# ---------------------------------------------------------------------------
# schedule invariants
# ---------------------------------------------------------------------------

def test_staleness_bounded_by_inflight_events():
    """A worker's staleness counts pairwise averages absorbed by its live
    row since its snapshot; each intervening event stales at most one
    row, so staleness can never exceed the events processed since the
    worker's previous event (the schedule's max in-flight bound)."""
    for churn in (None, SCHED):
        _, _, _, _, cluster = _experiment(CFG, churn=churn)
        sched = adpsgd_schedule(cluster, CFG, rounds=12)
        events = sched.events
        assert len(events) == 12 * CFG.num_workers
        for e in events:
            assert 0 <= e.staleness <= e.inflight_bound, e
        # heterogeneous compute speeds: staleness actually occurs
        assert max(e.staleness for e in events) > 0


def test_schedule_event_times_monotone_and_round_aligned():
    _, _, _, _, cluster = _experiment(CFG)
    sched = adpsgd_schedule(cluster, CFG, rounds=8)
    times = [e.time for e in sched.events]
    assert all(a <= b for a, b in zip(times, times[1:]))
    for r in sched.rounds:
        assert r.clock == r.events[-1].time
        assert len(r.events) == CFG.num_workers


def test_schedule_compressed_charges_wire_ratio():
    """Compressed events finish earlier: each event's comm term is
    beta / wire_ratio (Eq. 10 on the event clock)."""
    _, _, _, _, c1 = _experiment(CFG)
    _, _, _, _, c2 = _experiment(replace(CFG, compress="int8"))
    s1 = adpsgd_schedule(c1, CFG, rounds=8)
    s2 = adpsgd_schedule(c2, replace(CFG, compress="int8"), rounds=8)
    # every event's comm charge shrinks, so the same amount of work
    # finishes earlier on the event clock (the heap ORDER may differ —
    # faster links change which worker finishes next)
    assert s2.rounds[-1].clock < s1.rounds[-1].clock


def test_reference_records_schedule_staleness():
    """run_adpsgd surfaces the schedule's per-round mean staleness."""
    data, tx, ty, shards, cluster = _experiment(CFG, rounds=6)
    h = run_adpsgd(data, tx, ty, shards, cluster, CFG, rounds=6)
    _, _, _, _, cluster2 = _experiment(CFG, rounds=6)
    sched = adpsgd_schedule(cluster2, CFG, rounds=6)
    np.testing.assert_array_equal(
        h.as_arrays()["staleness"],
        [r.mean_staleness for r in sched.rounds])


# ---------------------------------------------------------------------------
# degenerate regime: simultaneous events == synchronous pairwise gossip
# ---------------------------------------------------------------------------

def _handmade_schedule(n, pairs_per_round, rounds, lr):
    """All events at time 0 (zero compute + link time): one round is a
    sequence of pairwise averages — synchronous pairwise gossip. The
    staleness annotations replay the engines' counter semantics (the
    fused scan cross-checks its carried counters against them)."""
    alive = np.ones(n, bool)
    stale = np.zeros(n, np.int64)
    events_done = 0
    last_ev = np.full(n, -1)
    rnds = []
    for _ in range(rounds):
        evs = []
        for (i, j) in pairs_per_round:
            bound = (int(events_done - last_ev[i] - 1)
                     if last_ev[i] >= 0 else events_done)
            evs.append(AdpsgdEvent(i, j, 0.0, int(stale[i]), bound))
            stale[i] = 0
            if j != i:
                stale[j] += 1
            last_ev[i] = events_done
            events_done += 1
        rnds.append(AdpsgdRound(tuple(evs), lr, alive.copy(), 0.0,
                                np.zeros(n, bool), np.zeros(n)))
    return AdpsgdSchedule(tuple(rnds), CFG.tau_init, n, n)


def test_zero_time_schedule_degenerates_to_synchronous_pairwise():
    """With lr=0 (pure mixing, no local drift) a zero-compute-time
    schedule whose rounds pair (0,1)(2,3)... then (1,2)(3,4)... must
    reproduce, through the fused scan's Pallas kernel path, exactly the
    synchronous sequential pairwise averaging of the initial rows."""
    cfg = replace(CFG, lr=0.0, rounds=4)
    data, tx, ty, shards, cluster = _experiment(cfg, rounds=4)
    n = cfg.num_workers
    pairs = [(i, i + 1) for i in range(0, n - 1, 2)] + \
            [(i, i + 1) for i in range(1, n - 1, 2)]
    sched = _handmade_schedule(n, pairs, rounds=4, lr=0.0)

    h_ref = run_adpsgd(data, tx, ty, shards, cluster, cfg, schedule=sched)
    _, _, _, _, cluster2 = _experiment(cfg, rounds=4)
    h_fus = run_adpsgd_fused(data, tx, ty, shards, cluster2, cfg,
                             schedule=sched)
    a, b = h_ref.as_arrays(), h_fus.as_arrays()
    np.testing.assert_allclose(a["consensus"], b["consensus"],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(a["cumulative_time"],
                                  np.zeros(4))        # zero-time events
    # staleness follows the event ORDER even at a single timestamp
    # (simultaneous events are applied sequentially), and both engines
    # agree on it exactly
    np.testing.assert_array_equal(a["staleness"], b["staleness"])
    # with lr=0 all rows start identical -> every pairwise average is a
    # no-op and consensus stays at the float mean-subtraction noise
    # floor (~1e-7: summing identical f32 rows reassociates)
    assert (a["consensus"] < 1e-5).all()
    assert (np.diff(a["consensus"]) == 0).all()


def test_zero_time_pairwise_contracts_like_pair_matrices():
    """One real training round to spread the rows, then zero-time lr=0
    rounds: the remaining schedule is synchronous pairwise gossip — the
    fused scan must multiply the [W, P] matrix by the same sequence of
    2-row averaging matrices the reference loop applies (consensus
    trajectories agree) and pure averaging contracts the spread
    monotonically."""
    cfg = replace(CFG, rounds=4, seed=9)
    data, tx, ty, shards, cluster = _experiment(cfg, rounds=4)
    n = cfg.num_workers
    rnds = list(_handmade_schedule(
        n, [(i, (i + 1) % n) for i in range(n)], rounds=4, lr=0.0).rounds)
    # round 0 trains (lr > 0) so the rows become distinct
    rnds[0] = AdpsgdRound(rnds[0].events, 0.1, rnds[0].alive, 0.0,
                          rnds[0].keep, rnds[0].donor_w)
    sched = AdpsgdSchedule(tuple(rnds), cfg.tau_init, n, n)
    h_ref = run_adpsgd(data, tx, ty, shards, cluster, cfg, schedule=sched)
    h_fus = run_adpsgd_fused(data, tx, ty, shards,
                             _experiment(cfg, rounds=4)[4], cfg,
                             schedule=sched)
    a, b = h_ref.as_arrays(), h_fus.as_arrays()
    np.testing.assert_allclose(a["consensus"], b["consensus"],
                               rtol=1e-5, atol=1e-5)
    assert a["consensus"][0] > 0                      # rows spread out
    # rounds 1.. are pure pairwise averaging: contraction only
    assert (np.diff(a["consensus"]) <= 1e-7).all()
    assert a["consensus"][-1] < a["consensus"][0]


# ---------------------------------------------------------------------------
# compressed pairwise exchange: error-feedback property (ChocoSGD)
# ---------------------------------------------------------------------------

def _pairwise_time_average(x0, error_feedback, steps=1500, burn=500):
    """Random-peer pairwise exchanges; time-averaged iterates."""
    rng = np.random.default_rng(0)
    w = x0.shape[0]
    x = x0
    err = jnp.zeros_like(x0)
    acc = np.zeros(x0.shape)
    step = jax.jit(partial(compression.compressed_pair_ref,
                           error_feedback=error_feedback))
    for t in range(steps):
        i = int(rng.integers(0, w))
        j = int((i + rng.integers(1, w)) % w)        # any other peer
        xi, xj, ei, ej = step(x[i], x[j], err[i], err[j])
        x = x.at[i].set(xi).at[j].set(xj)
        err = err.at[i].set(ei).at[j].set(ej)
        if t >= burn:
            acc += np.asarray(x)
    return acc / (steps - burn)


@pytest.mark.slow
def test_compressed_pairwise_ef_converges_naive_biases():
    """Pairwise mirror of test_compression's property test: with error
    feedback the time-averaged iterates converge to the network mean;
    naive int8 pairwise averaging stalls at a biased grid point."""
    w, p = 6, 256
    rng = np.random.default_rng(1)
    x0 = jnp.asarray(rng.normal(size=(w, p)), jnp.float32)
    target = np.asarray(x0).mean(0)
    ef = _pairwise_time_average(x0, True)
    naive = _pairwise_time_average(x0, False)
    # per-worker deviation from the network mean (the fleet mean itself
    # is preserved exactly by BOTH modes — each exchange keeps x_i + x_j)
    dev_ef = np.abs(ef - target[None]).max()
    dev_naive = np.abs(naive - target[None]).max()
    assert dev_ef < 5e-3, dev_ef
    assert dev_naive > 3 * dev_ef, (dev_naive, dev_ef)


def test_compressed_pair_preserves_sum_exactly():
    """One compressed exchange preserves x_i + x_j bit-for-bit minus
    float addition error (the invariant behind mean preservation)."""
    key = jax.random.PRNGKey(2)
    xi = jax.random.normal(key, (512,))
    xj = jax.random.normal(jax.random.fold_in(key, 1), (512,))
    ei = jax.random.normal(jax.random.fold_in(key, 2), (512,)) * 0.01
    ej = jax.random.normal(jax.random.fold_in(key, 3), (512,)) * 0.01
    xi2, xj2, *_ = compression.compressed_pair_ref(xi, xj, ei, ej)
    np.testing.assert_allclose(np.asarray(xi2 + xj2),
                               np.asarray(xi + xj), atol=1e-6)
    # the kernel path produces the identical update
    ki, kj, *_ = compression.compressed_pair_ref(
        xi, xj, ei, ej, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(ki), np.asarray(xi2), atol=2e-7)
    np.testing.assert_allclose(np.asarray(kj), np.asarray(xj2), atol=2e-7)
