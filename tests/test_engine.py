"""DFL engine + algorithm integration tests: convergence, the paper's
qualitative claims (completion time, waiting time), fault tolerance."""
from __future__ import annotations

import numpy as np
import pytest

from repro.configs.base import FedHPConfig
from repro.core.experiment import run_algorithm

CFG = FedHPConfig(num_workers=8, rounds=12, tau_init=5, tau_max=20,
                  lr=0.1, batch_size=32, seed=3)


@pytest.fixture(scope="module")
def histories():
    out = {}
    for algo in ("fedhp", "dpsgd", "ldsgd", "pens", "adpsgd"):
        out[algo] = run_algorithm(algo, CFG, non_iid_p=0.4, rounds=12)
    return out


@pytest.mark.parametrize("algo", ["fedhp", "dpsgd", "ldsgd", "pens",
                                  "adpsgd"])
def test_converges(histories, algo):
    h = histories[algo]
    assert h.final_accuracy > 0.8, f"{algo} failed to learn"
    assert np.isfinite([r.loss for r in h.records]).all()


def test_fedhp_faster_than_dpsgd(histories):
    """Paper Fig. 3: FedHP reduces completion time vs D-PSGD (~51%)."""
    t_fedhp = histories["fedhp"].records[-1].cumulative_time
    t_dpsgd = histories["dpsgd"].records[-1].cumulative_time
    assert t_fedhp < 0.8 * t_dpsgd, (t_fedhp, t_dpsgd)


def test_fedhp_low_waiting_time(histories):
    """Paper Fig. 7: FedHP waits far less than the synchronous baselines."""
    assert histories["fedhp"].avg_waiting < histories["dpsgd"].avg_waiting
    assert histories["fedhp"].avg_waiting < histories["pens"].avg_waiting


def test_adpsgd_zero_waiting(histories):
    """Paper Fig. 7: asynchronous AD-PSGD has no synchronization barrier."""
    assert histories["adpsgd"].avg_waiting == 0.0


def test_fedhp_respects_connectivity():
    """The adapted topology must stay connected every round (Eq. 12)."""
    h = run_algorithm("fedhp", CFG, non_iid_p=0.2, rounds=8)
    for r in h.records:
        assert r.num_links >= CFG.num_workers - 1  # spanning-tree minimum


def test_fault_tolerance_worker_failure():
    """Kill two workers mid-training: training must continue and converge
    (vertex removal + topology repair, DESIGN.md §6)."""
    h = run_algorithm("fedhp", CFG, non_iid_p=0.2, rounds=12,
                      fail_at={5: [0, 3]})
    assert h.final_accuracy > 0.75
    assert np.isfinite([r.loss for r in h.records]).all()


def test_metropolis_mixing_also_works():
    h = run_algorithm("dpsgd", CFG, non_iid_p=0.2, rounds=8,
                      mixing="metropolis")
    assert h.final_accuracy > 0.7
