"""Multi-device runtime checks, run as a subprocess by test_runtime.py
(device count must be set before jax initializes — never in conftest).

The gossip-collective checks that used to live here are now parametrized
pytest cases in tests/test_collectives.py (launched by the same
test_runtime.py through a subprocess pytest run, or directly by the CI
multi-device lane). What remains is the end-to-end substrate pass that
does not decompose into small cases: a sharded train step on a ring
topology with heterogeneous taus, plus the checkpoint roundtrip and
elastic reshard against the resulting worker-stacked state.

Prints one line per check; exits non-zero on any failure.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_smoke_config
from repro.core import topology as topo
from repro.models import registry
from repro.runtime import steps

PASS = 0
FAIL = 0


def check(name, cond):
    global PASS, FAIL
    if cond:
        PASS += 1
        print(f"ok   {name}")
    else:
        FAIL += 1
        print(f"FAIL {name}")


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    w = 4                                   # pod x data workers

    # ---- full train step on a RING (sparse) topology ----------------------
    # (a full graph with uniform weights is exact averaging — replicas
    # would be identical after gossip, which is correct but untestable
    # for divergence; the ring keeps them distinct)
    cfg = get_smoke_config("smollm-360m")
    cfg = dataclasses.replace(cfg, worker_axes=("pod", "data"))
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=32,
                                global_batch=8)
    ring = topo.ring_topology(w)
    bundle = steps.make_train_step(cfg, mesh, shape, adj=ring, tau_max=2,
                                   measure_distances=True)
    rng = jax.random.PRNGKey(1)
    p1 = registry.init_params(cfg, rng)
    params = jax.tree.map(lambda l: jnp.broadcast_to(l[None],
                                                     (w,) + l.shape), p1)
    step_fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                      out_shardings=bundle.out_shardings)
    taus = jnp.array([2, 1, 2, 1], jnp.int32)       # heterogeneous taus
    # memorize ONE fixed batch -> loss must decrease
    batch = registry.make_batch(cfg, shape, jax.random.PRNGKey(10))
    batch = jax.tree.map(
        lambda x: jnp.broadcast_to(
            x.reshape((w, x.shape[0] // w) + x.shape[1:])[:, None],
            (w, 2, x.shape[0] // w) + x.shape[1:]), batch)
    losses = []
    for i in range(6):
        params, loss, aux = step_fn(params, batch, taus, jnp.float32(0.05))
        losses.append(float(loss))
    check(f"train_step loss decreases ({losses[0]:.3f}->{losses[-1]:.3f})",
          losses[-1] < losses[0])
    check("train_step reports distances",
          "neighbor_dists" in aux and np.isfinite(
              np.asarray(aux["neighbor_dists"])).all())

    # ---- heterogeneous taus + sparse gossip -> replicas differ (DFL) -----
    check("worker replicas diverge (DFL, not DP)",
          not np.allclose(np.asarray(jax.tree.leaves(params)[0][0]),
                          np.asarray(jax.tree.leaves(params)[0][1])))

    # ---- checkpoint roundtrip with worker stacking + elastic reshard -----
    from repro.checkpoint import save_checkpoint, load_checkpoint
    from repro.checkpoint.store import elastic_reshard
    with tempfile.TemporaryDirectory() as d:
        state = jax.tree.map(np.asarray, params)
        save_checkpoint(d, 3, state)
        restored, meta = load_checkpoint(d, state)
        check("checkpoint roundtrip",
              all(np.array_equal(a, b) for a, b in
                  zip(jax.tree.leaves(state), jax.tree.leaves(restored))))
        r6 = elastic_reshard(restored, 6)
        check("elastic reshard 4->6",
              jax.tree.leaves(r6)[0].shape[0] == 6 and np.array_equal(
                  jax.tree.leaves(r6)[0][4], jax.tree.leaves(state)[0][0]))

    print(f"{PASS} passed, {FAIL} failed")
    return 1 if FAIL else 0


if __name__ == "__main__":
    sys.exit(main())
