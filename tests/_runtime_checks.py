"""Multi-device runtime checks, run as a subprocess by test_runtime.py
(device count must be set before jax initializes — never in conftest).

Prints one line per check; exits non-zero on any failure.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_smoke_config
from repro.core import topology as topo
from repro.models import registry
from repro.runtime import collectives, sharding, steps

PASS = 0
FAIL = 0


def check(name, cond):
    global PASS, FAIL
    if cond:
        PASS += 1
        print(f"ok   {name}")
    else:
        FAIL += 1
        print(f"FAIL {name}")


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    w = 4                                   # pod x data workers
    adj = topo.full_topology(w)
    mix = topo.mixing_matrix_uniform(adj)
    pairs = collectives.matchings_as_pairs(adj)
    wt = collectives.matching_weight_tables(adj, mix)

    # ---- gossip matches the dense mixing matrix --------------------------
    from jax.sharding import NamedSharding, PartitionSpec as P
    x = jax.random.normal(jax.random.PRNGKey(0), (w, 6, 32))
    spec = P(("pod", "data"), None, "model")
    gossip = collectives.gossip_fn(mesh, ("pod", "data"), pairs, wt, spec)
    with mesh:
        y = jax.jit(gossip, in_shardings=(NamedSharding(mesh, spec),),
                    out_shardings=NamedSharding(mesh, spec))(x)
    want = jnp.tensordot(jnp.asarray(mix, jnp.float32), x, axes=1)
    check("gossip == W @ X (Eq. 5)",
          np.allclose(np.asarray(y), np.asarray(want), atol=1e-5))
    check("gossip preserves mean",
          np.allclose(np.asarray(y).mean(0), np.asarray(x).mean(0),
                      atol=1e-5))

    # ---- gossip with distance measurement --------------------------------
    gossip_d = collectives.gossip_fn(mesh, ("pod", "data"), pairs, wt, spec,
                                     measure_distances=True)
    with mesh:
        y2, dists = jax.jit(gossip_d)(x)
    check("gossip(measure) same mix",
          np.allclose(np.asarray(y2), np.asarray(want), atol=1e-5))
    # distance of matching 0 equals ||x_i - x_partner|| for matched pairs
    d0 = np.linalg.norm(
        (np.asarray(x)[pairs[0][0][0]] - np.asarray(x)[pairs[0][0][1]]))
    check("consensus distance correct (Alg.1 l.9)",
          np.allclose(float(np.asarray(dists)[0]), d0, rtol=1e-4))

    # ---- compressed gossip approximates the uncompressed one -------------
    gossip_c = collectives.gossip_compressed_fn(mesh, ("pod", "data"),
                                                pairs, wt, spec)
    err0 = jnp.zeros_like(x)
    with mesh:
        yc, err = jax.jit(gossip_c)(x, err0, jnp.int32(0))
    rel = np.linalg.norm(np.asarray(yc) - np.asarray(want)) / \
        np.linalg.norm(np.asarray(want))
    check(f"int8 gossip close (rel={rel:.4f})", rel < 0.02)
    check("error feedback nonzero", float(jnp.abs(err).max()) > 0)
    # residual parity with the canonical compensated update: e' = z - Q(z)
    # computed per device shard ([1, 6, 16] blocks of the model axis)
    # through the shared core/compression wire format
    from repro.core import compression
    z_np = np.asarray(x, np.float32)                  # err0 == 0 -> z == x
    want_err = np.zeros_like(z_np)
    for ww in range(w):
        for m in range(2):
            blk = z_np[ww, :, 16 * m:16 * (m + 1)].reshape(-1)
            q2, s2 = compression.quantize_flat(jnp.asarray(blk))
            deq = np.asarray(compression.dequantize_flat(q2, s2, blk.size))
            want_err[ww, :, 16 * m:16 * (m + 1)] = \
                (blk - deq).reshape(6, 16)
    check("compressed residual == z - Q(z) (core parity)",
          np.allclose(np.asarray(err), want_err, atol=1e-7))

    # ---- sparse codecs over the same collective ---------------------------
    # rand-k: shared mask -> intermittent exact gossip; the doubly
    # stochastic compensated update preserves the fleet mean exactly
    gossip_rk = collectives.gossip_compressed_fn(
        mesh, ("pod", "data"), pairs, wt, spec, mode="randk:0.25", seed=7)
    with mesh:
        yr, err_r = jax.jit(gossip_rk)(x, err0, jnp.int32(0))
        yr2, _ = jax.jit(gossip_rk)(x, err0, jnp.int32(1))
    check("randk gossip preserves mean",
          np.allclose(np.asarray(yr).mean(0), np.asarray(x).mean(0),
                      atol=1e-5))
    check("randk carries no state", float(jnp.abs(err_r).max()) == 0.0)
    check("randk mask advances with step",
          not np.allclose(np.asarray(yr), np.asarray(yr2)))
    # top-k: x̂-tracking — one round from x̂ = x mixes the damped exact
    # update (innovation q = topk(x - x̂) = 0, x̂ unchanged)
    gossip_tk = collectives.gossip_compressed_fn(
        mesh, ("pod", "data"), pairs, wt, spec, mode="topk:0.5",
        gamma=0.5)
    with mesh:
        yt, xhat = jax.jit(gossip_tk)(x, x, jnp.int32(0))
    want_tk = x + 0.5 * (want - x)
    check("topk gossip == damped mix of tracked copies",
          np.allclose(np.asarray(yt), np.asarray(want_tk), atol=1e-5))
    check("topk xhat tracks params",
          np.allclose(np.asarray(xhat), np.asarray(x), atol=1e-7))

    # ---- sparse edge-list gossip over worker shards -----------------------
    # 8 workers over 4 pod x data shards: a ring exercises the +-1 shard
    # offsets, an erdos draw adds intra-shard and longer-offset groups
    from repro.kernels import ref as kernel_ref
    w8 = 8
    x8 = jax.random.normal(jax.random.PRNGKey(3), (w8, 24))
    x8s = jax.device_put(x8, NamedSharding(mesh, P(("pod", "data"), None)))
    for name, adj8 in (("ring", topo.ring_topology(w8)),
                       ("erdos", topo.erdos_topology(
                           w8, 0.4, np.random.default_rng(11)))):
        e8 = topo.edges_from_adj(adj8)
        ew8 = topo.edge_mixing_weights(e8, w8, "metropolis")
        s8, d8, wt8 = topo.directed_edges(e8, ew8)
        fe = collectives.gossip_edges_sharded_fn(
            mesh, ("pod", "data"), s8, d8, wt8, w8)
        with mesh:
            ye = jax.jit(fe)(x8s)
        want_e = kernel_ref.gossip_edges_ref(
            x8, jnp.asarray(s8), jnp.asarray(d8), jnp.asarray(wt8))
        check(f"sharded edge gossip == segment_sum oracle ({name})",
              np.allclose(np.asarray(ye), np.asarray(want_e), atol=1e-5))

    # ---- full train step on a RING (sparse) topology ----------------------
    # (a full graph with uniform weights is exact averaging — replicas
    # would be identical after gossip, which is correct but untestable
    # for divergence; the ring keeps them distinct)
    cfg = get_smoke_config("smollm-360m")
    cfg = dataclasses.replace(cfg, worker_axes=("pod", "data"))
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=32,
                                global_batch=8)
    ring = topo.ring_topology(w)
    bundle = steps.make_train_step(cfg, mesh, shape, adj=ring, tau_max=2,
                                   measure_distances=True)
    rng = jax.random.PRNGKey(1)
    p1 = registry.init_params(cfg, rng)
    params = jax.tree.map(lambda l: jnp.broadcast_to(l[None],
                                                     (w,) + l.shape), p1)
    step_fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                      out_shardings=bundle.out_shardings)
    taus = jnp.array([2, 1, 2, 1], jnp.int32)       # heterogeneous taus
    # memorize ONE fixed batch -> loss must decrease
    batch = registry.make_batch(cfg, shape, jax.random.PRNGKey(10))
    batch = jax.tree.map(
        lambda x: jnp.broadcast_to(
            x.reshape((w, x.shape[0] // w) + x.shape[1:])[:, None],
            (w, 2, x.shape[0] // w) + x.shape[1:]), batch)
    losses = []
    for i in range(6):
        params, loss, aux = step_fn(params, batch, taus, jnp.float32(0.05))
        losses.append(float(loss))
    check(f"train_step loss decreases ({losses[0]:.3f}->{losses[-1]:.3f})",
          losses[-1] < losses[0])
    check("train_step reports distances",
          "neighbor_dists" in aux and np.isfinite(
              np.asarray(aux["neighbor_dists"])).all())

    # ---- heterogeneous taus + sparse gossip -> replicas differ (DFL) -----
    check("worker replicas diverge (DFL, not DP)",
          not np.allclose(np.asarray(jax.tree.leaves(params)[0][0]),
                          np.asarray(jax.tree.leaves(params)[0][1])))

    # ---- checkpoint roundtrip with worker stacking + elastic reshard -----
    from repro.checkpoint import save_checkpoint, load_checkpoint
    from repro.checkpoint.store import elastic_reshard
    with tempfile.TemporaryDirectory() as d:
        state = jax.tree.map(np.asarray, params)
        save_checkpoint(d, 3, state)
        restored, meta = load_checkpoint(d, state)
        check("checkpoint roundtrip",
              all(np.array_equal(a, b) for a, b in
                  zip(jax.tree.leaves(state), jax.tree.leaves(restored))))
        r6 = elastic_reshard(restored, 6)
        check("elastic reshard 4->6",
              jax.tree.leaves(r6)[0].shape[0] == 6 and np.array_equal(
                  jax.tree.leaves(r6)[0][4], jax.tree.leaves(state)[0][0]))

    print(f"{PASS} passed, {FAIL} failed")
    return 1 if FAIL else 0


if __name__ == "__main__":
    sys.exit(main())
