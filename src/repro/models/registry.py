"""Model-zoo registry: family -> module with a uniform interface, plus
batch builders shared by smoke tests, examples, and the dry-run.

Uniform module interface (all pure functions over param pytrees):
    init(cfg, rng) -> params
    loss_fn(cfg, params, batch) -> (scalar_loss, metrics)
    prefill(cfg, params, ...) -> (logits, cache)
    decode_step(cfg, params, cache, tokens) -> (logits, cache)
    init_cache(cfg, batch, max_len[, ...]) -> cache

Family 'vlm' reuses the dense module (M-RoPE + prepended patch embeds are
dense-model features); its modality frontend is a stub: batches carry
precomputed patch embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.models import dense, encdec, hybrid, moe, xlstm
from repro.models import layers as L

_FAMILY = {
    "dense": dense,
    "moe": moe,
    "vlm": dense,
    "encdec": encdec,
    "hybrid": hybrid,
    "xlstm": xlstm,
}

VLM_VISION_FRACTION = 8       # S_vis = seq_len // 8


def get_model(family: str):
    if family not in _FAMILY:
        raise KeyError(f"unknown model family {family!r}")
    return _FAMILY[family]


def init_params(cfg: ModelConfig, rng):
    return get_model(cfg.family).init(cfg, rng)


def loss_fn(cfg: ModelConfig, params, batch):
    return get_model(cfg.family).loss_fn(cfg, params, batch)


# ---------------------------------------------------------------------------
# Batch construction (data for smoke/tests; shapes shared with input_specs)
# ---------------------------------------------------------------------------

def batch_shapes(cfg: ModelConfig, shape: InputShape,
                 batch_override: int = 0) -> dict[str, jax.ShapeDtypeStruct]:
    """Train-batch ShapeDtypeStructs for (cfg, shape)."""
    b = batch_override or shape.global_batch
    s = shape.seq_len
    dt = L.dtype_of(cfg.dtype)
    if cfg.family == "encdec":
        t = encdec.dec_len(s)
        return {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
            "tokens": jax.ShapeDtypeStruct((b, t), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, t), jnp.int32),
        }
    if cfg.family == "vlm":
        sv = s // VLM_VISION_FRACTION
        st = s - sv
        return {
            "tokens": jax.ShapeDtypeStruct((b, st), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, st), jnp.int32),
            "vision_embeds": jax.ShapeDtypeStruct((b, sv, cfg.d_model), dt),
            # batch-leading layout [B, 3, S] so worker stacking is uniform;
            # dense.loss_fn moves the stream axis to the front
            "mrope_positions": jax.ShapeDtypeStruct((b, 3, s), jnp.int32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }


def make_batch(cfg: ModelConfig, shape: InputShape, rng,
               batch_override: int = 0) -> dict:
    """Random concrete batch matching ``batch_shapes`` (smoke/tests)."""
    shapes = batch_shapes(cfg, shape, batch_override)
    out = {}
    keys = jax.random.split(rng, len(shapes))
    for k, (name, sds) in zip(keys, sorted(shapes.items())):
        if sds.dtype == jnp.int32 and name != "mrope_positions":
            out[name] = jax.random.randint(k, sds.shape, 0, cfg.vocab_size,
                                           jnp.int32)
        elif name == "mrope_positions":
            out[name] = mrope_positions_for(cfg, sds.shape[0], sds.shape[2])
        else:
            out[name] = jax.random.normal(k, sds.shape, jnp.float32) \
                .astype(sds.dtype) * 0.02
    return out


def mrope_positions_for(cfg: ModelConfig, b: int, s: int) -> jnp.ndarray:
    """Simple (t, h, w) position streams: a square-ish vision grid for the
    first S//8 positions, then sequential text ids on all three streams."""
    sv = s // VLM_VISION_FRACTION
    side = max(int(np.sqrt(max(sv, 1))), 1)
    idx = np.arange(s)
    t = np.where(idx < sv, 0, idx - sv + 1)
    h = np.where(idx < sv, np.minimum(idx // side, side - 1), idx - sv + 1)
    w = np.where(idx < sv, idx % side, idx - sv + 1)
    pos = np.stack([t, h, w]).astype(np.int32)          # [3, S]
    return jnp.broadcast_to(jnp.asarray(pos)[None], (b, 3, s))


# ---------------------------------------------------------------------------
# Serving entry points (uniform across families)
# ---------------------------------------------------------------------------

def prefill_kwargs(cfg: ModelConfig, batch: dict) -> dict:
    if cfg.family == "encdec":
        return {"frames": batch["frames"], "tokens": batch["tokens"]}
    return {"tokens": batch["tokens"]}


def run_prefill(cfg: ModelConfig, params, batch: dict, max_len: int = 0):
    m = get_model(cfg.family)
    if cfg.family == "encdec":
        return m.prefill(cfg, params, batch["frames"], batch["tokens"],
                         max_dec_len=max_len)
    return m.prefill(cfg, params, batch["tokens"], max_len=max_len)


def make_decode_cache(cfg: ModelConfig, batch: int, seq_len: int):
    """Cache stand-in for decode benchmarks/dry-runs: capacity exactly
    seq_len (a seq_len-1 context + the new token), keeping the sequence
    dim power-of-two so it shards over mesh axes."""
    m = get_model(cfg.family)
    if cfg.family == "encdec":
        return m.init_cache(cfg, batch, encdec.dec_len(seq_len), seq_len)
    if cfg.family == "xlstm":
        return m.init_cache(cfg, batch)
    return m.init_cache(cfg, batch, seq_len)


def decode_step(cfg: ModelConfig, params, cache, tokens):
    return get_model(cfg.family).decode_step(cfg, params, cache, tokens)
