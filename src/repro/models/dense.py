"""Dense decoder-only GQA transformer (internlm2, nemotron-4, smollm, gemma3).

Layers are stacked and scanned (`lax.scan`) to keep HLO/compile size flat in
depth. Gemma3's 5:1 local:global pattern is expressed as a *grouped* scan:
each group is (global_every-1) sliding-window layers followed by one global
layer, with a tail of leftover local layers; caches are stacked per group so
decode keeps a `window`-sized rolling cache for local layers and a full-size
cache only for the 1-in-N global layers (this is what makes long_500k decode
feasible for gemma3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def _block_init(rng, cfg: ModelConfig, dtype):
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": L.init_attention(k1, cfg.d_model, cfg.num_heads,
                                 cfg.num_kv_heads, cfg.resolved_head_dim,
                                 dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def _stack_init(rng, cfg: ModelConfig, n: int, dtype):
    ks = jax.random.split(rng, n)
    return jax.vmap(lambda k: _block_init(k, cfg, dtype))(ks)


def _group_shape(cfg: ModelConfig) -> tuple[int, int, int]:
    """(num_groups, locals_per_group, tail_locals)."""
    if not cfg.global_every:
        return 0, 0, 0
    ge = cfg.global_every
    return cfg.num_layers // ge, ge - 1, cfg.num_layers % ge


def init(cfg: ModelConfig, rng) -> dict:
    dtype = L.dtype_of(cfg.dtype)
    k_emb, k_blocks, k_head, k_tail, k_glob = jax.random.split(rng, 5)
    p = {
        "embed": L.embed_init(k_emb, (cfg.vocab_size, cfg.d_model), dtype),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                    dtype)
    if cfg.global_every:
        g, lpg, tail = _group_shape(cfg)
        ks = jax.random.split(k_blocks, g)
        p["local"] = jax.vmap(
            lambda k: _stack_init(k, cfg, lpg, dtype))(ks)    # [G, lpg, ...]
        p["global"] = _stack_init(k_glob, cfg, g, dtype)      # [G, ...]
        if tail:
            p["tail"] = _stack_init(k_tail, cfg, tail, dtype)
    else:
        p["blocks"] = _stack_init(k_blocks, cfg, cfg.num_layers, dtype)
    return p


def _block(cfg: ModelConfig, bp, x, positions, *, window: int,
           mrope_positions=None):
    h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
    h = L.multi_head_attention(
        bp["attn"], h, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
        positions=positions, theta=cfg.rope_theta, causal=True,
        window=window, mrope_positions=mrope_positions,
        attn_fn=L.pick_attn_fn(cfg, causal=True, window=window))
    x = x + h
    h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
    x = x + L.apply_mlp(bp["mlp"], h, cfg.act)
    return x


def _remat(f, cfg: ModelConfig):
    return L.remat(f, cfg)


def forward(cfg: ModelConfig, params: dict, tokens,
            mrope_positions=None, extra_embeds=None):
    """Full forward to final hidden states. tokens: [B, S] int32."""
    x = params["embed"][tokens]
    if extra_embeds is not None:                 # VLM: prepend patch embeds
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    if cfg.global_every:
        def local_fn(h, bp):
            return _block(cfg, bp, h, positions,
                          window=cfg.sliding_window), None

        def group_fn(h, gp):
            h, _ = L.scan(_remat(local_fn, cfg), h, gp["local"])
            h = _remat(lambda hh, bp: (_block(cfg, bp, hh, positions,
                                              window=0), None),
                       cfg)(h, gp["global"])[0]
            return h, None

        gp = {"local": params["local"], "global": params["global"]}
        x, _ = L.scan(group_fn, x, gp)
        if "tail" in params:
            x, _ = L.scan(_remat(local_fn, cfg), x, params["tail"])
    else:
        def block_fn(h, bp):
            return _block(cfg, bp, h, positions,
                          window=cfg.sliding_window,
                          mrope_positions=mrope_positions), None
        x, _ = L.scan(_remat(block_fn, cfg), x, params["blocks"])
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps)


def head_matrix(cfg: ModelConfig, params: dict):
    return (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])


def loss_fn(cfg: ModelConfig, params: dict, batch: dict):
    mp = batch.get("mrope_positions")
    if mp is not None:                    # stored batch-leading [B, 3, S]
        mp = jnp.moveaxis(mp, -2, 0)
    h = forward(cfg, params, batch["tokens"],
                mrope_positions=mp,
                extra_embeds=batch.get("vision_embeds"))
    labels, mask = batch["labels"], batch.get("loss_mask")
    if "vision_embeds" in batch:                 # loss only on text positions
        sv = batch["vision_embeds"].shape[1]
        h = h[:, sv:]
    loss, cnt = L.chunked_softmax_xent(h, head_matrix(cfg, params), labels,
                                       mask)
    return loss, {"tokens": cnt}


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dtype = L.dtype_of(cfg.dtype)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    w = cfg.sliding_window or max_len

    def kv(cap):
        return jnp.zeros((batch, cap, hkv, hd), dtype)

    if cfg.global_every:
        g, lpg, tail = _group_shape(cfg)
        cache = {
            "local_k": jnp.zeros((g, lpg, batch, w, hkv, hd), dtype),
            "local_v": jnp.zeros((g, lpg, batch, w, hkv, hd), dtype),
            "global_k": jnp.zeros((g, batch, max_len, hkv, hd), dtype),
            "global_v": jnp.zeros((g, batch, max_len, hkv, hd), dtype),
        }
        if tail:
            cache["tail_k"] = jnp.zeros((tail, batch, w, hkv, hd), dtype)
            cache["tail_v"] = jnp.zeros((tail, batch, w, hkv, hd), dtype)
    else:
        cap = cfg.sliding_window or max_len
        cache = {"k": jnp.zeros((cfg.num_layers, batch, cap, hkv, hd), dtype),
                 "v": jnp.zeros((cfg.num_layers, batch, cap, hkv, hd), dtype)}
    cache["len"] = jnp.zeros((), jnp.int32)
    return cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens):
    """One-token decode. tokens: [B, 1]. Returns (logits [B, V], cache)."""
    x = params["embed"][tokens]
    b = x.shape[0]
    pos = jnp.broadcast_to(cache["len"][None, None], (b, 1)).astype(jnp.int32)

    def attend(bp, h, ck, cv, window):
        return L.decode_attention(
            bp["attn"], h, ck, cv, cache["len"], num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            positions=pos, theta=cfg.rope_theta, window=window)

    def block_decode(bp, h, ck, cv, window):
        a = L.rms_norm(h, bp["ln1"], cfg.norm_eps)
        a, ck, cv = attend(bp, a, ck, cv, window)
        h = h + a
        m = L.rms_norm(h, bp["ln2"], cfg.norm_eps)
        h = h + L.apply_mlp(bp["mlp"], m, cfg.act)
        return h, ck, cv

    if cfg.global_every:
        w = cfg.sliding_window

        def local_scan(h, xs):
            bp, ck, cv = xs
            h, ck, cv = block_decode(bp, h, ck, cv, w)
            return h, (ck, cv)

        def group_scan(h, xs):
            gp_loc, gbp, lck, lcv, gck, gcv = xs
            h, (lck, lcv) = L.scan(local_scan, h, (gp_loc, lck, lcv))
            h, gck, gcv = block_decode(gbp, h, gck, gcv, 0)
            return h, (lck, lcv, gck, gcv)

        x, (lk, lv, gk, gv) = L.scan(
            group_scan, x, (params["local"], params["global"],
                            cache["local_k"], cache["local_v"],
                            cache["global_k"], cache["global_v"]))
        cache = dict(cache, local_k=lk, local_v=lv, global_k=gk,
                     global_v=gv)
        if "tail" in params:
            x, (tk, tv) = L.scan(
                local_scan, x,
                (params["tail"], cache["tail_k"], cache["tail_v"]))
            cache = dict(cache, tail_k=tk, tail_v=tv)
    else:
        w = cfg.sliding_window

        def layer_scan(h, xs):
            bp, ck, cv = xs
            h, ck, cv = block_decode(bp, h, ck, cv, w)
            return h, (ck, cv)

        x, (nk, nv) = L.scan(layer_scan, x,
                                   (params["blocks"], cache["k"], cache["v"]))
        cache = dict(cache, k=nk, v=nv)

    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, 0] @ head_matrix(cfg, params)).astype(jnp.float32)
    cache["len"] = cache["len"] + 1
    return logits, cache


def _block_kv(cfg: ModelConfig, bp, x, positions, *, window: int):
    """Like _block but also returns post-RoPE K/V for cache filling."""
    b, s, _ = x.shape
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
    k = (h @ bp["attn"]["wk"]).reshape(b, s, hkv, hd)
    v = (h @ bp["attn"]["wv"]).reshape(b, s, hkv, hd)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    a = L.multi_head_attention(
        bp["attn"], h, num_heads=cfg.num_heads, num_kv_heads=hkv,
        head_dim=hd, positions=positions, theta=cfg.rope_theta,
        causal=True, window=window)
    x = x + a
    m = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
    x = x + L.apply_mlp(bp["mlp"], m, cfg.act)
    return x, k, v


def _to_window_cache(k, window: int, s: int):
    """Last `window` entries rolled so entry for position p sits at p%window."""
    kw = k[:, -window:] if s >= window else jnp.pad(
        k, ((0, 0), (0, window - s), (0, 0), (0, 0)))
    return jnp.roll(kw, shift=s % window, axis=1) if s >= window else kw


def prefill(cfg: ModelConfig, params: dict, tokens, max_len: int = 0):
    """Prefill forward: returns (last-position logits, filled cache).

    max_len: full-cache capacity (defaults to s; pass s+budget for serving).
    """
    b, s = tokens.shape
    cap = max_len or s
    w = cfg.sliding_window or cap
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def pad_full(k):
        return jnp.pad(k, ((0, 0), (0, cap - s), (0, 0), (0, 0)))

    x = params["embed"][tokens]
    if cfg.global_every:
        def local_fn(h, bp):
            h, k, v = _block_kv(cfg, bp, h, positions,
                                window=cfg.sliding_window)
            return h, (_to_window_cache(k, w, s), _to_window_cache(v, w, s))

        def group_fn(h, gp):
            h, (lk, lv) = L.scan(local_fn, h, gp["local"])
            h, gk, gv = _block_kv(cfg, gp["global"], h, positions, window=0)
            return h, (lk, lv, pad_full(gk), pad_full(gv))

        x, (lk, lv, gk, gv) = L.scan(
            group_fn, x, {"local": params["local"],
                          "global": params["global"]})
        cache = {"local_k": lk, "local_v": lv, "global_k": gk,
                 "global_v": gv}
        if "tail" in params:
            x, (tk, tv) = L.scan(local_fn, x, params["tail"])
            cache["tail_k"], cache["tail_v"] = tk, tv
    else:
        if cfg.sliding_window:
            def layer_fn(h, bp):
                h, k, v = _block_kv(cfg, bp, h, positions,
                                    window=cfg.sliding_window)
                return h, (_to_window_cache(k, w, s),
                           _to_window_cache(v, w, s))
        else:
            def layer_fn(h, bp):
                h, k, v = _block_kv(cfg, bp, h, positions, window=0)
                return h, (pad_full(k), pad_full(v))
        x, (ck, cv) = L.scan(layer_fn, x, params["blocks"])
        cache = {"k": ck, "v": cv}

    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, -1] @ head_matrix(cfg, params)).astype(jnp.float32)
    cache["len"] = jnp.asarray(s, jnp.int32)
    return logits, cache
