"""xLSTM blocks (xlstm-1.3b): mLSTM (matrix memory, parallelizable) +
sLSTM (scalar memory with recurrent memory mixing, sequential).

TPU adaptation (DESIGN.md §3): the mLSTM recurrence
    C_t = f_t C_{t-1} + i_t v_t k_t^T,  n_t = f_t n_{t-1} + i_t k_t
is the same linear recurrence as mamba2's SSD, so training/prefill reuse
``ssm.chunked_recurrence`` with per-head (k, q) playing (B, C) and the
normalizer n folded in as an extra ones-column of v (MXU einsums; no
token-sequential scan). The denominator uses max(|n.q|, 1) — the common
stabilized variant. sLSTM has true memory mixing (recurrent gate inputs)
and is inherently sequential — a `lax.scan` over tokens, as the paper
states it is not parallelizable. Block layout: groups of `slstm_every`
mLSTM blocks followed by one sLSTM block (xLSTM[7:1] -> 48 layers = 6
groups of 7+1), tail mLSTM blocks if depth doesn't divide.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.ssm import chunked_recurrence

QK_DIM_FACTOR = 0.5      # mLSTM qk dim = head_dim / 2
UP_FACTOR = 2            # mLSTM block up-projection factor


# ---------------------------------------------------------------------------
# mLSTM layer
# ---------------------------------------------------------------------------

def _mlstm_dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    """(d_up, heads, head_dim_v, head_dim_qk)."""
    d_up = UP_FACTOR * cfg.d_model
    h = cfg.num_heads
    hd = d_up // h
    return d_up, h, hd, max(int(hd * QK_DIM_FACTOR), 4)


def init_mlstm_block(rng, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_up, h, hd, nqk = _mlstm_dims(cfg)
    ks = jax.random.split(rng, 8)
    return {
        "ln": jnp.zeros((d,), dtype),
        "w_up": L.dense_init(ks[0], (d, 2 * d_up), dtype),    # (mlstm in, gate)
        "wq": L.dense_init(ks[1], (d_up, h * nqk), dtype),
        "wk": L.dense_init(ks[2], (d_up, h * nqk), dtype),
        "wv": L.dense_init(ks[3], (d_up, d_up), dtype),
        "w_igate": L.dense_init(ks[4], (d_up, h), jnp.float32, scale=0.01),
        "b_igate": jnp.full((h,), -3.0, jnp.float32),
        "w_fgate": L.dense_init(ks[5], (d_up, h), jnp.float32, scale=0.01),
        "b_fgate": jnp.full((h,), 3.0, jnp.float32),   # init: mostly remember
        "ln_inner": jnp.zeros((d_up,), dtype),
        "w_down": L.dense_init(ks[6], (d_up, d), dtype),
    }


def _mlstm_qkv_gates(p, a):
    """Projections for the mLSTM inner cell. a: [B,S,d_up]."""
    b, s, d_up = a.shape
    h = p["w_igate"].shape[-1]
    nqk = p["wq"].shape[-1] // h
    hd = d_up // h
    q = (a @ p["wq"]).reshape(b, s, h, nqk) * (nqk ** -0.5)
    k = (a @ p["wk"]).reshape(b, s, h, nqk)
    v = (a @ p["wv"]).reshape(b, s, h, hd)
    af = a.astype(jnp.float32)
    igate = af @ p["w_igate"] + p["b_igate"]                 # [B,S,H] pre-act
    fgate = af @ p["w_fgate"] + p["b_fgate"]
    return q, k, v, igate, fgate


def apply_mlstm(p, x, cfg: ModelConfig, chunk: int = 256):
    """Full-sequence mLSTM block. x: [B,S,d] -> [B,S,d]."""
    bsz, s, d = x.shape
    h_res = x
    x = L.rms_norm(x, p["ln"], cfg.norm_eps)
    up = x @ p["w_up"]
    a, gate = jnp.split(up, 2, axis=-1)                      # [B,S,d_up] each
    q, k, v, igate, fgate = _mlstm_qkv_gates(p, a)
    log_f = jax.nn.log_sigmoid(fgate)                        # [B,S,H]
    i_mult = jnp.exp(igate)                                  # update gate
    # normalizer: run the same recurrence with an extra ones column on v
    v_aug = jnp.concatenate(
        [v.astype(jnp.float32),
         jnp.ones(v.shape[:-1] + (1,), jnp.float32)], axis=-1)
    y_aug, _ = chunked_recurrence(v_aug, gate=i_mult, log_decay=log_f,
                                  b=k, c=q, chunk=chunk)
    num, den = y_aug[..., :-1], y_aug[..., -1:]
    hid = num / jnp.maximum(jnp.abs(den), 1.0)               # [B,S,H,hd]
    hid = hid.reshape(bsz, s, -1).astype(x.dtype)
    hid = L.rms_norm(hid, p["ln_inner"], cfg.norm_eps)
    out = (hid * jax.nn.silu(gate)) @ p["w_down"]
    return h_res + out


def init_mlstm_cache(cfg: ModelConfig, batch: int):
    d_up, h, hd, nqk = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, h, nqk, hd + 1), jnp.float32),  # aug column
    }


def decode_mlstm(p, x, cache, cfg: ModelConfig):
    """Single-token mLSTM decode. x: [B,1,d]. O(1) state update."""
    bsz, _, d = x.shape
    h_res = x
    x = L.rms_norm(x, p["ln"], cfg.norm_eps)
    up = x @ p["w_up"]
    a, gate = jnp.split(up, 2, axis=-1)
    q, k, v, igate, fgate = _mlstm_qkv_gates(p, a)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                       # [B,H,*]
    f = jnp.exp(jax.nn.log_sigmoid(fgate[:, 0]))              # [B,H]
    i = jnp.exp(igate[:, 0])
    v_aug = jnp.concatenate(
        [v.astype(jnp.float32),
         jnp.ones(v.shape[:-1] + (1,), jnp.float32)], axis=-1)
    # C_t = f C + i k (x) v_aug
    C = cache["C"] * f[..., None, None] + \
        i[..., None, None] * jnp.einsum("bhn,bhp->bhnp",
                                        k.astype(jnp.float32), v_aug)
    y_aug = jnp.einsum("bhn,bhnp->bhp", q.astype(jnp.float32), C)
    num, den = y_aug[..., :-1], y_aug[..., -1:]
    hid = (num / jnp.maximum(jnp.abs(den), 1.0)).reshape(bsz, 1, -1)
    hid = L.rms_norm(hid.astype(x.dtype), p["ln_inner"], cfg.norm_eps)
    out = (hid * jax.nn.silu(gate)) @ p["w_down"]
    return h_res + out, {"C": C}


# ---------------------------------------------------------------------------
# sLSTM layer (sequential; memory mixing via block-diagonal recurrence)
# ---------------------------------------------------------------------------

def _slstm_dims(cfg: ModelConfig) -> tuple[int, int]:
    h = cfg.num_heads
    return h, cfg.d_model // h


def init_slstm_block(rng, cfg: ModelConfig, dtype):
    d = cfg.d_model
    h, dh = _slstm_dims(cfg)
    ks = jax.random.split(rng, 7)
    # 4 gates (z, i, f, o): input projections + block-diag recurrent
    return {
        "ln": jnp.zeros((d,), dtype),
        "w_in": L.dense_init(ks[0], (d, 4 * d), dtype),
        "r": L.dense_init(ks[1], (4, h, dh, dh), jnp.float32, scale=0.05),
        "b": jnp.concatenate([jnp.zeros((2 * d,)), jnp.full((d,), 3.0),
                              jnp.zeros((d,))]).astype(jnp.float32),
        "ln_inner": jnp.zeros((d,), dtype),
        # post-sLSTM gated FFN (factor 4/3, gated -> ~2x d params)
        "w_ffn_gate": L.dense_init(ks[2], (d, 4 * d // 3), dtype),
        "w_ffn_up": L.dense_init(ks[3], (d, 4 * d // 3), dtype),
        "w_ffn_down": L.dense_init(ks[4], (4 * d // 3, d), dtype),
        "ln2": jnp.zeros((d,), dtype),
    }


def init_slstm_state(cfg: ModelConfig, batch: int):
    h, dh = _slstm_dims(cfg)
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.zeros((batch, h), jnp.float32)}


def _slstm_step(p, state, x_proj):
    """One token. x_proj: [B, 4d] precomputed W x + b. state dict of [B,H,dh]."""
    bsz = x_proj.shape[0]
    h_heads, dh = p["r"].shape[1], p["r"].shape[2]
    # recurrent contribution: block-diag R @ h_{t-1}, per gate
    rec = jnp.einsum("ghde,bhe->bghd", p["r"].astype(jnp.float32),
                     state["h"])                              # [B,4,H,dh]
    pre = x_proj.astype(jnp.float32).reshape(bsz, 4, h_heads, dh) + rec
    z_t = jnp.tanh(pre[:, 0])
    i_t = pre[:, 1]                                           # log-space
    f_t = jax.nn.log_sigmoid(pre[:, 2])
    o_t = jax.nn.sigmoid(pre[:, 3])
    # stabilizer (per head, scalar): m_t = max(f + m, max_dh i)
    i_head = i_t.max(axis=-1)                                 # [B,H]
    m_new = jnp.maximum(f_t.mean(axis=-1) + state["m"], i_head)
    f_s = jnp.exp(f_t + (state["m"] - m_new)[..., None])
    i_s = jnp.exp(i_t - m_new[..., None])
    c = f_s * state["c"] + i_s * z_t
    n = f_s * state["n"] + i_s
    h_new = o_t * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h_new, "m": m_new}


def apply_slstm(p, x, cfg: ModelConfig, state=None):
    """Full-sequence sLSTM block (sequential scan). x: [B,S,d]."""
    bsz, s, d = x.shape
    h_res = x
    xn = L.rms_norm(x, p["ln"], cfg.norm_eps)
    x_proj = xn @ p["w_in"] + p["b"].astype(xn.dtype)          # [B,S,4d]
    st0 = state or init_slstm_state(cfg, bsz)

    def step(st, xp):
        st = _slstm_step(p, st, xp)
        return st, st["h"]

    final, hs = L.scan(step, st0, jnp.moveaxis(x_proj, 1, 0),
                       unroll_ok=False)
    hid = jnp.moveaxis(hs, 0, 1).reshape(bsz, s, d).astype(x.dtype)
    hid = L.rms_norm(hid, p["ln_inner"], cfg.norm_eps)
    x = h_res + hid
    # gated FFN sub-block
    m = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    ff = (jax.nn.silu(m @ p["w_ffn_gate"]) * (m @ p["w_ffn_up"])) \
        @ p["w_ffn_down"]
    return x + ff, final


def decode_slstm(p, x, state, cfg: ModelConfig):
    """Single-token sLSTM decode. x: [B,1,d]."""
    bsz, _, d = x.shape
    h_res = x
    xn = L.rms_norm(x, p["ln"], cfg.norm_eps)
    x_proj = (xn @ p["w_in"] + p["b"].astype(xn.dtype))[:, 0]
    st = _slstm_step(p, state, x_proj)
    hid = st["h"].reshape(bsz, 1, d).astype(x.dtype)
    hid = L.rms_norm(hid, p["ln_inner"], cfg.norm_eps)
    x = h_res + hid
    m = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    ff = (jax.nn.silu(m @ p["w_ffn_gate"]) * (m @ p["w_ffn_up"])) \
        @ p["w_ffn_down"]
    return x + ff, st


# ---------------------------------------------------------------------------
# Model: groups of (slstm_every mLSTM blocks + 1 sLSTM block), mLSTM tail
# ---------------------------------------------------------------------------

def _group_shape(cfg: ModelConfig) -> tuple[int, int, int]:
    """(num_groups, mlstm_per_group, tail_mlstm)."""
    per = cfg.slstm_every + 1 if cfg.slstm_every else cfg.num_layers
    g = cfg.num_layers // per if cfg.slstm_every else 0
    tail = cfg.num_layers - g * per
    return g, cfg.slstm_every, tail


def init(cfg: ModelConfig, rng) -> dict:
    dtype = L.dtype_of(cfg.dtype)
    g, mpg, tail = _group_shape(cfg)
    k_emb, k_m, k_s, k_t, k_head = jax.random.split(rng, 5)
    p = {"embed": L.embed_init(k_emb, (cfg.vocab_size, cfg.d_model), dtype),
         "ln_f": jnp.zeros((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                    dtype)

    def stack(key, n, init_fn):
        ks = jax.random.split(key, n)
        return jax.vmap(lambda k: init_fn(k, cfg, dtype))(ks)

    if g:
        ks = jax.random.split(k_m, g)
        p["mlstm"] = jax.vmap(
            lambda k: stack(k, mpg, init_mlstm_block))(ks)    # [G, mpg, ...]
        p["slstm"] = stack(k_s, g, init_slstm_block)          # [G, ...]
    if tail:
        p["tail"] = stack(k_t, tail, init_mlstm_block)
    return p


def _remat(f, cfg: ModelConfig):
    return L.remat(f, cfg)


def forward(cfg: ModelConfig, params: dict, tokens):
    x = params["embed"][tokens]

    def mlstm_fn(h, bp):
        return apply_mlstm(bp, h, cfg), None

    if "mlstm" in params:
        def group_fn(h, gp):
            h, _ = L.scan(_remat(mlstm_fn, cfg), h, gp["m"])
            h, _ = _remat(lambda hh, sp: apply_slstm(sp, hh, cfg),
                          cfg)(h, gp["s"])
            return h, None

        x, _ = L.scan(group_fn, x,
                            {"m": params["mlstm"], "s": params["slstm"]})
    if "tail" in params:
        x, _ = L.scan(_remat(mlstm_fn, cfg), x, params["tail"])
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps)


def head_matrix(cfg: ModelConfig, params: dict):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def loss_fn(cfg: ModelConfig, params: dict, batch: dict):
    h = forward(cfg, params, batch["tokens"])
    loss, cnt = L.chunked_softmax_xent(h, head_matrix(cfg, params),
                                       batch["labels"],
                                       batch.get("loss_mask"))
    return loss, {"tokens": cnt}


# ---------------------------------------------------------------------------
# Serving: recurrent state cache (constant size -> long_500k decode runs)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0) -> dict:
    g, mpg, tail = _group_shape(cfg)

    def rep(tree, n):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), tree)

    cache = {"len": jnp.zeros((), jnp.int32)}
    if g:
        m1 = init_mlstm_cache(cfg, batch)
        cache["mlstm"] = rep(rep(m1, mpg), g)                # [G, mpg, ...]
        cache["slstm"] = rep(init_slstm_state(cfg, batch), g)
    if tail:
        cache["tail"] = rep(init_mlstm_cache(cfg, batch), tail)
    return cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens):
    """One-token decode. tokens: [B,1]. Returns (logits [B,V], cache)."""
    x = params["embed"][tokens]
    new = dict(cache)

    def mlstm_scan(h, xs):
        bp, st = xs
        h, st = decode_mlstm(bp, h, st, cfg)
        return h, st

    if "mlstm" in params:
        def group_scan(h, xs):
            gp_m, gp_s, cm, cs = xs
            h, cm = L.scan(mlstm_scan, h, (gp_m, cm))
            h, cs = decode_slstm(gp_s, h, cs, cfg)
            return h, (cm, cs)

        x, (cm, cs) = L.scan(
            group_scan, x, (params["mlstm"], params["slstm"],
                            cache["mlstm"], cache["slstm"]))
        new["mlstm"], new["slstm"] = cm, cs
    if "tail" in params:
        x, ct = L.scan(mlstm_scan, x, (params["tail"], cache["tail"]))
        new["tail"] = ct
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, 0] @ head_matrix(cfg, params)).astype(jnp.float32)
    new["len"] = cache["len"] + 1
    return logits, new


def prefill(cfg: ModelConfig, params: dict, tokens, max_len: int = 0):
    """Prefill: chunked-parallel mLSTM + scan sLSTM, emitting final states."""
    b, s = tokens.shape
    x = params["embed"][tokens]
    cache = init_cache(cfg, b)

    def mlstm_prefill(h, bp):
        # run parallel path, then recover final state via one recurrence call
        bsz = h.shape[0]
        h_res = h
        hn = L.rms_norm(h, bp["ln"], cfg.norm_eps)
        up = hn @ bp["w_up"]
        a, gate = jnp.split(up, 2, axis=-1)
        q, k, v, igate, fgate = _mlstm_qkv_gates(bp, a)
        log_f = jax.nn.log_sigmoid(fgate)
        i_mult = jnp.exp(igate)
        v_aug = jnp.concatenate(
            [v.astype(jnp.float32),
             jnp.ones(v.shape[:-1] + (1,), jnp.float32)], axis=-1)
        y_aug, st = chunked_recurrence(v_aug, gate=i_mult, log_decay=log_f,
                                       b=k, c=q)
        num, den = y_aug[..., :-1], y_aug[..., -1:]
        hid = (num / jnp.maximum(jnp.abs(den), 1.0)).reshape(bsz, s, -1)
        hid = L.rms_norm(hid.astype(h.dtype), bp["ln_inner"], cfg.norm_eps)
        return h_res + (hid * jax.nn.silu(gate)) @ bp["w_down"], {"C": st}

    new = dict(cache)
    if "mlstm" in params:
        def group_fn(h, xs):
            gp_m, gp_s = xs
            h, cm = L.scan(mlstm_prefill, h, gp_m)
            h, cs = apply_slstm(gp_s, h, cfg)
            return h, (cm, cs)

        x, (cm, cs) = L.scan(group_fn, x,
                                   (params["mlstm"], params["slstm"]))
        new["mlstm"], new["slstm"] = cm, cs
    if "tail" in params:
        x, ct = L.scan(mlstm_prefill, x, params["tail"])
        new["tail"] = ct
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, -1] @ head_matrix(cfg, params)).astype(jnp.float32)
    new["len"] = jnp.asarray(s, jnp.int32)
    return logits, new
