"""Zamba2-style hybrid: Mamba2 backbone + shared attention blocks.

`num_layers` Mamba2 blocks; after every `ssm_every` of them one of TWO
shared attention+FFN blocks fires (parameters reused across invocations,
alternating A/B — Zamba2's shared-block scheme). Groups scan with
`lax.scan`; the shared params are selected by group parity inside the
scan body. Decode keeps per-invocation KV caches (params shared, caches
not) plus constant-size Mamba2 states — sub-quadratic, so long_500k runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm

NUM_SHARED = 2


def _group_shape(cfg: ModelConfig) -> tuple[int, int, int]:
    """(num_groups, mamba_per_group, tail_mamba)."""
    if not cfg.ssm_every:
        return 0, 0, cfg.num_layers
    g = cfg.num_layers // cfg.ssm_every
    return g, cfg.ssm_every, cfg.num_layers - g * cfg.ssm_every


def _init_shared_block(rng, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": L.init_attention(k1, cfg.d_model, cfg.num_heads,
                                 cfg.num_kv_heads, cfg.resolved_head_dim,
                                 dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def _init_mamba_block(rng, cfg: ModelConfig, dtype):
    return {"ln": jnp.zeros((cfg.d_model,), dtype),
            "mamba": ssm.init_mamba2(rng, cfg.d_model, cfg.ssm_state, dtype)}


def init(cfg: ModelConfig, rng) -> dict:
    dtype = L.dtype_of(cfg.dtype)
    g, mpg, tail = _group_shape(cfg)
    k_emb, k_m, k_s, k_t, k_head = jax.random.split(rng, 5)
    p = {"embed": L.embed_init(k_emb, (cfg.vocab_size, cfg.d_model), dtype),
         "ln_f": jnp.zeros((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                    dtype)

    def stack(key, n, init_fn):
        ks = jax.random.split(key, n)
        return jax.vmap(lambda k: init_fn(k, cfg, dtype))(ks)

    if g:
        ks = jax.random.split(k_m, g)
        p["mamba"] = jax.vmap(lambda k: stack(k, mpg, _init_mamba_block))(ks)
        p["shared"] = stack(k_s, NUM_SHARED, _init_shared_block)  # [2, ...]
    if tail:
        p["tail"] = stack(k_t, tail, _init_mamba_block)
    return p


def _remat(f, cfg: ModelConfig):
    return L.remat(f, cfg)


def _mamba_fn(cfg: ModelConfig):
    def f(h, bp):
        x = L.rms_norm(h, bp["ln"], cfg.norm_eps)
        return h + ssm.apply_mamba2(bp["mamba"], x, cfg.ssm_state), None
    return f


def _shared_apply(cfg: ModelConfig, sp, h, positions):
    a = L.rms_norm(h, sp["ln1"], cfg.norm_eps)
    a = L.multi_head_attention(
        sp["attn"], a, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
        positions=positions, theta=cfg.rope_theta, causal=True,
        attn_fn=L.pick_attn_fn(cfg, causal=True, window=0))
    h = h + a
    m = L.rms_norm(h, sp["ln2"], cfg.norm_eps)
    return h + L.apply_mlp(sp["mlp"], m, cfg.act)


def forward(cfg: ModelConfig, params: dict, tokens):
    x = params["embed"][tokens]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    g, mpg, tail = _group_shape(cfg)
    mamba_fn = _mamba_fn(cfg)

    if "mamba" in params:
        def group_fn(h, xs):
            gp, parity = xs
            h, _ = L.scan(_remat(mamba_fn, cfg), h, gp)
            sp = jax.tree.map(lambda a: a[parity], params["shared"])
            h = _remat(lambda hh, spp: _shared_apply(cfg, spp, hh, positions),
                       cfg)(h, sp)
            return h, None

        parities = jnp.arange(g, dtype=jnp.int32) % NUM_SHARED
        x, _ = L.scan(group_fn, x, (params["mamba"], parities))
    if "tail" in params:
        x, _ = L.scan(_remat(mamba_fn, cfg), x, params["tail"])
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps)


def head_matrix(cfg: ModelConfig, params: dict):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def loss_fn(cfg: ModelConfig, params: dict, batch: dict):
    h = forward(cfg, params, batch["tokens"])
    loss, cnt = L.chunked_softmax_xent(h, head_matrix(cfg, params),
                                       batch["labels"],
                                       batch.get("loss_mask"))
    return loss, {"tokens": cnt}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dtype = L.dtype_of(cfg.dtype)
    g, mpg, tail = _group_shape(cfg)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def rep(tree, n):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape),
                            tree)

    cache = {"len": jnp.zeros((), jnp.int32)}
    m1 = ssm.init_mamba2_cache(batch, cfg.d_model, cfg.ssm_state, dtype)
    if g:
        cache["mamba"] = rep(rep(m1, mpg), g)
        cache["attn_k"] = jnp.zeros((g, batch, max_len, hkv, hd), dtype)
        cache["attn_v"] = jnp.zeros((g, batch, max_len, hkv, hd), dtype)
    if tail:
        cache["tail"] = rep(m1, tail)
    return cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens):
    x = params["embed"][tokens]
    b = x.shape[0]
    pos = jnp.broadcast_to(cache["len"][None, None], (b, 1)).astype(jnp.int32)
    g, mpg, tail = _group_shape(cfg)
    new = dict(cache)

    def mamba_scan(h, xs):
        bp, st = xs
        a = L.rms_norm(h, bp["ln"], cfg.norm_eps)
        y, st = ssm.decode_mamba2(bp["mamba"], a, st, cfg.ssm_state)
        return h + y, st

    if "mamba" in params:
        def group_scan(h, xs):
            gp, parity, cm, ck, cv = xs
            h, cm = L.scan(mamba_scan, h, (gp, cm))
            sp = jax.tree.map(lambda a: a[parity], params["shared"])
            a = L.rms_norm(h, sp["ln1"], cfg.norm_eps)
            a, ck, cv = L.decode_attention(
                sp["attn"], a, ck, cv, cache["len"],
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, positions=pos,
                theta=cfg.rope_theta)
            h = h + a
            m = L.rms_norm(h, sp["ln2"], cfg.norm_eps)
            h = h + L.apply_mlp(sp["mlp"], m, cfg.act)
            return h, (cm, ck, cv)

        parities = jnp.arange(g, dtype=jnp.int32) % NUM_SHARED
        x, (cm, ck, cv) = L.scan(
            group_scan, x, (params["mamba"], parities, cache["mamba"],
                            cache["attn_k"], cache["attn_v"]))
        new["mamba"], new["attn_k"], new["attn_v"] = cm, ck, cv
    if "tail" in params:
        x, ct = L.scan(mamba_scan, x, (params["tail"], cache["tail"]))
        new["tail"] = ct
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, 0] @ head_matrix(cfg, params)).astype(jnp.float32)
    new["len"] = cache["len"] + 1
    return logits, new


def prefill(cfg: ModelConfig, params: dict, tokens, max_len: int = 0):
    b, s = tokens.shape
    cap = max_len or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    g, mpg, tail = _group_shape(cfg)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dtype = L.dtype_of(cfg.dtype)
    x = params["embed"][tokens]
    new = {"len": jnp.asarray(s, jnp.int32)}

    def mamba_prefill(h, bp):
        a = L.rms_norm(h, bp["ln"], cfg.norm_eps)
        # full-sequence apply + final state via the chunked recurrence
        bsz, sl, d = a.shape
        z, xbc, dt, d_in, hh = ssm._split_proj(bp["mamba"], a, d,
                                               cfg.ssm_state)
        xbc, conv_state = ssm._causal_conv(bp["mamba"], xbc)
        xs_, bb, cc = jnp.split(xbc, [d_in, d_in + cfg.ssm_state], axis=-1)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + bp["mamba"]["dt_bias"])
        xhh = xs_.reshape(bsz, sl, hh, ssm.HEAD_DIM)
        y, st = ssm.ssd_chunked(xhh, dt, bp["mamba"]["a_log"], bb, cc)
        y = y + xhh.astype(jnp.float32) * \
            bp["mamba"]["d_skip"][None, None, :, None]
        y = y.reshape(bsz, sl, d_in).astype(a.dtype)
        y = L.rms_norm(y * jax.nn.silu(z), bp["mamba"]["norm"])
        out = y @ bp["mamba"]["out_proj"]
        return h + out, {"state": st,
                         "conv": conv_state[:, -(ssm.CONV_WIDTH - 1):]}

    if "mamba" in params:
        def group_fn(h, xs):
            gp, parity = xs
            h, cm = L.scan(mamba_prefill, h, gp)
            sp = jax.tree.map(lambda a: a[parity], params["shared"])
            a = L.rms_norm(h, sp["ln1"], cfg.norm_eps)
            k = L.apply_rope((a @ sp["attn"]["wk"]).reshape(b, s, hkv, hd),
                             positions, cfg.rope_theta)
            v = (a @ sp["attn"]["wv"]).reshape(b, s, hkv, hd)
            a = L.multi_head_attention(
                sp["attn"], a, num_heads=cfg.num_heads, num_kv_heads=hkv,
                head_dim=hd, positions=positions, theta=cfg.rope_theta,
                causal=True)
            h = h + a
            m = L.rms_norm(h, sp["ln2"], cfg.norm_eps)
            h = h + L.apply_mlp(sp["mlp"], m, cfg.act)
            pad = ((0, 0), (0, cap - s), (0, 0), (0, 0))
            return h, (cm, jnp.pad(k, pad).astype(dtype),
                       jnp.pad(v, pad).astype(dtype))

        parities = jnp.arange(g, dtype=jnp.int32) % NUM_SHARED
        x, (cm, ck, cv) = L.scan(group_fn, x,
                                       (params["mamba"], parities))
        new["mamba"], new["attn_k"], new["attn_v"] = cm, ck, cv
    if "tail" in params:
        x, ct = L.scan(mamba_prefill, x, params["tail"])
        new["tail"] = ct
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, -1] @ head_matrix(cfg, params)).astype(jnp.float32)
    return logits, new
