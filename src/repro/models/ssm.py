"""Mamba2 / SSD blocks (zamba2 backbone).

The selective-state-space layer is computed with the chunked SSD algorithm:
intra-chunk terms are attention-like einsums (MXU-friendly — this is the
TPU-native adaptation; no sequential scan over tokens), and only a tiny
`lax.scan` over chunks carries the [B, H, n, p] state. Decode is the O(1)
recurrent update. A sequential-scan reference (`ssd_ref`) is kept for tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L

HEAD_DIM = 64          # mamba2 default headdim p
CONV_WIDTH = 4


def num_ssm_heads(d_inner: int) -> int:
    return max(1, d_inner // HEAD_DIM)


def init_mamba2(rng, d_model: int, ssm_state: int, dtype):
    d_in = 2 * d_model
    h = num_ssm_heads(d_in)
    n = ssm_state
    ks = jax.random.split(rng, 6)
    conv_ch = d_in + 2 * n
    return {
        # projections: z (gate), x, B, C, dt
        "in_proj": L.dense_init(ks[0], (d_model, 2 * d_in + 2 * n + h),
                                dtype),
        "conv_w": L.dense_init(ks[1], (CONV_WIDTH, conv_ch), dtype,
                               scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": jnp.zeros((d_in,), dtype),
        "out_proj": L.dense_init(ks[2], (d_in, d_model), dtype),
    }


def _split_proj(p, x, d_model: int, n: int):
    d_in = 2 * d_model
    h = num_ssm_heads(d_in)
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * n], axis=-1)
    return z, xbc, dt, d_in, h


def _causal_conv(p, xbc, conv_state=None):
    """Depthwise causal conv width 4 over [B, S, C]; returns (out, new_state).

    conv_state: [B, CONV_WIDTH-1, C] trailing context (decode path)."""
    if conv_state is None:
        pad = jnp.zeros(xbc.shape[:1] + (CONV_WIDTH - 1,) + xbc.shape[2:],
                        xbc.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xbc], axis=1)           # [B, S+3, C]
    out = sum(xp[:, i:i + xbc.shape[1]] * p["conv_w"][i]
              for i in range(CONV_WIDTH)) + p["conv_b"]
    out = jax.nn.silu(out)
    new_state = xp[:, -(CONV_WIDTH - 1):]
    return out, new_state


def chunked_recurrence(xh, gate, log_decay, b, c, chunk: int = 256,
                       state0=None):
    """Generalized chunked linear recurrence (SSD / mLSTM share this core).

    State recurrence per head:  S_t = exp(log_decay_t) * S_{t-1}
                                      + gate_t * b_t (x) x_t
    Output:                     y_t = c_t . S_t

    xh: [B,S,H,p]; gate, log_decay: [B,S,H];
    b, c: [B,S,n] (shared across heads, mamba2) or [B,S,H,n] (per head, mLSTM).
    Returns (y [B,S,H,p], final_state [B,H,n,p]). Intra-chunk terms are
    attention-like einsums (MXU-friendly); only a tiny scan carries state
    across chunks — the TPU-native adaptation of the recurrence.
    """
    bsz, s, h, p = xh.shape
    n = b.shape[-1]
    per_head = b.ndim == 4
    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q
    gate = gate.astype(jnp.float32)
    # chunk views
    xc = xh.reshape(bsz, nc, q, h, p).astype(jnp.float32)
    dtc = gate.reshape(bsz, nc, q, h)
    dac = log_decay.astype(jnp.float32).reshape(bsz, nc, q, h)
    bshape = (bsz, nc, q, h, n) if per_head else (bsz, nc, q, n)
    bc = b.reshape(bshape).astype(jnp.float32)
    cc = c.reshape(bshape).astype(jnp.float32)
    lcum = jnp.cumsum(dac, axis=2)                       # [B,nc,q,H]

    # ---- intra-chunk (attention-like) ----
    # M[t, s] = exp(l_t - l_s) * (C_t . B_s) * gate_s   for s <= t
    rel = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]    # [B,nc,q,q,H]
    tri = jnp.tril(jnp.ones((q, q), bool))
    rel = jnp.where(tri[None, None, :, :, None], rel, -jnp.inf)
    if per_head:
        cb = jnp.einsum("bgthn,bgshn->bgtsh", cc, bc)        # [B,nc,q,q,H]
        m = jnp.exp(rel) * cb * dtc[:, :, None, :, :]
    else:
        cb = jnp.einsum("bgtn,bgsn->bgts", cc, bc)           # [B,nc,q,q]
        m = jnp.exp(rel) * cb[..., None] * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bgtsh,bgshp->bgthp", m, xc)

    # ---- chunk summaries ----
    # state contribution of chunk g: sum_s exp(l_end - l_s) gate_s B_s x_s^T
    dec_end = jnp.exp(lcum[:, :, -1:, :] - lcum)             # [B,nc,q,H]
    if per_head:
        states = jnp.einsum("bgsh,bgshn,bgshp->bghnp",
                            dec_end * dtc, bc, xc)           # [B,nc,H,n,p]
    else:
        states = jnp.einsum("bgsh,bgsn,bgshp->bghnp",
                            dec_end * dtc, bc, xc)
    chunk_decay = jnp.exp(lcum[:, :, -1, :])                 # [B,nc,H]

    # ---- inter-chunk state recurrence (tiny scan over chunks) ----
    s0 = (jnp.zeros((bsz, h, n, p), jnp.float32) if state0 is None
          else state0.astype(jnp.float32))

    def step(carry, xs):
        st, dec = xs                                        # per-chunk
        new = carry * dec[:, :, None, None] + st
        return new, carry                                   # emit state BEFORE chunk

    # unroll_ok=False: the body is an elementwise state update (<0.1% of
    # layer FLOPs) but nc can be 128+ — unrolling it explodes compile time
    # for no accounting gain (DESIGN.md §8b)
    final, prev_states = L.scan(
        step, s0, (jnp.moveaxis(states, 1, 0),
                   jnp.moveaxis(chunk_decay, 1, 0)), unroll_ok=False)
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # [B,nc,H,n,p]

    # ---- inter-chunk contribution: y_t += C_t^T (exp(l_t) * S_chunk_start)
    dec_in = jnp.exp(lcum)                                   # [B,nc,q,H]
    if per_head:
        y_inter = jnp.einsum("bgthn,bghnp->bgthp", cc, prev_states) \
            * dec_in[..., None]
    else:
        y_inter = jnp.einsum("bgtn,bghnp->bgthp", cc, prev_states) \
            * dec_in[..., None]
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y.astype(xh.dtype), final


def ssd_chunked(xh, dt, a_log, b, c, chunk: int = 256, state0=None):
    """Chunked SSD (mamba2). xh: [B,S,H,p]; dt: [B,S,H]; b,c: [B,S,n].

    Returns (y [B,S,H,p], final_state [B,H,n,p]).
    """
    a = -jnp.exp(a_log)                                  # [H]
    dt = dt.astype(jnp.float32)
    return chunked_recurrence(xh, gate=dt, log_decay=dt * a, b=b, c=c,
                              chunk=chunk, state0=state0)


def ssd_ref(xh, dt, a_log, b, c, state0=None):
    """Sequential-scan oracle for tests. Same signature as ssd_chunked."""
    bsz, s, h, p = xh.shape
    n = b.shape[-1]
    a = -jnp.exp(a_log)
    st0 = (jnp.zeros((bsz, h, n, p), jnp.float32) if state0 is None
           else state0.astype(jnp.float32))

    def step(st, xs):
        x_t, dt_t, b_t, c_t = xs
        dec = jnp.exp(dt_t * a)                              # [B,H]
        upd = jnp.einsum("bh,bn,bhp->bhnp", dt_t, b_t,
                         x_t.astype(jnp.float32))
        st = st * dec[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", c_t, st)
        return st, y

    xs = (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(b.astype(jnp.float32), 1, 0),
          jnp.moveaxis(c.astype(jnp.float32), 1, 0))
    final, ys = jax.lax.scan(step, st0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(xh.dtype), final


def apply_mamba2(p, x, ssm_state_dim: int, *, chunk: int = 256):
    """Full-sequence Mamba2 block body. x: [B,S,d]. Returns [B,S,d]."""
    bsz, s, d = x.shape
    z, xbc, dt, d_in, h = _split_proj(p, x, d, ssm_state_dim)
    xbc, _ = _causal_conv(p, xbc)
    xs, b, c = jnp.split(xbc, [d_in, d_in + ssm_state_dim], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    xh = xs.reshape(bsz, s, h, HEAD_DIM)
    y, _ = ssd_chunked(xh, dt, p["a_log"], b, c, chunk=chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, d_in).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"]


def init_mamba2_cache(batch: int, d_model: int, ssm_state: int, dtype):
    d_in = 2 * d_model
    h = num_ssm_heads(d_in)
    return {
        "state": jnp.zeros((batch, h, ssm_state, HEAD_DIM), jnp.float32),
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, d_in + 2 * ssm_state),
                          dtype),
    }


def decode_mamba2(p, x, cache, ssm_state_dim: int):
    """Single-token decode. x: [B,1,d]. Returns (y [B,1,d], new_cache)."""
    bsz, _, d = x.shape
    z, xbc, dt, d_in, h = _split_proj(p, x, d, ssm_state_dim)
    xbc, conv_state = _causal_conv(p, xbc, cache["conv"])
    xs, b, c = jnp.split(xbc, [d_in, d_in + ssm_state_dim], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,1,H]
    xh = xs.reshape(bsz, h, HEAD_DIM)
    a = -jnp.exp(p["a_log"])
    dec = jnp.exp(dt[:, 0] * a)                                  # [B,H]
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt[:, 0], b[:, 0],
                     xh.astype(jnp.float32))
    st = cache["state"] * dec[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", c[:, 0].astype(jnp.float32), st)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"], {"state": st, "conv": conv_state}
