"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: inputs are precomputed
frame embeddings [B, S, d] (``input_specs`` supplies them). Encoder:
bidirectional self-attention over frames with sinusoidal positions.
Decoder: causal self-attention + cross-attention to encoder output;
decoder length = seq_len // 8 (config note). Decode keeps a self-cache of
decoder length plus precomputed cross-attention K/V over all frames.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

DEC_RATIO = 8     # decoder_len = seq_len // DEC_RATIO


def dec_len(seq_len: int) -> int:
    return max(seq_len // DEC_RATIO, 1)


def _enc_block_init(rng, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": L.init_attention(k1, cfg.d_model, cfg.num_heads,
                                 cfg.num_kv_heads, cfg.resolved_head_dim,
                                 dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def _dec_block_init(rng, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "self_attn": L.init_attention(k1, cfg.d_model, cfg.num_heads,
                                      cfg.num_kv_heads,
                                      cfg.resolved_head_dim, dtype),
        "ln_x": jnp.zeros((cfg.d_model,), dtype),
        "cross_attn": L.init_attention(k2, cfg.d_model, cfg.num_heads,
                                       cfg.num_kv_heads,
                                       cfg.resolved_head_dim, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def init(cfg: ModelConfig, rng) -> dict:
    dtype = L.dtype_of(cfg.dtype)
    k_emb, k_enc, k_dec, k_head = jax.random.split(rng, 4)
    ke = jax.random.split(k_enc, cfg.encoder_layers)
    kd = jax.random.split(k_dec, cfg.decoder_layers)
    p = {
        "embed": L.embed_init(k_emb, (cfg.vocab_size, cfg.d_model), dtype),
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(ke),
        "enc_ln_f": jnp.zeros((cfg.d_model,), dtype),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg, dtype))(kd),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                    dtype)
    return p


def _remat(f, cfg: ModelConfig):
    return L.remat(f, cfg)


def encode(cfg: ModelConfig, params: dict, frames):
    """frames: [B, S, d] precomputed frame embeddings (conv frontend stub)."""
    b, s, d = frames.shape
    x = frames + L.sinusoidal_positions(s, d).astype(frames.dtype)[None]

    def block_fn(h, bp):
        a = L.layer_norm(h, 1.0 + bp["ln1"], jnp.zeros_like(bp["ln1"]),
                         cfg.norm_eps)
        a = L.multi_head_attention(
            bp["attn"], a, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            causal=False)
        h = h + a
        m = L.layer_norm(h, 1.0 + bp["ln2"], jnp.zeros_like(bp["ln2"]),
                         cfg.norm_eps)
        return h + L.apply_mlp(bp["mlp"], m, cfg.act), None

    x, _ = L.scan(_remat(block_fn, cfg), x, params["enc_blocks"])
    return L.layer_norm(x, 1.0 + params["enc_ln_f"],
                        jnp.zeros_like(params["enc_ln_f"]), cfg.norm_eps)


def _dec_block(cfg: ModelConfig, bp, x, enc_out, positions):
    a = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
    a = L.multi_head_attention(
        bp["self_attn"], a, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
        positions=positions, causal=True)
    x = x + a
    a = L.rms_norm(x, bp["ln_x"], cfg.norm_eps)
    a = L.multi_head_attention(
        bp["cross_attn"], a, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
        causal=False, kv_x=enc_out)
    x = x + a
    m = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
    return x + L.apply_mlp(bp["mlp"], m, cfg.act)


def decode_seq(cfg: ModelConfig, params: dict, tokens, enc_out):
    """Teacher-forced decoder forward. tokens: [B, T]."""
    x = params["embed"][tokens]
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

    def block_fn(h, bp):
        return _dec_block(cfg, bp, h, enc_out, positions), None

    x, _ = L.scan(_remat(block_fn, cfg), x, params["dec_blocks"])
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps)


def head_matrix(cfg: ModelConfig, params: dict):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def loss_fn(cfg: ModelConfig, params: dict, batch: dict):
    """batch: frames [B,S,d], tokens [B,T], labels [B,T]."""
    enc_out = encode(cfg, params, batch["frames"])
    h = decode_seq(cfg, params, batch["tokens"], enc_out)
    loss, cnt = L.chunked_softmax_xent(h, head_matrix(cfg, params),
                                       batch["labels"],
                                       batch.get("loss_mask"))
    return loss, {"tokens": cnt}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_dec_len: int,
               enc_len: int) -> dict:
    dtype = L.dtype_of(cfg.dtype)
    nl = cfg.decoder_layers
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((nl, batch, max_dec_len, hkv, hd), dtype),
        "v": jnp.zeros((nl, batch, max_dec_len, hkv, hd), dtype),
        "xk": jnp.zeros((nl, batch, enc_len, hkv, hd), dtype),
        "xv": jnp.zeros((nl, batch, enc_len, hkv, hd), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(cfg: ModelConfig, params: dict, frames, tokens,
            max_dec_len: int = 0):
    """Encode frames, precompute cross K/V, teacher-force the prompt tokens.

    Returns (last-position logits, cache)."""
    b, t = tokens.shape
    cap = max_dec_len or t
    enc_out = encode(cfg, params, frames)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    se = enc_out.shape[1]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    x = params["embed"][tokens]

    def block_fn(h, bp):
        a = L.rms_norm(h, bp["ln1"], cfg.norm_eps)
        k = (a @ bp["self_attn"]["wk"]).reshape(b, t, hkv, hd)
        v = (a @ bp["self_attn"]["wv"]).reshape(b, t, hkv, hd)
        h = _dec_block(cfg, bp, h, enc_out, positions)
        xk = (enc_out @ bp["cross_attn"]["wk"]).reshape(b, se, hkv, hd)
        xv = (enc_out @ bp["cross_attn"]["wv"]).reshape(b, se, hkv, hd)
        pad = ((0, 0), (0, cap - t), (0, 0), (0, 0))
        return h, (jnp.pad(k, pad), jnp.pad(v, pad), xk, xv)

    x, (ck, cv, xk, xv) = L.scan(block_fn, x, params["dec_blocks"])
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, -1] @ head_matrix(cfg, params)).astype(jnp.float32)
    return logits, {"k": ck, "v": cv, "xk": xk, "xv": xv,
                    "len": jnp.asarray(t, jnp.int32)}


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens):
    """One-token decode against cached self K/V + cross K/V."""
    import math
    x = params["embed"][tokens]
    b = x.shape[0]
    pos = jnp.broadcast_to(cache["len"][None, None], (b, 1)).astype(jnp.int32)
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = hq // hkv

    def cross(bp, h, xk, xv):
        q = (h @ bp["cross_attn"]["wq"]).reshape(b, 1, hkv, g, hd)
        scores = jnp.einsum("bshgd,bthd->bhgst", q, xk,
                            preferred_element_type=jnp.float32)
        w = jax.nn.softmax(scores / math.sqrt(hd), axis=-1).astype(xv.dtype)
        o = jnp.einsum("bhgst,bthd->bshgd", w, xv).reshape(b, 1, hq * hd)
        return o @ bp["cross_attn"]["wo"]

    def layer_scan(h, xs):
        bp, ck, cv, xk, xv = xs
        a = L.rms_norm(h, bp["ln1"], cfg.norm_eps)
        a, ck, cv = L.decode_attention(
            bp["self_attn"], a, ck, cv, cache["len"], num_heads=hq,
            num_kv_heads=hkv, head_dim=hd, positions=pos)
        h = h + a
        a = L.rms_norm(h, bp["ln_x"], cfg.norm_eps)
        h = h + cross(bp, a, xk, xv)
        m = L.rms_norm(h, bp["ln2"], cfg.norm_eps)
        h = h + L.apply_mlp(bp["mlp"], m, cfg.act)
        return h, (ck, cv)

    x, (nk, nv) = L.scan(
        layer_scan, x, (params["dec_blocks"], cache["k"], cache["v"],
                        cache["xk"], cache["xv"]))
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, 0] @ head_matrix(cfg, params)).astype(jnp.float32)
    return logits, dict(cache, k=nk, v=nv, len=cache["len"] + 1)
