"""Mixture-of-Experts decoder (olmoe-1b-7b, kimi-k2-1t-a32b).

Routing is sort-based ("dropped" capacity MoE, MaxText-style, adapted for
TPU): token->expert assignments are argsorted by expert id, packed into a
dense [E, C, d] buffer (C = capacity), processed with plain einsums (MXU
friendly — no ragged ops), and scattered back. Overflow tokens beyond
capacity are dropped (standard capacity-factor semantics). The expert
dimension shards over the TP axis (EP), so the pack/unpack gathers lower to
all-to-alls under GSPMD.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

CAPACITY_FACTOR = 1.25


# ---------------------------------------------------------------------------
# MoE FFN layer
# ---------------------------------------------------------------------------

def init_moe_ffn(rng, cfg: ModelConfig, dtype):
    d, e = cfg.d_model, cfg.num_experts
    dff = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(rng, 5)
    p = {
        "router": L.dense_init(ks[0], (d, e), jnp.float32, scale=0.02),
        "w_gate": L.dense_init(ks[1], (e, d, dff), dtype),
        "w_up": L.dense_init(ks[2], (e, d, dff), dtype),
        "w_down": L.dense_init(ks[3], (e, dff, d), dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = L.init_mlp(ks[4], d,
                                 cfg.num_shared_experts * dff, cfg.act, dtype)
    return p


def capacity(tokens: int, num_experts: int, k: int) -> int:
    return max(1, math.ceil(k * tokens / num_experts * CAPACITY_FACTOR))


def apply_moe_ffn(p, x, cfg: ModelConfig):
    """x: [B, S, d] -> ([B, S, d], aux_loss).

    cfg.moe_shard_groups > 0 (§Perf): shard-local dispatch — tokens are
    routed within G independent groups (aligned with the data shards), so
    the pack/unpack scatters never address the GLOBAL token buffer and
    GSPMD lowers dispatch to group-local collectives instead of
    all-gathering every token to every chip. Capacity is per group; the
    drop pattern differs only at group boundaries."""
    b, s, d = x.shape
    groups = cfg.moe_shard_groups
    if groups and (b * s) % groups == 0:
        xg = x.reshape(groups, (b * s) // groups, 1, d)
        yg, aux = jax.vmap(lambda xx: _moe_ffn_flat(p, xx, cfg))(xg)
        return yg.reshape(b, s, d), aux.mean()
    return _moe_ffn_flat(p, x, cfg)


def _moe_ffn_flat(p, x, cfg: ModelConfig):
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    c = capacity(t, e, k)
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"])           # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                      # [T, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch-style).
    me = probs.mean(axis=0)                                   # [E]
    ce = jnp.zeros((e,)).at[eidx.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # ---- pack: sort assignments by expert, drop beyond capacity ----
    flat_e = eidx.reshape(t * k)
    sidx = jnp.argsort(flat_e)                                # [T*k]
    sorted_e = flat_e[sidx]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))     # [E]
    pos = jnp.arange(t * k) - seg_start[sorted_e]
    keep = pos < c
    slot = jnp.where(keep, sorted_e * c + jnp.clip(pos, 0, c - 1), e * c)
    src_tok = sidx // k                                       # origin token
    buf = jnp.zeros((e * c + 1, d), x.dtype).at[slot].set(xf[src_tok])
    h = buf[:e * c].reshape(e, c, d)

    # ---- expert computation (dense einsums; E shards over TP axis) ----
    f = L.act_fn(cfg.act)
    a = f(jnp.einsum("ecd,edf->ecf", h, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", h, p["w_up"])
    o = jnp.einsum("ecf,efd->ecd", a, p["w_down"])            # [E, C, d]

    # ---- unpack: gather back, unsort, combine with gates ----
    of = jnp.concatenate([o.reshape(e * c, d),
                          jnp.zeros((1, d), o.dtype)], axis=0)
    y_rep = of[jnp.where(keep, slot, e * c)]                  # dropped -> 0
    y_unsorted = jnp.zeros((t * k, d), x.dtype).at[sidx].set(y_rep)
    y = (y_unsorted.reshape(t, k, d)
         * gate[..., None].astype(x.dtype)).sum(axis=1)

    if "shared" in p:
        y = y + L.apply_mlp(p["shared"], xf, cfg.act)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

def _block_init(rng, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": L.init_attention(k1, cfg.d_model, cfg.num_heads,
                                 cfg.num_kv_heads, cfg.resolved_head_dim,
                                 dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "moe": init_moe_ffn(k2, cfg, dtype),
    }


def init(cfg: ModelConfig, rng) -> dict:
    dtype = L.dtype_of(cfg.dtype)
    k_emb, k_blocks, k_head = jax.random.split(rng, 3)
    ks = jax.random.split(k_blocks, cfg.num_layers)
    p = {
        "embed": L.embed_init(k_emb, (cfg.vocab_size, cfg.d_model), dtype),
        "blocks": jax.vmap(lambda k: _block_init(k, cfg, dtype))(ks),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                    dtype)
    return p


def _block(cfg: ModelConfig, bp, x, positions):
    h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
    h = L.multi_head_attention(
        bp["attn"], h, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
        positions=positions, theta=cfg.rope_theta, causal=True,
        attn_fn=L.pick_attn_fn(cfg, causal=True, window=0))
    x = x + h
    h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
    y, aux = apply_moe_ffn(bp["moe"], h, cfg)
    return x + y, aux


def forward(cfg: ModelConfig, params: dict, tokens):
    x = params["embed"][tokens]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def block_fn(h, bp):
        h, aux = _block(cfg, bp, h, positions)
        return h, aux

    f = L.remat(block_fn, cfg)
    x, auxes = L.scan(f, x, params["blocks"])
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps), auxes.mean()


def head_matrix(cfg: ModelConfig, params: dict):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def loss_fn(cfg: ModelConfig, params: dict, batch: dict):
    h, aux = forward(cfg, params, batch["tokens"])
    loss, cnt = L.chunked_softmax_xent(h, head_matrix(cfg, params),
                                       batch["labels"],
                                       batch.get("loss_mask"))
    return loss + 0.01 * aux, {"tokens": cnt, "aux_loss": aux}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dtype = L.dtype_of(cfg.dtype)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((cfg.num_layers, batch, max_len, hkv, hd), dtype),
        "v": jnp.zeros((cfg.num_layers, batch, max_len, hkv, hd), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens):
    x = params["embed"][tokens]
    b = x.shape[0]
    pos = jnp.broadcast_to(cache["len"][None, None], (b, 1)).astype(jnp.int32)

    def layer_scan(h, xs):
        bp, ck, cv = xs
        a = L.rms_norm(h, bp["ln1"], cfg.norm_eps)
        a, ck, cv = L.decode_attention(
            bp["attn"], a, ck, cv, cache["len"], num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            positions=pos, theta=cfg.rope_theta)
        h = h + a
        m = L.rms_norm(h, bp["ln2"], cfg.norm_eps)
        y, _ = apply_moe_ffn(bp["moe"], m, cfg)
        return h + y, (ck, cv)

    x, (nk, nv) = L.scan(layer_scan, x,
                               (params["blocks"], cache["k"], cache["v"]))
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, 0] @ head_matrix(cfg, params)).astype(jnp.float32)
    return logits, dict(cache, k=nk, v=nv, len=cache["len"] + 1)


def prefill(cfg: ModelConfig, params: dict, tokens, max_len: int = 0):
    b, s = tokens.shape
    cap = max_len or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def layer_fn(h, bp):
        a = L.rms_norm(h, bp["ln1"], cfg.norm_eps)
        k = L.apply_rope((a @ bp["attn"]["wk"]).reshape(b, s, hkv, hd),
                         positions, cfg.rope_theta)
        v = (a @ bp["attn"]["wv"]).reshape(b, s, hkv, hd)
        a = L.multi_head_attention(
            bp["attn"], a, num_heads=cfg.num_heads, num_kv_heads=hkv,
            head_dim=hd, positions=positions, theta=cfg.rope_theta,
            causal=True)
        h = h + a
        m = L.rms_norm(h, bp["ln2"], cfg.norm_eps)
        y, _ = apply_moe_ffn(bp["moe"], m, cfg)
        pad = ((0, 0), (0, cap - s), (0, 0), (0, 0))
        return h + y, (jnp.pad(k, pad), jnp.pad(v, pad))

    x, (ck, cv) = L.scan(layer_fn, params["embed"][tokens],
                               params["blocks"])
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, -1] @ head_matrix(cfg, params)).astype(jnp.float32)
    return logits, {"k": ck, "v": cv, "len": jnp.asarray(s, jnp.int32)}
