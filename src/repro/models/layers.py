"""Shared pure-JAX building blocks for the model zoo.

Functional style: params are nested dicts of jnp arrays; every layer is a
pair of (init_*, apply) functions. No flax/haiku — the substrate is built
from scratch per the reproduction scope.
"""
from __future__ import annotations

import contextlib
import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]

# ---------------------------------------------------------------------------
# Layer-scan control. Production uses lax.scan (flat HLO, fast compiles at
# 1000-node scale); the dry-run unrolls so XLA cost_analysis counts every
# trip (while-loop bodies are otherwise costed ONCE — see launch/roofline).
# ---------------------------------------------------------------------------

_SCAN_UNROLL = False


@contextlib.contextmanager
def scan_unroll(enable: bool = True):
    global _SCAN_UNROLL
    prev = _SCAN_UNROLL
    _SCAN_UNROLL = enable
    try:
        yield
    finally:
        _SCAN_UNROLL = prev


def remat(f, cfg):
    """Activation-checkpoint policy selector (cfg.remat):
    none | block (nothing_saveable; recompute everything) |
    dots (save matmul outputs — less recompute, more memory; §Perf)."""
    if cfg.remat == "none":
        return f
    if cfg.remat == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)


def scan(f, init, xs, length=None, *, unroll_ok: bool = True):
    """lax.scan that fully unrolls under ``scan_unroll()`` (dry-run cost
    accounting). Token-sequential recurrences pass unroll_ok=False.

    The unrolled path is hand-rolled (static slices in, ONE stack out)
    rather than lax.scan(unroll=True): scan-emitted unrolling updates the
    stacked ys/carry buffers with dynamic-update-slice per step, which
    XLA's cost model charges at full-buffer size per step — a ~L x
    overcount of HBM bytes for decode caches that are updated in place on
    real hardware. Static slice + single stack is charged once, matching
    the TPU execution."""
    if not (_SCAN_UNROLL and unroll_ok):
        return jax.lax.scan(f, init, xs, length=length)
    n = length if xs is None else jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        xi = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = f(carry, xi)
        ys.append(y)
    stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys) if ys else None
    return carry, stacked


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(rng, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


def embed_init(rng, shape, dtype):
    return (jax.random.normal(rng, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)
            + beta.astype(jnp.float32)).astype(dt)


def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":                      # nemotron squared ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                        # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * inv   # [..., S, hd/2]
    ang = ang[..., None, :]                            # [..., S, 1, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float,
                sections: tuple[int, int, int] = (2, 1, 1)):
    """Qwen2-VL multimodal RoPE. positions3: [3, ..., S] (t, h, w) ids.

    The head_dim/2 frequency slots are split into (t, h, w) sections in the
    given ratio; each section rotates by its own position stream.
    """
    hd = x.shape[-1]
    half = hd // 2
    tot = sum(sections)
    bounds = [half * sections[0] // tot,
              half * (sections[0] + sections[1]) // tot]
    inv = rope_freqs(hd, theta)                        # [half]
    sect = jnp.zeros((half,), jnp.int32)
    sect = sect.at[bounds[0]:bounds[1]].set(1).at[bounds[1]:].set(2)
    # pick the position stream per frequency slot
    p3 = jnp.moveaxis(positions3, 0, -1)               # [..., S, 3]
    pos = p3[..., sect]                                # [..., S, half]
    ang = pos.astype(jnp.float32) * inv
    ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, dim: int):
    """Whisper-style fixed sinusoidal embeddings."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    idx = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (idx / max(dim // 2 - 1, 1)))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention (reference path — the Pallas kernel mirrors this math)
# ---------------------------------------------------------------------------

def init_attention(rng, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, dtype) -> Params:
    ks = jax.random.split(rng, 4)
    return {
        "wq": dense_init(ks[0], (d_model, num_heads * head_dim), dtype),
        "wk": dense_init(ks[1], (d_model, num_kv_heads * head_dim), dtype),
        "wv": dense_init(ks[2], (d_model, num_kv_heads * head_dim), dtype),
        "wo": dense_init(ks[3], (num_heads * head_dim, d_model), dtype),
    }


def gqa_scores_mask(q_len: int, kv_len: int, *, causal: bool,
                    window: int, q_offset=0):
    """Boolean [q_len, kv_len] mask. q_offset: absolute pos of q[0]."""
    qp = jnp.arange(q_len)[:, None] + q_offset
    kp = jnp.arange(kv_len)[None, :]
    m = jnp.ones((q_len, kv_len), bool)
    if causal:
        m &= kp <= qp
    if window:
        m &= kp > qp - window
    return m


def multi_head_attention(p: Params, x, *, num_heads: int, num_kv_heads: int,
                         head_dim: int, positions=None, theta: float = 1e4,
                         causal: bool = True, window: int = 0,
                         mrope_positions=None, kv_x=None,
                         attn_fn=None) -> jnp.ndarray:
    """Full-sequence GQA attention. x: [B, S, D]. kv_x: cross-attn source."""
    b, s, _ = x.shape
    src = x if kv_x is None else kv_x
    sk = src.shape[1]
    q = (x @ p["wq"]).reshape(b, s, num_heads, head_dim)
    k = (src @ p["wk"]).reshape(b, sk, num_kv_heads, head_dim)
    v = (src @ p["wv"]).reshape(b, sk, num_kv_heads, head_dim)
    if mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, theta)
        k = apply_mrope(k, mrope_positions, theta)
    elif positions is not None:
        q = apply_rope(q, positions, theta)
        if kv_x is None:
            k = apply_rope(k, positions, theta)
    mask = None
    if kv_x is None and (causal or window):
        mask = gqa_scores_mask(s, sk, causal=causal, window=window)
    if attn_fn is not None:
        o = attn_fn(q, k, v, mask)
    else:
        o = gqa_attention_ref(q, k, v, mask)
    return o.reshape(b, s, num_heads * head_dim) @ p["wo"]


def pick_attn_fn(cfg, *, causal: bool, window: int):
    """Full-sequence attention backend selector: None (jnp reference,
    XLA-visible for the dry-run cost model) or the Pallas flash kernel
    (cfg.use_flash_kernel; the TPU hot-spot path — interpret mode on
    CPU). The kernel takes the same post-RoPE q/k/v layout."""
    if not getattr(cfg, "use_flash_kernel", False):
        return None

    def flash(q, k, v, mask):            # mask encoded via causal/window
        from repro.kernels import ops
        return ops.flash_attention(q, k, v, causal=causal, window=window)

    return flash


def gqa_attention_ref(q, k, v, mask=None):
    """Reference attention. q: [B,S,Hq,hd]; k,v: [B,Sk,Hkv,hd]."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    q = q.reshape(b, s, hkv, g, hd)
    scores = jnp.einsum("bshgd,bthd->bhgst", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhgst,bthd->bshgd", w, v)
    return o.reshape(b, s, hq, hd)


def decode_attention(p: Params, x, cache_k, cache_v, cache_len, *,
                     num_heads: int, num_kv_heads: int, head_dim: int,
                     positions=None, theta: float = 1e4, window: int = 0):
    """Single-step decode with KV cache.

    x: [B, 1, D]; cache_k/v: [B, C, Hkv, hd] (C = window or max_len);
    cache_len: scalar current length (== absolute position of the new token).
    Window layers use a rolling cache of size C=window.
    Returns (out [B,1,D], new_k, new_v).
    """
    b = x.shape[0]
    cap = cache_k.shape[1]
    q = (x @ p["wq"]).reshape(b, 1, num_heads, head_dim)
    k = (x @ p["wk"]).reshape(b, 1, num_kv_heads, head_dim)
    v = (x @ p["wv"]).reshape(b, 1, num_kv_heads, head_dim)
    if positions is not None:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    slot = jnp.mod(cache_len, cap) if window else jnp.minimum(cache_len,
                                                              cap - 1)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
    # valid slots: rolling (window) or prefix (full)
    idx = jnp.arange(cap)
    if window:
        valid = idx < jnp.minimum(cache_len + 1, cap)
    else:
        valid = idx <= slot
    hkv, g = num_kv_heads, num_heads // num_kv_heads
    qr = q.reshape(b, 1, hkv, g, head_dim)
    scores = jnp.einsum("bshgd,bthd->bhgst", qr, cache_k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(head_dim)
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(cache_v.dtype)
    o = jnp.einsum("bhgst,bthd->bshgd", w, cache_v)
    o = o.reshape(b, 1, num_heads * head_dim) @ p["wo"]
    return o, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(rng, d_model: int, d_ff: int, act: str, dtype) -> Params:
    ks = jax.random.split(rng, 3)
    p = {"w_up": dense_init(ks[0], (d_model, d_ff), dtype),
         "w_down": dense_init(ks[1], (d_ff, d_model), dtype)}
    if act in ("silu", "gelu"):           # gated variants
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype)
    return p


def apply_mlp(p: Params, x, act: str):
    f = act_fn(act)
    if "w_gate" in p:
        return (f(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return f(x @ p["w_up"]) @ p["w_down"]


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def chunked_softmax_xent(h, w_emb, labels, mask=None, chunk: int = 512):
    """Cross-entropy over a huge vocab without materializing [B,S,V] at once.

    h: [B, S, D] final hidden states; w_emb: [D, V]; labels: [B, S] int32.
    Scans over sequence chunks — peak logits memory is [B, chunk, V].
    Returns (mean_loss, token_count).
    """
    b, s, d = h.shape
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    n_chunks = max(s // chunk, 1)
    chunk = s // n_chunks
    hc = h.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    mc = mask.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    # checkpoint: recompute the [B, chunk, V] logits in the backward pass
    # instead of saving them (peak logits memory = ONE chunk, fwd and bwd)
    @jax.checkpoint
    def body(carry, xs):
        hx, lx, mx = xs
        logits = (hx @ w_emb).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        loss = ((logz - gold) * mx).sum()
        return (carry[0] + loss, carry[1] + mx.sum()), None

    (tot, cnt), _ = scan(body, (jnp.float32(0), jnp.float32(0)),
                         (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0), cnt
