"""SGD / momentum / AdamW and LR schedules, as pure (init, update) pairs.

update(grads, state, params) -> (updates, new_state); apply with
``jax.tree.map(lambda p, u: p + u, params, updates)``. Updates are cast
to the param dtype at the end (master math in f32).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]   # step -> lr


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]


def _as_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def exponential_decay(init_lr: float, decay: float,
                      steps_per_decay: int = 1) -> Schedule:
    """Paper Sec. V-C: lr_0 * decay^round (0.1/0.98 CNN, 0.1/0.993 others)."""
    def sched(step):
        return jnp.asarray(
            init_lr * decay ** (step / steps_per_decay), jnp.float32)
    return sched


def sgd(lr) -> Optimizer:
    """Plain SGD — the paper's DSGD local update (Eq. 3). State = step only."""
    sched = _as_schedule(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        eta = sched(state["step"])
        updates = jax.tree.map(
            lambda g: (-eta * g.astype(jnp.float32)), grads)
        updates = _cast_like(updates, params)
        return updates, {"step": state["step"] + 1}

    return Optimizer(init, update)


def momentum_sgd(lr, momentum: float = 0.9,
                 nesterov: bool = False) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params=None):
        eta = sched(state["step"])
        m = jax.tree.map(lambda mm, g: momentum * mm + g.astype(jnp.float32),
                         state["m"], grads)
        if nesterov:
            upd = jax.tree.map(
                lambda mm, g: -(eta * (momentum * mm + g.astype(jnp.float32))),
                m, grads)
        else:
            upd = jax.tree.map(lambda mm: -eta * mm, m)
        upd = _cast_like(upd, params)
        return upd, {"step": state["step"] + 1, "m": m}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        eta = sched(state["step"])
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) *
                         g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) *
                         jnp.square(g.astype(jnp.float32)), state["v"],
                         grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(mm, vv, p):
            mhat = mm / bc1
            vhat = vv / bc2
            u = -eta * (mhat / (jnp.sqrt(vhat) + eps)
                        + weight_decay * p.astype(jnp.float32))
            return u
        updates = jax.tree.map(upd, m, v, params)
        updates = _cast_like(updates, params)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def _cast_like(updates, params):
    if params is None:
        return updates
    return jax.tree.map(lambda u, p: u.astype(p.dtype), updates, params)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))
