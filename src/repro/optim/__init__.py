"""Pure-JAX optimizers (optax-like (init, update) pairs) + LR schedules.

The paper's DSGD is plain SGD (Eq. 3-4): state-free, which is what makes
the trillion-param archs fit (DESIGN.md §4). Momentum-SGD and AdamW are
provided for the beyond-paper experiments.
"""
from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adamw,
    exponential_decay,
    momentum_sgd,
    sgd,
)
