"""The paper's synthesized non-IID partitioner (Sec. V-A).

"p of a unique class is divided equally for every three workers and the
remaining samples of each class are partitioned to other workers
uniformly." p=0.1..0.8 are the paper's non-IID levels; p = 1/(N/3) is the
IID special case (paper: p=0.1 with N=30).
"""
from __future__ import annotations

import numpy as np

GROUP = 3      # the paper pins each class to a group of three workers


def pskew_partition(labels: np.ndarray, num_workers: int, p: float,
                    rng: np.random.Generator) -> list[np.ndarray]:
    """Return per-worker index arrays implementing the paper's p-skew.

    Class c is pinned to worker group g(c) = (c*GROUP ... c*GROUP+2) mod N;
    a p-fraction of its samples goes equally to that group, the rest is
    spread uniformly over the remaining workers.
    """
    labels = np.asarray(labels)
    n = num_workers
    shards: list[list[np.ndarray]] = [[] for _ in range(n)]
    classes = np.unique(labels)
    for c in classes:
        idx = np.nonzero(labels == c)[0]
        rng.shuffle(idx)
        group = [(int(c) * GROUP + k) % n for k in range(GROUP)]
        others = [w for w in range(n) if w not in group]
        cut = int(round(p * len(idx)))
        pinned, rest = idx[:cut], idx[cut:]
        for k, part in enumerate(np.array_split(pinned, GROUP)):
            shards[group[k]].append(part)
        if others:
            for k, part in enumerate(np.array_split(rest, len(others))):
                shards[others[k]].append(part)
        else:                       # tiny N: spread rest over the group too
            for k, part in enumerate(np.array_split(rest, GROUP)):
                shards[group[k]].append(part)
    out = []
    for w in range(n):
        ix = (np.concatenate(shards[w]) if shards[w]
              else np.empty((0,), np.int64))
        rng.shuffle(ix)
        out.append(ix)
    return out


def label_histogram(labels: np.ndarray, shards: list[np.ndarray],
                    num_classes: int) -> np.ndarray:
    """(N, C) per-worker class histogram — used by tests and by the PENS
    baseline's similarity oracle."""
    h = np.zeros((len(shards), num_classes), np.int64)
    for w, ix in enumerate(shards):
        cls, cnt = np.unique(labels[ix], return_counts=True)
        h[w, cls.astype(int)] = cnt
    return h
