"""The paper's synthesized non-IID partitioner (Sec. V-A).

"p of a unique class is divided equally for every three workers and the
remaining samples of each class are partitioned to other workers
uniformly." p=0.1..0.8 are the paper's non-IID levels; p = 1/(N/3) is the
IID special case (paper: p=0.1 with N=30).
"""
from __future__ import annotations

import numpy as np

GROUP = 3      # the paper pins each class to a group of three workers


def pskew_partition(labels: np.ndarray, num_workers: int, p: float,
                    rng: np.random.Generator,
                    shift: int = 0) -> list[np.ndarray]:
    """Return per-worker index arrays implementing the paper's p-skew.

    Class c is pinned to worker group g(c) = (c*GROUP+shift ...
    c*GROUP+shift+2) mod N; a p-fraction of its samples goes equally to
    that group, the rest is spread uniformly over the remaining workers.
    ``shift`` rotates the class -> group pinning across the fleet — the
    time-varying non-IID drift axis (``DriftingPartition`` steps it on a
    schedule; shift=0 is the paper's static assignment).
    """
    labels = np.asarray(labels)
    n = num_workers
    shards: list[list[np.ndarray]] = [[] for _ in range(n)]
    classes = np.unique(labels)
    for c in classes:
        idx = np.nonzero(labels == c)[0]
        rng.shuffle(idx)
        group = [(int(c) * GROUP + shift + k) % n for k in range(GROUP)]
        others = [w for w in range(n) if w not in group]
        cut = int(round(p * len(idx)))
        pinned, rest = idx[:cut], idx[cut:]
        for k, part in enumerate(np.array_split(pinned, GROUP)):
            shards[group[k]].append(part)
        if others:
            for k, part in enumerate(np.array_split(rest, len(others))):
                shards[others[k]].append(part)
        else:                       # tiny N: spread rest over the group too
            for k, part in enumerate(np.array_split(rest, GROUP)):
                shards[group[k]].append(part)
    out = []
    for w in range(n):
        ix = (np.concatenate(shards[w]) if shards[w]
              else np.empty((0,), np.int64))
        rng.shuffle(ix)
        out.append(ix)
    return out


class DriftingPartition:
    """Time-varying non-IID drift: the label distribution rotates across
    the group assignment on a schedule.

    ``shards_at(h)`` returns the fleet's shards for round ``h``, computed
    as ``pskew_partition(..., shift = h // period)`` — every ``period``
    rounds the class -> worker-group pinning rotates one worker over the
    fleet, so each worker's local distribution slowly cycles through the
    classes while the global distribution stays fixed. Each distinct
    shift's draw comes from its own seeded RNG (``seed + shift``), so a
    shift's shards are a pure function of (labels, num_workers, p, seed,
    shift) — both engines replaying the same rounds see the same shards.
    Results are cached per effective shift (``shift % num_workers``:
    the rotation is periodic in the fleet size).

    Engines accept either a plain shard list or this object wherever
    ``shards`` flows; the eval batches always come from ``shards_at(0)``
    so metrics stay comparable across the run.
    """

    def __init__(self, labels: np.ndarray, num_workers: int, p: float,
                 seed: int, period: int):
        if period <= 0:
            raise ValueError(f"drift period must be positive, got {period}")
        self.labels = np.asarray(labels)
        self.num_workers = num_workers
        self.p = p
        self.seed = seed
        self.period = period
        self._cache: dict[int, list[np.ndarray]] = {}

    def shift_at(self, h: int) -> int:
        """Effective rotation of round ``h`` (drift steps every period)."""
        return (h // self.period) % self.num_workers

    def shards_at(self, h: int) -> list[np.ndarray]:
        """Per-worker index arrays in force at round ``h``."""
        s = self.shift_at(h)
        if s not in self._cache:
            rng = np.random.default_rng(self.seed + s)
            self._cache[s] = pskew_partition(self.labels, self.num_workers,
                                             self.p, rng, shift=s)
        return self._cache[s]

    def __len__(self) -> int:
        return self.num_workers

    def __getitem__(self, w: int) -> np.ndarray:
        # round-0 view: lets drift-unaware consumers (eval batches,
        # AD-PSGD) treat the object as a static shard list
        return self.shards_at(0)[w]

    def __iter__(self):
        return iter(self.shards_at(0))


def label_histogram(labels: np.ndarray, shards: list[np.ndarray],
                    num_classes: int) -> np.ndarray:
    """(N, C) per-worker class histogram — used by tests and by the PENS
    baseline's similarity oracle."""
    h = np.zeros((len(shards), num_classes), np.int64)
    for w, ix in enumerate(shards):
        cls, cnt = np.unique(labels[ix], return_counts=True)
        h[w, cls.astype(int)] = cnt
    return h
