"""Synthetic datasets standing in for EMNIST/CIFAR-10/IMAGE-100 (offline
container) plus LM token streams for the assigned architectures.

The classification task is a Gaussian-mixture blob problem: class c is a
Gaussian at a random center; a small MLP separates them. Crucially the
per-class structure makes the paper's p-skew partition produce genuinely
non-IID worker shards, reproducing the statistical-heterogeneity axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass
class Dataset:
    x: np.ndarray          # [N, dim] features (or [N, S] int tokens)
    y: np.ndarray          # [N] labels (or [N, S] next-token labels)
    num_classes: int


def make_classification_data(num_samples: int = 6000, dim: int = 32,
                             num_classes: int = 10, *, spread: float = 1.0,
                             seed: int = 0) -> Dataset:
    rng = np.random.default_rng(seed)
    centers = rng.normal(0.0, 2.0, (num_classes, dim))
    y = rng.integers(0, num_classes, num_samples)
    x = centers[y] + rng.normal(0.0, spread, (num_samples, dim))
    return Dataset(x.astype(np.float32), y.astype(np.int32), num_classes)


def make_token_data(num_sequences: int = 512, seq_len: int = 128,
                    vocab_size: int = 256, *, num_classes: int = 8,
                    seed: int = 0) -> Dataset:
    """Synthetic LM corpus with class structure: each "document class" is a
    distinct first-order Markov chain, so p-skew partitions are non-IID."""
    rng = np.random.default_rng(seed)
    # one random band-diagonal transition matrix per class
    trans = []
    for c in range(num_classes):
        t = rng.random((vocab_size, vocab_size)) ** 4
        roll = rng.integers(1, vocab_size)
        t += 4.0 * np.eye(vocab_size)[:, np.roll(np.arange(vocab_size), roll)]
        trans.append(t / t.sum(1, keepdims=True))
    y = rng.integers(0, num_classes, num_sequences)
    x = np.zeros((num_sequences, seq_len), np.int32)
    x[:, 0] = rng.integers(0, vocab_size, num_sequences)
    u = rng.random((num_sequences, seq_len))
    for s in range(1, seq_len):
        for c in range(num_classes):
            m = y == c
            if not m.any():
                continue
            cum = np.cumsum(trans[c][x[m, s - 1]], axis=1)
            x[m, s] = (u[m, s][:, None] < cum).argmax(axis=1)
    return Dataset(x, y.astype(np.int32), num_classes)


def worker_batch_iterator(data: Dataset, shard: np.ndarray, batch_size: int,
                          seed: int = 0) -> Iterator[dict]:
    """Infinite shuffled mini-batch iterator over one worker's shard."""
    rng = np.random.default_rng(seed)
    if len(shard) == 0:
        raise ValueError("empty shard")
    while True:
        order = rng.permutation(len(shard))
        for lo in range(0, len(order) - batch_size + 1, batch_size):
            ix = shard[order[lo:lo + batch_size]]
            yield {"x": data.x[ix], "y": data.y[ix]}
        if len(order) < batch_size:        # shard smaller than a batch
            ix = shard[rng.integers(0, len(shard), batch_size)]
            yield {"x": data.x[ix], "y": data.y[ix]}
