"""Data substrate: synthetic datasets, the paper's p-skew non-IID
partitioner (Sec. V-A), and per-worker shard iterators."""
from repro.data.partition import pskew_partition, label_histogram  # noqa: F401
from repro.data.synthetic import (  # noqa: F401
    make_classification_data,
    make_token_data,
    worker_batch_iterator,
)
