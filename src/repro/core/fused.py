"""Fused scan-based DFL engines — the fast paths next to ``run_dfl``
and ``run_adpsgd``.

``run_dfl_fused`` executes whole blocks of rounds on device inside one
``jax.lax.scan`` instead of the reference engine's one Python iteration
(~10 dispatches + host syncs) per round:

- Static-plan baselines (D-PSGD ring, LD-SGD alternation, the plain base
  strategy) fuse the entire horizon into a single scan.
- Adaptive strategies (FedHP, PENS) scan in segments of
  ``cfg.replan_every`` rounds; measurements (Alg. 1 lines 4-5) surface to
  the host only at segment boundaries, where the strategy's
  ``observe``/``plan`` cycle is replayed round by round. With
  ``replan_every=1`` the fused engine replans every round exactly like
  the reference; larger segments freeze (A^h, tau^h) within a segment —
  a documented behavioral deviation bought for throughput (README.md).
- Gossip (Eq. 5-6) runs through the Pallas ``gossip_mix_2d`` kernel on
  the flattened [W, P] parameter matrix; the kernel's padding shim means
  P need not be a tile multiple, so real model sizes work.
- ``cfg.gossip == "sparse"`` swaps the dense [W, W] mixing for the
  edge-list path: per-round directed edge arrays (padded to a static
  E_max with zero-weight no-op edges) ride the scan instead of [K, W, W]
  mixing matrices, and the mix runs through the
  ``kernels/gossip_edges.py`` gather-mix-scatter kernel — O(E P) per
  round instead of O(W² P), which is what lets W scale past the dense
  wall (composes with churn masks, every codec, and ``seeds=``).
- Churn masks (alive / joined / donor weights) become traced arrays
  threaded through the scan — join re-init, metric masking and mixing all
  happen on device. The schedule itself is replayed host-side so the
  cluster's RNG stream matches the reference engine draw for draw.
- ``seeds=jnp.arange(S)`` adds a ``jax.vmap`` axis over model-init /
  batch-sampling seeds: S experiments amortize one scan (sweep workloads
  like benchmarks/hillclimb.py). Static-plan strategies only — an
  adaptive plan is feedback from one seed's trajectory.
- ``cfg.compress`` ("int8" / "topk:<k>" / "randk:<k>") swaps the gossip
  for the codec's compensated update (core/compression.py): per-worker
  error-feedback residuals ride in the scan carry, the wire round trip
  runs through the Pallas kernels on the [W, P] layout
  (``quantize_block_2d``/``dequantize_block_2d`` for int8,
  ``sparsify_block_2d`` mask-and-pack for top-k / rand-k), and Eq. 10
  charges comm time / the codec's wire_ratio — composing with churn
  masks, the vmapped ``seeds`` axis, and FedHP's per-plan codec
  tightening (``RoundPlan.codec``, frozen per segment).

``run_adpsgd_fused`` does the same for the event-driven AD-PSGD
baseline: the host precomputes the full event schedule
(``engine.adpsgd_schedule`` — partners, event clocks, staleness) and the
scan replays every event with snapshots, int8 residuals and staleness
counters carried in the scan state, pairwise-averaging through the
Pallas ``gossip_mix_2d`` kernel on a 2-row slice.

Interchangeability with the reference engines is proven by the
differential harness in ``tests/test_fused_equivalence.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import FedHPConfig
from repro.core import compression
from repro.core import modelspec
from repro.core import robust as robust_agg
from repro.core import topology as topo
from repro.core.algorithms import Strategy
from repro.core.engine import (AdpsgdSchedule, History, RoundRecord,
                               _adpsgd_delta, _blend_joined,
                               _cross_loss_matrix, _draw_batches,
                               _flatten_row, _flatten_workers,
                               _measure_worker, _sgd_worker,
                               _unflatten, _unflatten_row, adpsgd_schedule)
from repro.data.synthetic import Dataset
from repro.kernels.gossip_edges import gossip_edges
from repro.kernels.gossip_mix import gossip_mix_2d
from repro.kernels.robust_gossip import robust_gossip
from repro.runtime.collectives import (_shard_map, edge_shard_tables,
                                       routed_mix_delta)
from repro.runtime.sharding import worker_stack_pspecs, worker_stack_spec
from repro.simulation.cluster import SimCluster

# static-plan strategies would otherwise stage the whole horizon's batch
# tensors host-side at once ([S, K, W, tau, B, D] f32); chunking the scan
# bounds that at ~64 rounds per dispatch with no semantic difference
# (static plans are recomputed per round either way)
MAX_FUSE_ROUNDS = 64

# AD-PSGD stages one batch tensor PER EVENT ([S, K, N, tau, B, D] — an
# extra N factor over the synchronous engine), so its segments are shorter
ADPSGD_FUSE_ROUNDS = 32


# ---------------------------------------------------------------------------
# device code: one scan over the rounds of a segment
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("adapter", "tau_cap", "measure",
                                   "needs_cross", "interpret", "kind", "k",
                                   "ef", "sparse", "lcodec", "robust", "rb",
                                   "attack"))
def _scan_segment(stacked, err, bx, by, ex, ey, px, py, taus, lrs, mixes,
                  esrc, edst, ewt, comms, ew, cw, keep, rw, hs, nbrs, degs,
                  byz, atk_scale, skey, gamma, tx, ty, *, adapter,
                  tau_cap: int, measure: bool, needs_cross: bool,
                  interpret: bool, kind: str, k: int, ef: bool, sparse: bool,
                  lcodec=None, robust: str = "none", rb: float = 0.0,
                  attack: str = ""):
    """Run K rounds on device. Batched over a leading seed axis S on
    (stacked, err, bx, by, ex, ey, px, py); control inputs (taus .. rw
    plus the round indices ``hs``, all [K]-leading), the rand-k mask key
    ``skey`` and the test set are shared across seeds. ``adapter`` (a
    hashable ``modelspec.ModelAdapter``) supplies loss/accuracy — the
    scan itself only sees the flattened [W, P] layout.

    ``err`` is the [S, W, P] error-feedback residual carried as scan
    state on compressed runs (untouched otherwise); ``kind``/``k`` name
    the segment's wire codec ("none" uncompressed — a frozen adaptive
    plan fixes the codec for the whole segment). ``lcodec`` is the
    segment's compiled per-leaf codec map when ``kind == "leafmap"``
    (None otherwise) — its shared oracle payload keeps reference and
    fused leafmap trajectories bit-identical by construction.

    ``sparse`` selects the edge-list gossip path: the round topology
    arrives as directed edge arrays (``esrc``/``edst``/``ewt``,
    [K, E_max] padded with zero-weight edges — exact no-ops), the mixing
    delta runs through the ``kernels/gossip_edges.py`` gather-mix-scatter
    kernel on [W, P], and ``mixes`` is a [K, 1, 1] dummy (no dense
    [W, W] matrix is ever staged). Dense mode carries [K, 8] edge
    dummies instead.

    The Byzantine scenario axis rides the scan too: ``attack`` (the
    attack kind, "" for an honest fleet) makes byzantine rows (``byz``,
    [W] bool shared across seeds) transmit a corrupted wire copy
    (``core/robust.apply_attack`` scaled by ``atk_scale``), and
    ``robust`` ("trimmed"/"median" with trim knob ``rb``) replaces the
    weighted mix with the coordinate-wise robust aggregation over the
    per-round padded neighbor tables (``nbrs``/``degs``, [K, W, Dp] /
    [K, W]) through the Pallas ``kernels/robust_gossip.py``
    gather-sort-trim kernel — robust rounds gather their own dense
    window, so dense and sparse gossip share one lowering. Honest
    uncompressed rounds never touch any of this (dead static branches).

    Returns ((stacked', err'), outs) where outs is a dict of [S, K, ...]
    metric trajectories.
    """
    leafmap = lcodec is not None
    compress = kind != "none" and not leafmap
    # which codecs evolve the state buffer (int8 residual / top-k x̂) —
    # rand-k carries nothing; mirrors compression.carries_state so the
    # scan state matches the reference engine bit for bit
    stateful = compress and compression.carries_state(kind, ef)
    leaves = jax.tree.leaves(stacked)
    p_total = sum(int(np.prod(l.shape[2:])) for l in leaves)
    rows, cols = compression.flat_tile_shape(p_total)

    def one_seed(stacked, err, bx, by, ex, ey, px, py):

        def body(carry, xs):
            carry, err_c = carry
            (bxh, byh, tau_h, lr_h, mix_h, src_h, dst_h, wgt_h, comm_h,
             ew_h, cw_h, keep_h, rw_h, h_h, nbr_h, deg_h) = xs

            def mix_delta(v):
                # (W @ v - v): through the edge kernel when sparse (zero-
                # weight padding edges make no-comm rounds exact no-ops),
                # dense tensordot otherwise
                if sparse:
                    return gossip_edges(v, src_h, dst_h, wgt_h,
                                        interpret=interpret) - v
                return jnp.tensordot(mix_h, v, axes=1) - v

            # --- join re-init: the reference's _reinit_joined with
            # (keep, donor weights) precomputed host-side; an all-False
            # keep_h makes the blend an exact no-op ---
            carry = _blend_joined(carry, keep_h, rw_h)
            if stateful:
                # joined rows adopt a blended model; their codec state
                # resets the same way as in the reference engine (zeroed
                # residual / x̂ re-anchored at the blended row)
                err_c = compression.state_after_join(
                    err_c, keep_h[:, None], _flatten_workers(carry),
                    kind, ef)
            elif leafmap:
                err_c = compression.leafmap_state_after_join(
                    err_c, keep_h[:, None], _flatten_workers(carry),
                    lcodec, ef)
            prev = carry

            # --- local updating (Eq. 3), masked to tau_i — the SAME
            # per-worker step function the reference engine vmaps ---
            carry = jax.vmap(
                lambda p, bxw, byw, tau: _sgd_worker(adapter, p, bxw, byw,
                                                     tau, lr_h, tau_cap))(
                carry, bxh, byh, tau_h)

            flat = _flatten_workers(carry)
            if robust != "none":
                # --- robust aggregation (core/robust.py lowered): the
                # wire carries the (possibly corrupted) transmitted copy;
                # each worker sort-trims its gathered closed neighborhood
                # through the Pallas gather-sort-trim kernel. No-comm
                # rounds carry all-zero degrees (keep-own-row) and are
                # additionally comm_h-gated to the reference's skipped
                # gossip — an exact no-op either way ---
                transmitted = (robust_agg.apply_attack(
                    flat, byz, atk_scale, kind=attack) if attack else flat)
                mixed = robust_gossip(flat, transmitted, nbr_h, deg_h,
                                      b=rb, mode=robust,
                                      interpret=interpret)
                y_flat = jnp.where(comm_h > 0, mixed, flat)
            elif attack:
                # --- plain (non-robust) mixing of a lying wire — the
                # attacked baseline the robust modes are measured
                # against: Eq. 5 consumes the transmitted copies ---
                transmitted = robust_agg.apply_attack(flat, byz, atk_scale,
                                                      kind=attack)
                if sparse:
                    mixed = robust_agg.gossip_byz_edges(
                        flat, transmitted, src_h, dst_h, wgt_h)
                else:
                    mixed = robust_agg.gossip_byz_dense(flat, transmitted,
                                                        mix_h)
                y_flat = jnp.where(comm_h > 0, mixed, flat)
            elif leafmap:
                # --- per-leaf codec map: the SAME shared payload round
                # trip as the reference (compression.leafmap_payload),
                # one mixing delta on the combined payload, per-segment
                # gamma damping, comm_h gating both params and codec
                # state to an exact no-op on no-communication rounds ---
                payload, new_err = compression.leafmap_payload(
                    flat, err_c, lcodec, error_feedback=ef, key=skey,
                    step=h_h)
                err_c = jnp.where(comm_h > 0, new_err, err_c)
                gmask = jnp.asarray(
                    compression.leafmap_gamma_mask(lcodec, ef))
                gvec = gmask * gamma + (1.0 - gmask)
                y_flat = flat + comm_h * gvec[None, :] * mix_delta(payload)
            elif kind == "topk" and ef:
                # --- x̂-tracked top-k (ChocoSGD form, the same update as
                # compression.compressed_gossip_ref): the wire carries
                # the top-k innovation against the tracked public copy,
                # through the Pallas sparsify kernel; the damped
                # consensus step mixes the advanced copies. comm_h gates
                # no-communication rounds to an exact no-op (nothing is
                # sent: neither params nor x̂ move) ---
                q = compression.sparsify_rows(flat - err_c, "topk", k,
                                              use_kernel=True,
                                              interpret=interpret)
                xhat = err_c + q
                err_c = jnp.where(comm_h > 0, xhat, err_c)
                y_flat = flat + comm_h * gamma * mix_delta(xhat)
            elif compress:
                # --- int8 / rand-k / naive top-k: the codec round trip
                # of z = x + e per worker through the Pallas kernels on
                # the [W, rows, cols] layout (quantize/dequantize or the
                # sparsify mask-and-pack), then the same tensordot mixing
                # of ŷ as the reference's _gossip_compressed, with comm_h
                # gating as above ---
                z = flat + err_c if stateful else flat
                yhat = compression.encode_rows(z, kind, k, key=skey,
                                               step=h_h, use_kernel=True,
                                               interpret=interpret)
                if stateful:
                    err_c = jnp.where(comm_h > 0, z - yhat, err_c)
                y_flat = flat + comm_h * mix_delta(yhat)
            elif sparse:
                # --- sparse gossip (Eq. 5-6) through the edge kernel on
                # [W, P]: y_i = x_i + sum_e w_e (x_src - x_i) over the
                # round's directed edges; no-communication rounds carry
                # all-zero-weight edges — an exact no-op ---
                y_flat = gossip_edges(flat, src_h, dst_h, wgt_h,
                                      interpret=interpret)
            else:
                # --- gossip (Eq. 5-6) through the Pallas kernel on
                # [W, R, C]. Row i of the mixing matrix becomes the
                # kernel's neighbor weights: y_i = x_i + sum_j w_ij
                # (x_j - x_i) = sum_j w_ij x_j for a row-stochastic mix;
                # rounds without communication carry an identity mix,
                # which the kernel maps to an exact no-op ---
                x2 = jnp.pad(flat, ((0, 0), (0, rows * cols - p_total)))
                x2 = x2.reshape(-1, rows, cols)
                y2 = jax.vmap(
                    lambda xi, wi: gossip_mix_2d(xi, x2, wi,
                                                 interpret=interpret))(
                    x2, mix_h)
                y_flat = y2.reshape(y2.shape[0], -1)[:, :p_total]
            carry = _unflatten(y_flat, carry)

            # --- per-round metrics: fleet accuracy/loss over alive
            # workers + consensus distance to the alive mean ---
            accs = jax.vmap(lambda p: adapter.accuracy(p, tx, ty))(carry)
            tloss = jax.vmap(
                lambda p: adapter.loss(p, {"x": tx, "y": ty}))(carry)
            dmean = jnp.tensordot(cw_h, y_flat, axes=1)
            dists = jnp.sqrt(jnp.sum((y_flat - dmean[None]) ** 2, axis=1))
            outs = {"acc": jnp.dot(ew_h, accs),
                    "loss": jnp.dot(ew_h, tloss),
                    "consensus": jnp.dot(cw_h, dists)}

            if measure:
                # --- Alg. 1 lines 4-5: the SAME per-worker measurement
                # function as the reference engine's _measure (eval/probe
                # tensors passed whole, only params vmapped) ---
                losses, _, ls, sigs, upds = jax.vmap(
                    lambda p, q: _measure_worker(adapter, p, q, ex, ey, px,
                                                 py))(carry, prev)
                # consensus.pairwise_distances' f32 gram trick, including
                # its cancellation noise floor for near-identical models —
                # that floor feeds FedHP's tracker, so it is part of the
                # behavior being reproduced
                sq = jnp.sum(y_flat * y_flat, axis=1)
                d2 = jnp.maximum(
                    sq[:, None] + sq[None, :] - 2.0 * (y_flat @ y_flat.T),
                    0.0)
                d2 = d2 * (1.0 - jnp.eye(d2.shape[0]))
                outs.update(losses=losses, ls=ls, sigs=sigs, upds=upds,
                            edge=jnp.sqrt(d2))
                if needs_cross:
                    outs["cross"] = _cross_loss_matrix(
                        adapter, carry, ex[:, :64], ey[:, :64])
            return (carry, err_c), outs

        return jax.lax.scan(body, (stacked, err),
                            (bx, by, taus, lrs, mixes, esrc, edst, ewt,
                             comms, ew, cw, keep, rw, hs, nbrs, degs))

    return jax.vmap(one_seed,
                    in_axes=(0, 0, 0, 0, 0, 0, 0, 0))(stacked, err, bx, by,
                                                      ex, ey, px, py)


# ---------------------------------------------------------------------------
# device code: the sharded twin — shard_map around the whole segment scan
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("adapter", "tau_cap", "measure", "kind",
                                   "k", "ef", "mesh", "axes", "offsets",
                                   "n_shards"))
def _scan_segment_sharded(stacked, err, bx, by, ex, ey, px, py, taus, lrs,
                          esl, edl, ewl, comms, ew, cw, keep, rw, hs, skey,
                          gamma, tx, ty, *, adapter, tau_cap: int,
                          measure: bool, kind: str, k: int, ef: bool,
                          mesh, axes, offsets, n_shards: int):
    """``_scan_segment`` with the [W, P] worker matrix sharded over the
    ``axes`` of ``mesh`` (the ``runtime/shardexec`` layout): the WHOLE
    K-round ``lax.scan`` runs inside one ``shard_map``, so per-round
    device work stays on each shard's ``rows = w_pad / n_shards`` block
    and only the cross-shard gossip contributions move — one ``ppermute``
    per distinct shard offset, via ``runtime/collectives.
    routed_mix_delta`` on the per-round [D, n_shards, width] edge tables
    ``esl``/``edl``/``ewl`` (built by the driver against the static
    ``offsets`` so every round of the segment shares one specialization).

    Differences from the unsharded scan, none of them behavioral:

    - no seed axis: the driver runs S=1 and re-adds the axis host-side
      (a batched ``seeds`` sweep stays unsharded);
    - gossip is ALWAYS the edge-list form (per-edge weights bit-identical
      to the dense off-diagonals) and the codecs run the
      ``use_kernel=False`` oracle row path (bit-identical to the Pallas
      kernels by the kernel differential tests) — payloads are row-local,
      so each shard compresses its own block and only the routed mixing
      delta crosses shards;
    - fleet scalars (join-blend mean, acc/loss/consensus dots) are psums
      of per-shard partials; the measure-mode [W, W] edge-distance gram
      ``all_gather``s the flat matrix (FedHP's tracker consumes the full
      gram — a measurement cost at segment boundaries, not a per-round
      training cost);
    - inputs arrive PADDED to ``w_pad`` rows (inert rows: zero params,
      tau 0, no edges, zero metric weights — exact no-ops end to end);
      the driver slices [W]-shaped outputs back to the real fleet.

    Returns ((stacked', err'), outs) with NO leading seed axis.
    """
    compress = kind != "none"
    stateful = compress and compression.carries_state(kind, ef)
    lead = axes if len(axes) > 1 else axes[0]

    def xspec(ndim):
        # [K, w_pad, ...] per-round control input: worker axis second
        return P(*([None, lead] + [None] * (ndim - 2)))

    def rspec(ndim):
        # fully replicated (eval tensors, scalars, [K] vectors)
        return P(*([None] * ndim))

    def scanned(stacked, err, bx, by, ex, ey, px, py, taus, lrs, esl, edl,
                ewl, comms, ew, cw, keep, rw, hs, skey, gamma, tx, ty):

        def body(carry, xs):
            carry, err_c = carry
            (bxh, byh, tau_h, lr_h, sl_h, dl_h, wl_h, comm_h, ew_h, cw_h,
             keep_h, rw_h, h_h) = xs

            def mix_delta(v):
                return routed_mix_delta(v, sl_h, dl_h, wl_h, offsets, axes,
                                        n_shards)

            # --- join re-init: _blend_joined with the fleet mean as a
            # psum of per-shard partial tensordots (rw_h is zero outside
            # the donor rows, so partials just add up) ---
            def blend(l):
                part = jnp.tensordot(rw_h, l.astype(jnp.float32), axes=1)
                mean = jax.lax.psum(part, axes)
                kk = keep_h.reshape((-1,) + (1,) * (l.ndim - 1))
                return jnp.where(kk, mean[None].astype(l.dtype), l)

            carry = jax.tree.map(blend, carry)
            if stateful:
                err_c = compression.state_after_join(
                    err_c, keep_h[:, None], _flatten_workers(carry), kind,
                    ef)
            prev = carry

            # --- local updating (Eq. 3): row-local, the same vmapped
            # per-worker step on each shard's block ---
            carry = jax.vmap(
                lambda p, bxw, byw, tau: _sgd_worker(adapter, p, bxw, byw,
                                                     tau, lr_h, tau_cap))(
                carry, bxh, byh, tau_h)

            flat = _flatten_workers(carry)
            if kind == "topk" and ef:
                # x̂-tracked top-k: identical update to the unsharded
                # scan; the oracle sparsify is per-row, so each shard
                # compresses its own rows
                q = compression.sparsify_rows(flat - err_c, "topk", k,
                                              use_kernel=False)
                xhat = err_c + q
                err_c = jnp.where(comm_h > 0, xhat, err_c)
                y_flat = flat + comm_h * gamma * mix_delta(xhat)
            elif compress:
                # int8 / rand-k / naive top-k round trip per shard block
                # (rand-k's mask is recomputed identically on every shard
                # from the shared key + step), then the routed delta
                z = flat + err_c if stateful else flat
                yhat = compression.encode_rows(z, kind, k, key=skey,
                                               step=h_h, use_kernel=False)
                if stateful:
                    err_c = jnp.where(comm_h > 0, z - yhat, err_c)
                y_flat = flat + comm_h * mix_delta(yhat)
            else:
                # sparse gossip (Eq. 5-6): zero-weight padding edges make
                # no-comm rounds exact no-ops, same contract as the edge
                # kernel
                y_flat = flat + mix_delta(flat)
            carry = _unflatten(y_flat, carry)

            # --- per-round fleet metrics: per-shard partial dots, psum'd
            # (metric weights are zero on the inert padding rows) ---
            accs = jax.vmap(lambda p: adapter.accuracy(p, tx, ty))(carry)
            tloss = jax.vmap(
                lambda p: adapter.loss(p, {"x": tx, "y": ty}))(carry)
            dmean = jax.lax.psum(jnp.tensordot(cw_h, y_flat, axes=1), axes)
            dists = jnp.sqrt(jnp.sum((y_flat - dmean[None]) ** 2, axis=1))
            outs = {"acc": jax.lax.psum(jnp.dot(ew_h, accs), axes),
                    "loss": jax.lax.psum(jnp.dot(ew_h, tloss), axes),
                    "consensus": jax.lax.psum(jnp.dot(cw_h, dists), axes)}

            if measure:
                # per-worker measurements are row-local (the eval/probe
                # stacks are replicated — historical full-stack
                # semantics); the [W, W] gram needs every row, so the
                # flat matrix is all_gathered once per measured round
                losses, _, ls, sigs, upds = jax.vmap(
                    lambda p, q: _measure_worker(adapter, p, q, ex, ey, px,
                                                 py))(carry, prev)
                yg = jax.lax.all_gather(y_flat, axes, axis=0, tiled=True)
                sq = jnp.sum(yg * yg, axis=1)
                d2 = jnp.maximum(
                    sq[:, None] + sq[None, :] - 2.0 * (yg @ yg.T), 0.0)
                d2 = d2 * (1.0 - jnp.eye(d2.shape[0]))
                outs.update(losses=losses, ls=ls, sigs=sigs, upds=upds,
                            edge=jnp.sqrt(d2))
            return (carry, err_c), outs

        return jax.lax.scan(body, (stacked, err),
                            (bx, by, taus, lrs, esl, edl, ewl, comms, ew,
                             cw, keep, rw, hs))

    s_specs = worker_stack_pspecs(stacked, axes)
    e_spec = worker_stack_spec(err.ndim, axes)
    t_spec = P(None, None, lead, None)
    in_specs = (s_specs, e_spec, xspec(bx.ndim), xspec(by.ndim),
                rspec(ex.ndim), rspec(ey.ndim), rspec(px.ndim),
                rspec(py.ndim), xspec(2), P(None), t_spec, t_spec, t_spec,
                P(None), xspec(2), xspec(2), xspec(2), xspec(2), P(None),
                rspec(jnp.ndim(skey)), P(), rspec(tx.ndim), rspec(ty.ndim))
    outs_spec = {"acc": P(None), "loss": P(None), "consensus": P(None)}
    if measure:
        outs_spec.update(losses=xspec(2), ls=xspec(2), sigs=xspec(2),
                         upds=xspec(2), edge=rspec(3))
    fn = _shard_map(scanned, mesh, in_specs, ((s_specs, e_spec), outs_spec))
    return fn(stacked, err, bx, by, ex, ey, px, py, taus, lrs, esl, edl,
              ewl, comms, ew, cw, keep, rw, hs, skey, gamma, tx, ty)


# ---------------------------------------------------------------------------
# host code: segment precompute replaying the reference engine's streams
# ---------------------------------------------------------------------------

@dataclass
class _Segment:
    """Per-round control inputs + host-side record fields for K rounds."""
    bx: np.ndarray            # [S, K, W, T, B, *feat] (data.x dtype)
    by: np.ndarray            # [S, K, W, T, B]
    taus: np.ndarray          # [K, W] i32
    lrs: np.ndarray           # [K] f32
    mixes: np.ndarray         # [K, W, W] f32 ([K, 1, 1] dummy when sparse)
    esrc: np.ndarray          # [K, E_max] i32 directed edge sources
    edst: np.ndarray          # [K, E_max] i32 directed edge destinations
    ewt: np.ndarray           # [K, E_max] f32 edge weights (0 == padding)
    comms: np.ndarray         # [K] f32  1.0 on rounds with communication
    ew: np.ndarray            # [K, W] f32  eval (accuracy/loss) weights
    cw: np.ndarray            # [K, W] f32  consensus weights
    keep: np.ndarray          # [K, W] bool join re-init mask
    rw: np.ndarray            # [K, W] f32  donor weights
    hs: np.ndarray            # [K] i32 absolute round indices (rand-k step)
    nbrs: np.ndarray          # [K, W, Dp] i32 padded neighbor tables
    degs: np.ndarray          # [K, W] i32 neighbor counts (robust rounds)
    tau_cap: int
    codec: object             # the segment's wire codec (compression.Codec)
    wire_ratio: list[float]   # per-round Eq. 10 comm divisor (observe fb)
    meas: list[np.ndarray]    # honest-alive measurement masks
    alive: list[np.ndarray]
    adjs: list[np.ndarray]
    mus: list[np.ndarray]
    betas: list[np.ndarray]
    round_time: list[float]
    waiting: list[float]
    mean_tau: list[float]
    num_links: list[int]
    cum_time: list[float]

    def __len__(self) -> int:
        return len(self.round_time)


def _precompute_segment(h0: int, seg_len: int, cluster: SimCluster,
                        strategy: Strategy, cfg: FedHPConfig, rngs, data,
                        shards, mixfn, clock: float,
                        time_budget: float | None, adaptive: bool,
                        codec0, p_model: int, sparse: bool = False,
                        mixing: str = "uniform", byz: np.ndarray | None = None,
                        robust: bool = False):
    """Advance cluster/strategy/batch RNG streams for rounds h0..h0+K-1 in
    the exact order ``run_dfl`` would, and pack the device inputs.

    For an adaptive strategy the plan is frozen at the segment's first
    round; static strategies re-plan every round (observation-free, so
    this is exactly the reference behavior). The frozen plan also fixes
    the segment's wire codec (``plan.codec`` falling back to ``codec0``,
    the parsed ``cfg.compress``; an uncompiled leafmap in the plan is
    replaced by the driver's compiled ``codec0``), whose
    ``wire_ratio(p_model)`` — the adapter's true parameter count —
    divides the Eq. 10 comm term exactly like the reference engine's
    clock.

    ``byz`` (a [W] bool mask, None when the fleet is honest) shifts the
    measurement weights onto the honest alive workers (``meas``) exactly
    like the reference engine; ``robust`` additionally packs per-round
    padded neighbor tables (``core/robust.neighbor_table`` of the
    repaired adjacency, segment max degree bucketed to the next power of
    two) for the fused trimmed/median sort window.
    """
    n = cfg.num_workers
    compress = codec0.kind != "none"
    drifting = hasattr(shards, "shards_at")
    per: list[dict] = []
    plan = None
    stop = False
    for t in range(seg_len):
        h = h0 + t
        alive = cluster.advance_round(h)
        joined = cluster.last_joined.copy()
        crashed = cluster.last_crashed.copy()
        mu = cluster.sample_mu()
        beta = cluster.sample_beta()
        if plan is None or not adaptive:
            plan = strategy.plan(h, alive=alive)
        rcodec = plan.codec if plan.codec is not None else codec0
        if codec0.kind == "leafmap" and rcodec.kind == "leafmap":
            rcodec = codec0           # the compiled copy
        comm_ratio = rcodec.wire_ratio(p_model) if compress else 1.0
        adj = plan.adj.copy()
        adj[~alive, :] = 0
        adj[:, ~alive] = 0
        # churn safety net: reconnect survivors whenever the strategy
        # intended communication this round (plan.adj has links) but
        # departures may have disconnected — or fully severed — them
        if not alive.all() and alive.sum() > 1 and plan.adj.sum() > 0:
            adj = topo.repair_connectivity(adj, alive, cost=beta)
        taus = np.where(alive, np.clip(plan.taus, 1, cfg.tau_max), 0)
        tau_cap = int(max(taus.max(), 1))
        sh = shards.shards_at(h) if drifting else shards
        batches = [_draw_batches(rng, data, sh, tau_cap, cfg.batch_size)
                   for rng in rngs]

        # --- clock (Eq. 10-11), formulas identical to run_dfl ---
        comm = np.where(adj.sum(1) > 0,
                        np.where(adj > 0, beta, 0.0).max(1), 0.0)
        if compress:
            comm = comm / comm_ratio
        t_i = taus * mu + comm
        if plan.extra_time is not None:
            t_i = t_i + plan.extra_time * alive
        t_round = float(t_i[alive].max()) if alive.any() else 0.0
        if crashed.any():
            t_round += cfg.crash_timeout
        waiting = float((t_round - t_i[alive]).mean()) if alive.any() else 0.0
        clock += t_round

        # --- device-side control inputs ---
        if sparse:
            # edge-list round topology: per-edge weights from degrees
            # (bit-identical to the dense matrices' off-diagonals); the
            # dense mix is never built — [K, 1, 1] dummies ride the scan
            mix = np.zeros((1, 1), np.float32)
            if adj.sum() > 0:
                e_und = topo.edges_from_adj(adj)
                e_w = topo.edge_mixing_weights(e_und, n, mixing)
                src, dst, wts = topo.directed_edges(e_und, e_w)
            else:
                src = dst = np.zeros(0, np.int32)
                wts = np.zeros(0, np.float32)
        else:
            mix = mixfn(adj) if adj.sum() > 0 else np.eye(n)
            src = dst = np.zeros(0, np.int32)
            wts = np.zeros(0, np.float32)
        donors = alive & ~joined
        do_reinit = joined.any() and donors.any()
        keep = joined if do_reinit else np.zeros(n, bool)
        rw = donors / max(donors.sum(), 1.0) if do_reinit else np.zeros(n)
        # fleet metrics cover the honest alive workers only (identical to
        # the reference engine's meas mask — equal to alive when the
        # fleet is honest, so honest runs are untouched bit for bit)
        meas = alive
        if byz is not None and byz.any() and (alive & ~byz).any():
            meas = alive & ~byz
        if meas.any() and not meas.all():
            ew = meas / meas.sum()
        else:
            ew = np.full(n, 1.0 / n)
        cw = meas / meas.sum() if meas.any() else np.full(n, 1.0 / n)
        # padded closed-neighborhood index table of the repaired round
        # topology — the fused trimmed/median sort window (dummy [W, 1]
        # zeros otherwise; deg 0 == keep-own-row, an exact no-op)
        if robust:
            nbr_t, deg_t = robust_agg.neighbor_table(adj)
        else:
            nbr_t = np.zeros((n, 1), np.int32)
            deg_t = np.zeros(n, np.int32)

        per.append(dict(alive=alive, adj=adj, mu=mu, beta=beta, taus=taus,
                        tau_cap=tau_cap, batches=batches, mix=mix,
                        src=src, dst=dst, wts=wts, meas=meas,
                        nbr=nbr_t, deg=deg_t,
                        comm=1.0 if adj.sum() > 0 else 0.0,
                        keep=keep, rw=rw, ew=ew, cw=cw, h=h,
                        codec=rcodec, wire_ratio=comm_ratio,
                        lr=cfg.lr * (cfg.lr_decay ** h),
                        t_round=t_round, waiting=waiting,
                        mean_tau=float(taus[alive].mean())
                        if alive.any() else 0.0,
                        num_links=int(adj.sum() // 2), cum=clock))
        if time_budget is not None and clock >= time_budget:
            stop = True
            break

    # bucket the scan's tau extent to the next power of two: the masked
    # step makes extra iterations no-ops, and bucketing caps the number of
    # distinct (seg_len, tau_cap) jit specializations the adaptive path
    # (whose taus change every replan) can trigger at ~log2(tau_max)
    cap = max(p["tau_cap"] for p in per)
    cap = 1 << (cap - 1).bit_length() if cap > 1 else 1
    n_seeds = len(rngs)

    def pad(b, tc):
        return np.pad(b, ((0, 0), (0, cap - tc)) + ((0, 0),) * (b.ndim - 2))

    bx = np.stack([np.stack([pad(p["batches"][s][0], p["tau_cap"])
                             for p in per]) for s in range(n_seeds)])
    by = np.stack([np.stack([pad(p["batches"][s][1], p["tau_cap"])
                             for p in per]) for s in range(n_seeds)])
    # pad per-round edge arrays to one static E_max (zero-weight edges are
    # exact kernel no-ops), bucketed to the next power of two like tau_cap
    # so adaptive topologies trigger ~log2(E) jit specializations, not one
    # per distinct edge count
    e_max = max((len(p["src"]) for p in per), default=0)
    e_max = max(8, 1 << (e_max - 1).bit_length()) if e_max > 1 else 8
    esrc = np.zeros((len(per), e_max), np.int32)
    edst = np.zeros((len(per), e_max), np.int32)
    ewt_a = np.zeros((len(per), e_max), np.float32)
    for t, p in enumerate(per):
        ne = len(p["src"])
        esrc[t, :ne] = p["src"]
        edst[t, :ne] = p["dst"]
        ewt_a[t, :ne] = p["wts"]
    # pad per-round neighbor tables to one segment-wide D, bucketed to the
    # next power of two like tau_cap/e_max so adaptive topologies trigger
    # ~log2(W) sort-window jit specializations (padding slots sit above
    # deg and are masked to +inf on device — exact no-ops)
    d_max = max(p["nbr"].shape[1] for p in per)
    d_max = 1 << (d_max - 1).bit_length() if d_max > 1 else 1
    nbrs = np.zeros((len(per), n, d_max), np.int32)
    degs = np.zeros((len(per), n), np.int32)
    for t, p in enumerate(per):
        nbrs[t, :, :p["nbr"].shape[1]] = p["nbr"]
        degs[t] = p["deg"]
    seg = _Segment(
        bx=bx, by=by.astype(np.int32),
        taus=np.stack([p["taus"] for p in per]).astype(np.int32),
        lrs=np.array([p["lr"] for p in per], np.float32),
        mixes=np.stack([p["mix"] for p in per]).astype(np.float32),
        esrc=esrc, edst=edst, ewt=ewt_a,
        comms=np.array([p["comm"] for p in per], np.float32),
        ew=np.stack([p["ew"] for p in per]).astype(np.float32),
        cw=np.stack([p["cw"] for p in per]).astype(np.float32),
        keep=np.stack([p["keep"] for p in per]),
        rw=np.stack([p["rw"] for p in per]).astype(np.float32),
        hs=np.array([p["h"] for p in per], np.int32),
        nbrs=nbrs, degs=degs,
        tau_cap=cap,
        codec=per[0]["codec"],
        wire_ratio=[p["wire_ratio"] for p in per],
        meas=[p["meas"] for p in per],
        alive=[p["alive"] for p in per], adjs=[p["adj"] for p in per],
        mus=[p["mu"] for p in per], betas=[p["beta"] for p in per],
        round_time=[p["t_round"] for p in per],
        waiting=[p["waiting"] for p in per],
        mean_tau=[p["mean_tau"] for p in per],
        num_links=[p["num_links"] for p in per],
        cum_time=[p["cum"] for p in per])
    return seg, clock, stop


def _pad_rows(a, pad: int, axis: int = 1, fill=0):
    """Pad ``a``'s worker ``axis`` with ``pad`` inert rows (host numpy)."""
    if pad == 0:
        return np.asarray(a)
    widths = [(0, 0)] * np.ndim(a)
    widths[axis] = (0, pad)
    return np.pad(a, widths, constant_values=fill)


def _sharded_edge_tables(seg: "_Segment", plan):
    """Per-round routed edge tables for one segment, unioned to a single
    static (offsets, width) so all K rounds share one ``shard_map``
    specialization: [K, D, n_shards, width] arrays whose zero-weight
    padding slots contribute exactly 0 to the routed delta."""
    rows = plan.rows
    offs = {0}      # padding edges (src=dst=0) always land in offset 0
    for t in range(seg.esrc.shape[0]):
        src, dst = seg.esrc[t], seg.edst[t]
        offs.update(int(d) for d in np.unique(
            (dst // rows - src // rows) % plan.n_shards))
    offsets = tuple(sorted(offs))
    per = []
    for t in range(seg.esrc.shape[0]):
        _, sl, dl, wl = edge_shard_tables(
            seg.esrc[t], seg.edst[t], seg.ewt[t], plan.w_pad,
            plan.n_shards, offsets=offsets)
        per.append((sl, dl, wl))
    # bucket the per-(offset, dest-shard) slot width to the next power of
    # two so adaptive topologies trigger ~log2(E) specializations
    width = max(max(sl.shape[2] for sl, _, _ in per), 8)
    width = 1 << (width - 1).bit_length()

    def padw(a):
        return np.pad(a, ((0, 0), (0, 0), (0, width - a.shape[2])))

    esl = np.stack([padw(sl) for sl, _, _ in per])
    edl = np.stack([padw(dl) for _, dl, _ in per])
    ewl = np.stack([padw(wl) for _, _, wl in per])
    return offsets, esl, edl, ewl


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run_dfl_fused(data: Dataset, test_x, test_y, shards,
                  cluster: SimCluster, cfg: FedHPConfig, strategy: Strategy,
                  *, rounds: int | None = None, hidden: int = 64,
                  eval_subset: int = 512, mixing: str = "uniform",
                  time_budget: float | None = None, seeds=None,
                  interpret: bool | None = None,
                  adapter: modelspec.ModelAdapter | None = None,
                  init_params=None, mesh=None):
    """Drop-in fused replacement for ``engine.run_dfl``.

    With ``seeds=None`` runs one experiment from ``cfg.seed`` and returns
    a ``History`` matching the reference engine's to tolerance. With an
    array of ``seeds`` returns ``list[History]``, one per seed, batched
    through a single vmapped scan: each lane uses its seed for the model
    init PRNGKey and the batch-sampling RNG while sharing the data split,
    cluster and (static) plans. ``adapter``/``init_params`` mirror
    ``run_dfl`` (``init_params`` resumes a single run — incompatible with
    batched ``seeds``).

    ``mesh`` (or ``cfg.sharded``) runs the scan through
    ``_scan_segment_sharded``: the [W, P] worker matrix splits over the
    mesh's worker axis, gossip takes the ppermute-routed edge-list form,
    and the host control plane is byte-identical to the unsharded run.
    Single lane only (no batched ``seeds``); PENS and per-leaf codec
    maps are excluded (see ``engine.run_dfl``'s sharded contract).
    """
    rounds = rounds or cfg.rounds
    n = cfg.num_workers
    sharded = mesh is not None or getattr(cfg, "sharded", False)
    # Byzantine scenario axis (core/robust.py): attackers corrupt the
    # wire copy inside the scan, trimmed/median rounds sort-trim the
    # gathered closed neighborhood through the Pallas robust kernel —
    # no delegation to the reference engine
    byz = robust_agg.byzantine_mask(cfg.byzantine, n)
    has_byz = bool(byz.any())
    robust_mode, robust_b = robust_agg.parse_robust(cfg.robust)
    if robust_mode == "screen":
        raise ValueError(
            "cfg.robust='screen:<z>' is the AD-PSGD accept/reject rule; "
            "synchronous engines use 'trimmed:<b>' / 'median'")
    robust_active = has_byz or robust_mode != "none"
    if robust_active and sharded:
        raise ValueError(
            "the sharded path does not compose with cfg.byzantine / "
            "cfg.robust (data-dependent sorts are single-device-only)")
    atk_kind, atk_scale = (robust_agg.parse_attack(cfg.byzantine_attack)
                           if has_byz else ("signflip", 1.0))
    adaptive = getattr(strategy, "adaptive", False)
    batched = seeds is not None
    if sharded:
        if batched:
            raise ValueError(
                "the sharded fused scan runs one lane (S=1); a batched "
                "seeds axis would stack S copies of the sharded fleet — "
                "run seeds sequentially or drop the mesh")
        if strategy.name == "pens":
            raise ValueError(
                "pens needs the [W, W] cross-loss matrix every round; "
                "the sharded path excludes it (engine.run_dfl contract)")
    if init_params is not None and batched:
        raise ValueError(
            "init_params resumes ONE run's stacked params; it does not "
            "compose with a batched seeds axis")
    seed_list = ([int(s) for s in np.asarray(seeds).reshape(-1)]
                 if batched else [int(cfg.seed)])
    if adapter is None:
        adapter = modelspec.adapter_for(cfg, data, hidden=hidden)
    if adaptive and len(seed_list) > 1:
        raise ValueError(
            f"strategy {strategy.name!r} adapts its plan to per-round "
            "measurements; a batched seeds axis would need one plan per "
            "seed. Batch static-plan strategies (dpsgd/ldsgd) or run "
            "seeds sequentially.")
    interp = (jax.default_backend() == "cpu") if interpret is None \
        else interpret

    # per-seed setup, consuming each seed's RNG exactly like run_dfl
    rngs = [np.random.default_rng(s) for s in seed_list]
    stacked0, exs, eys = [], [], []
    for s, rng in zip(seed_list, rngs):
        if init_params is not None:
            stacked0.append(jax.tree.map(jnp.asarray, init_params))
        else:
            key = jax.random.PRNGKey(s)
            p0 = adapter.init(key)
            stacked0.append(jax.tree.map(
                lambda l: jnp.broadcast_to(l, (n,) + l.shape), p0))
        exs.append(np.stack([data.x[sh[rng.integers(0, len(sh), 256)]]
                             for sh in shards]))
        eys.append(np.stack([data.y[sh[rng.integers(0, len(sh), 256)]]
                             for sh in shards]))
    plan = None
    if sharded:
        from repro.runtime import shardexec
        plan = shardexec.WorkerShardPlan(
            mesh if mesh is not None else shardexec.default_worker_mesh(),
            n)
        # one lane, padded to w_pad inert rows and committed to the mesh
        # (no leading seed axis — the scan runs S=1)
        stacked = plan.put_stacked(stacked0[0])
    else:
        stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *stacked0)
    codec0 = compression.parse_mode(cfg.compress)
    if codec0.kind == "leafmap":
        codec0 = codec0.compile(adapter.leaf_offsets())
    leafmap = codec0.kind == "leafmap"
    if sharded and leafmap:
        raise ValueError(
            "per-leaf codec maps are single-device only: their shared "
            "payload spans leaf segments, which would need per-segment "
            "routing tables on the sharded path")
    compress = codec0.kind != "none"
    if robust_active and compress:
        raise ValueError(
            "cfg.byzantine / cfg.robust do not compose with cfg.compress")
    p_model = adapter.param_count
    # rand-k mask stream: derived from cfg.seed (not the lane seeds) so
    # vmapped lanes share the masks like they share the rest of the
    # host-side control plane
    skey = compression.sparsify_base_key(cfg.seed)
    # per-seed codec state (int8 residual / top-k x̂ / leafmap segment
    # buffer), carried across segments; a [S, W, 1] dummy keeps the carry
    # structure static for stateless runs (uncompressed, rand-k, EF off)
    # without hauling a dead fleet-sized buffer through the scan
    if leafmap:
        err = compression.leafmap_state_init(
            jnp.stack([_flatten_workers(s) for s in stacked0]),
            codec0, cfg.error_feedback)
    elif compress and compression.carries_state(codec0.kind,
                                                cfg.error_feedback):
        # sharded: state rows follow the padded [w_pad, P] layout (the
        # inert rows' zero params give zero residual / zero x̂)
        err = (compression.state_init(_flatten_workers(stacked),
                                      codec0.kind, cfg.error_feedback)
               if plan is not None else
               compression.state_init(
                   jnp.stack([_flatten_workers(s) for s in stacked0]),
                   codec0.kind, cfg.error_feedback))
    elif plan is not None:
        err = jnp.zeros((plan.w_pad, 1), jnp.float32)
    else:
        err = jnp.zeros((len(seed_list), n, 1), jnp.float32)
    ex = jnp.asarray(np.stack(exs))
    ey = jnp.asarray(np.stack(eys))
    px, py = ex[:, :, :32], ey[:, :, :32]
    tx = jnp.asarray(test_x[:eval_subset])
    ty = jnp.asarray(test_y[:eval_subset])

    mixfn = (topo.mixing_matrix_metropolis if mixing == "metropolis"
             else topo.mixing_matrix_uniform)
    needs_cross = strategy.name == "pens"
    replan = max(int(getattr(cfg, "replan_every", 1)), 1)
    # the sharded scan always routes gossip through the edge-list form
    # (weights bit-identical to the dense off-diagonals), so the segment
    # precompute builds edge arrays instead of [K, W, W] mixing matrices
    sparse = cfg.gossip == "sparse" or plan is not None

    hists = [History() for _ in seed_list]
    clock = 0.0
    h = 0
    stop = False
    while h < rounds and not stop:
        seg_len = (min(replan, rounds - h) if adaptive
                   else min(rounds - h, MAX_FUSE_ROUNDS))
        seg, clock, stop = _precompute_segment(
            h, seg_len, cluster, strategy, cfg, rngs, data, shards, mixfn,
            clock, time_budget, adaptive, codec0, p_model, sparse=sparse,
            mixing=mixing, byz=byz if has_byz else None,
            robust=robust_mode in ("trimmed", "median"))
        if plan is not None:
            offsets, esl, edl, ewl = _sharded_edge_tables(seg, plan)
            pd = plan.pad
            (stacked, err), outs = _scan_segment_sharded(
                stacked, err,
                jnp.asarray(_pad_rows(seg.bx[0], pd)),
                jnp.asarray(_pad_rows(seg.by[0], pd)),
                ex[0], ey[0], px[0], py[0],
                jnp.asarray(_pad_rows(seg.taus, pd)),
                jnp.asarray(seg.lrs),
                jnp.asarray(esl), jnp.asarray(edl), jnp.asarray(ewl),
                jnp.asarray(seg.comms),
                jnp.asarray(_pad_rows(seg.ew, pd)),
                jnp.asarray(_pad_rows(seg.cw, pd)),
                jnp.asarray(_pad_rows(seg.keep, pd)),
                jnp.asarray(_pad_rows(seg.rw, pd)),
                jnp.asarray(seg.hs), skey, jnp.float32(cfg.sparse_gamma),
                tx, ty, adapter=adapter, tau_cap=seg.tau_cap,
                measure=adaptive, kind=seg.codec.kind,
                k=seg.codec.resolve_k(p_model), ef=cfg.error_feedback,
                mesh=plan.mesh, axes=plan.axes, offsets=offsets,
                n_shards=plan.n_shards)
            outs = {k2: np.asarray(v) for k2, v in outs.items()}
            # slice the inert padding rows off, then re-add the S=1 seed
            # axis the record/observe loops below index with si=0
            for k2 in ("losses", "ls", "sigs", "upds"):
                if k2 in outs:
                    outs[k2] = outs[k2][:, :n]
            if "edge" in outs:
                outs["edge"] = outs["edge"][:, :n, :n]
            outs = {k2: v[None] for k2, v in outs.items()}
        else:
            (stacked, err), outs = _scan_segment(
                stacked, err, jnp.asarray(seg.bx), jnp.asarray(seg.by),
                ex, ey,
                px, py, jnp.asarray(seg.taus), jnp.asarray(seg.lrs),
                jnp.asarray(seg.mixes), jnp.asarray(seg.esrc),
                jnp.asarray(seg.edst), jnp.asarray(seg.ewt),
                jnp.asarray(seg.comms),
                jnp.asarray(seg.ew), jnp.asarray(seg.cw),
                jnp.asarray(seg.keep), jnp.asarray(seg.rw),
                jnp.asarray(seg.hs), jnp.asarray(seg.nbrs),
                jnp.asarray(seg.degs), jnp.asarray(byz),
                jnp.float32(atk_scale), skey,
                jnp.float32(cfg.sparse_gamma),
                tx, ty, adapter=adapter, tau_cap=seg.tau_cap,
                measure=adaptive,
                needs_cross=needs_cross, interpret=interp,
                kind=seg.codec.kind,
                k=seg.codec.resolve_k(p_model),
                ef=cfg.error_feedback, sparse=sparse,
                lcodec=seg.codec if leafmap else None,
                robust=robust_mode, rb=robust_b,
                attack=atk_kind if has_byz else "")
            outs = {k: np.asarray(v) for k, v in outs.items()}

        for t in range(len(seg)):
            hh = h + t
            for si, hist in enumerate(hists):
                hist.records.append(RoundRecord(
                    round=hh, round_time=seg.round_time[t],
                    waiting_time=seg.waiting[t],
                    accuracy=float(outs["acc"][si, t]),
                    loss=float(outs["loss"][si, t]),
                    mean_tau=seg.mean_tau[t], num_links=seg.num_links[t],
                    consensus=float(outs["consensus"][si, t]),
                    cumulative_time=seg.cum_time[t]))
            if adaptive:
                a = seg.alive[t]
                m = seg.meas[t]     # honest alive workers (== a sans byz)
                strategy.observe(
                    hh, adj=seg.adjs[t], mu=seg.mus[t], beta=seg.betas[t],
                    edge_dist=np.asarray(outs["edge"][0, t], np.float64),
                    update_norms=outs["upds"][0, t][m] if m.any() else [0.0],
                    smooth_l=float(np.median(outs["ls"][0, t][m])),
                    sigma=float(np.median(outs["sigs"][0, t][m])),
                    loss=float(np.mean(outs["losses"][0, t][m])),
                    cross_loss=np.asarray(outs["cross"][0, t], np.float64)
                    if needs_cross else None,
                    alive=a, wire_ratio=seg.wire_ratio[t])
        h += len(seg)
    for si, hist in enumerate(hists):
        # sharded: one lane, no seed axis — hand back the real W rows
        # (still device-sharded when W divides the shard count)
        hist.final_params = (plan.unpad(stacked) if plan is not None else
                             jax.tree.map(lambda l, si=si: l[si], stacked))
    return hists if batched else hists[0]


# ---------------------------------------------------------------------------
# Fused event-driven AD-PSGD
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("adapter", "tau", "interpret", "kind",
                                   "k", "ef", "screen", "attack"))
def _adpsgd_scan(stacked, snap, err, stale, histn, bx, by, iidx, jidx,
                 eidx, lrs, keep, rw, ew, cw, byz, atk_scale, z, skey,
                 gamma, tx, ty, *, adapter, tau: int, interpret: bool,
                 kind: str, k: int, ef: bool, screen: bool = False,
                 attack: str = ""):
    """Run K AD-PSGD rounds (K*N events) on device in one nested scan.

    The outer scan walks rounds, the inner scan the round's N events;
    the carry is the full asynchronous state the reference event loop
    keeps between dispatches: live parameter rows (``stacked``), the
    per-worker snapshots deltas are computed from (``snap``), the
    error-feedback residuals (``err``, [S, W, P] on compressed runs) and
    the per-worker staleness counters (``stale``, [S, W] i32). Batched
    over a leading seed axis S on (stacked, snap, err, stale, bx, by);
    the event schedule (iidx/jidx [K, N] and the global event indices
    eidx [K, N] — the rand-k mask step), learning rates, join masks,
    metric weights and the mask key ``skey`` are shared across seeds.

    The pairwise average runs through the Pallas ``gossip_mix_2d`` kernel
    on the 2-row slice (partner row as the single neighbor buffer,
    weight ½); compressed runs instead route the codec round trip of
    both rows through the Pallas kernels (int8 quantize/dequantize or
    the sparsify mask-and-pack, per the static ``kind``/``k``) and apply
    the compensated half-mix (``compression.compressed_pair_ref``).

    The lie-on-wire scenario axis rides the event scan when ``attack``
    names an attack kind: byzantine endpoints (``byz``, [W] bool shared
    across seeds) transmit a corrupted copy of their row
    (``core/robust.attack_row`` scaled by ``atk_scale``), and with
    ``screen`` on each endpoint z-tests the incoming payload against its
    own-delta-norm EMA (``histn``, [S, W] carried in the scan state,
    threshold ``z``) and keeps its self-model on rejection — the same
    accept/reject primitives the reference loop calls, so decisions
    match. Screening is data-plane only: event order, staleness and the
    clock are untouched. Self-events (i == j) have no wire. Attack-free
    screened exchanges reduce to the plain kernel average bit for bit
    (the payload-as-base half-mix below).

    Returns ((stacked', snap', err', stale', histn'), outs) where outs
    carries [S, K] metric trajectories (plus per-round screen-reject
    counts) and the [S, K, N] per-event staleness actually observed by
    the scan (host schedule replay must agree)."""
    compress = kind != "none"
    lying = screen or bool(attack)
    leaves = jax.tree.leaves(stacked)
    p_total = sum(int(np.prod(l.shape[2:])) for l in leaves)
    rows, cols = compression.flat_tile_shape(p_total)

    def one_seed(stacked, snap, err, stale, histn, bx, by):
        # the scan carries FLAT [W, P] matrices (params + snapshots): one
        # row scatter per event instead of one per pytree leaf; the
        # single-worker ``template`` pytree only supplies shapes for the
        # per-event unflatten around the SGD steps
        template = jax.tree.map(lambda l: l[0], stacked)
        flat0 = _flatten_workers(stacked)
        snap0 = _flatten_workers(snap)

        def half_mix(base, other):
            # 2-row slice through the gossip kernel: one neighbor
            # buffer, weight 1/2, so y = base + ½ (other - base) —
            # the atomic pairwise average
            pad = rows * cols - p_total
            b2d = jnp.pad(base, (0, pad)).reshape(rows, cols)
            u = jnp.pad(other, (0, pad)).reshape(1, rows, cols)
            y2d = gossip_mix_2d(b2d, u, jnp.full((1,), 0.5, jnp.float32),
                                interpret=interpret)
            return y2d.reshape(-1)[:p_total]

        def event_body(carry, xs):
            flat, snapf, err, stale, histn = carry
            i, j, bxe, bye, e_h, lr_h = xs
            p_snap = _unflatten_row(snapf[i], template)
            delta = _adpsgd_delta(adapter, p_snap, bxe, bye, lr_h, tau)
            dflat = _flatten_row(delta)
            xi = flat[i] + dflat
            xj = flat[j]
            nrej = jnp.int32(0)
            if compress:
                xi2, xj2, ei2, ej2 = compression.compressed_pair_ref(
                    xi, xj, err[i], err[j], error_feedback=ef,
                    kind=kind, k=k, key=skey, step=e_h, gamma=gamma,
                    use_kernel=True, interpret=interpret)
                err = err.at[i].set(ei2).at[j].set(ej2)
                flat = flat.at[i].set(xi2).at[j].set(xj2)
            elif lying:
                # lying wire: each endpoint receives the partner's
                # TRANSMITTED copy; screening keeps the self-model on
                # rejection. Both accepted rows are half-mixes with the
                # incoming payload as one operand — attack-free this is
                # literally the plain kernel average on both sides
                wire = i != j
                ti = robust_agg.attack_row(xi, byz[i] & wire, atk_scale,
                                           kind=attack or "signflip")
                tj = robust_agg.attack_row(xj, byz[j] & wire, atk_scale,
                                           kind=attack or "signflip")
                if screen:
                    h_i = robust_agg.screen_fold(histn[i],
                                                 jnp.linalg.norm(dflat))
                    histn = histn.at[i].set(h_i)
                    acc_i = ~wire | robust_agg.screen_accept(xi, tj, h_i, z)
                    acc_j = ~wire | robust_agg.screen_accept(xj, ti,
                                                             histn[j], z)
                    nrej = ((~acc_i).astype(jnp.int32)
                            + (~acc_j).astype(jnp.int32))
                else:
                    acc_i = acc_j = jnp.bool_(True)
                row_i = jnp.where(acc_i, half_mix(xi, tj), xi)
                row_j = jnp.where(acc_j, half_mix(ti, xj), xj)
                flat = flat.at[i].set(row_i).at[j].set(row_j)
            else:
                avg = half_mix(xi, xj)
                flat = flat.at[i].set(avg).at[j].set(avg)
            # fresh snapshot for i = its live row after the exchange
            snapf = snapf.at[i].set(flat[i])
            st_i = stale[i]
            stale = stale.at[i].set(0)
            stale = stale.at[j].add(jnp.where(j != i, 1, 0))
            return (flat, snapf, err, stale, histn), (st_i, nrej)

        def round_body(carry, xs):
            flat, snapf, err, stale, histn = carry
            bxh, byh, i_h, j_h, e_h, lr_h, keep_h, rw_h, ew_h, cw_h = xs
            # --- join re-init before the round's events: joined rows
            # adopt the donor average, get a fresh snapshot, and drop
            # residual + staleness + screening history (exact no-op when
            # keep_h is all-False)
            mean = jnp.tensordot(rw_h, flat, axes=1)
            flat = jnp.where(keep_h[:, None], mean[None], flat)
            snapf = jnp.where(keep_h[:, None], flat, snapf)
            if compress and compression.carries_state(kind, ef):
                # same reset as the reference: zeroed residual, or x̂
                # re-anchored at the (shared-knowledge) blended row
                err = compression.state_after_join(err, keep_h[:, None],
                                                   flat, kind, ef)
            stale = jnp.where(keep_h, 0, stale)
            histn = jnp.where(keep_h, 0.0, histn)

            lrs_ev = jnp.broadcast_to(lr_h, i_h.shape)
            (flat, snapf, err, stale, histn), (st, rej) = jax.lax.scan(
                event_body, (flat, snapf, err, stale, histn),
                (i_h, j_h, bxh, byh, e_h, lrs_ev))

            carry_tree = _unflatten(flat, stacked)
            accs = jax.vmap(lambda p: adapter.accuracy(p, tx, ty))(
                carry_tree)
            tloss = jax.vmap(
                lambda p: adapter.loss(p, {"x": tx, "y": ty}))(carry_tree)
            dmean = jnp.tensordot(cw_h, flat, axes=1)
            dists = jnp.sqrt(jnp.sum((flat - dmean[None]) ** 2, axis=1))
            outs = {"acc": jnp.dot(ew_h, accs),
                    "loss": jnp.dot(ew_h, tloss),
                    "consensus": jnp.dot(cw_h, dists),
                    "event_staleness": st,
                    "rejects": rej.sum()}
            return (flat, snapf, err, stale, histn), outs

        (flat, snapf, err, stale, histn), outs = jax.lax.scan(
            round_body, (flat0, snap0, err, stale, histn),
            (bx, by, iidx, jidx, eidx, lrs, keep, rw, ew, cw))
        return (_unflatten(flat, stacked), _unflatten(snapf, snap),
                err, stale, histn), outs

    return jax.vmap(one_seed, in_axes=(0, 0, 0, 0, 0, 0, 0))(
        stacked, snap, err, stale, histn, bx, by)


def run_adpsgd_fused(data: Dataset, test_x, test_y, shards,
                     cluster: SimCluster, cfg: FedHPConfig, *,
                     rounds: int | None = None, hidden: int = 64,
                     eval_subset: int = 512,
                     time_budget: float | None = None, seeds=None,
                     interpret: bool | None = None,
                     schedule: AdpsgdSchedule | None = None,
                     adapter: modelspec.ModelAdapter | None = None):
    """Drop-in fused replacement for ``engine.run_adpsgd``.

    The event-driven loop lowers to one ``jax.lax.scan`` per segment of
    ``ADPSGD_FUSE_ROUNDS`` rounds: the host precomputes the full event
    schedule (``engine.adpsgd_schedule`` — per-event worker, pairwise
    partner, event time, staleness; Eq. 10 event clock, compressed runs
    charging beta / wire_ratio) and the per-event batch tensors, then the
    device replays every event with the same per-event math as the
    reference loop — snapshot deltas, atomic pairwise averaging through
    the Pallas ``gossip_mix_2d`` kernel (or the compensated int8 exchange
    through the quantize kernels when ``cfg.compress == "int8"``), and
    staleness counters carried in the scan state.

    With ``seeds=None`` this matches ``run_adpsgd`` record for record
    (host fields, including ``staleness``, bit-identical; device
    trajectories to float tolerance — tests/test_fused_equivalence.py).
    With an array of ``seeds`` it returns ``list[History]``: all lanes
    share the cfg.seed-derived event schedule and cluster draws while the
    model init / batch streams come from each lane's seed (the lane whose
    seed equals ``cfg.seed`` reproduces the unbatched run exactly). Pass
    an explicit ``schedule`` to replay a custom event sequence verbatim
    (``rounds``/``time_budget`` are generation-time knobs).

    ``cfg.byzantine`` / ``cfg.robust="screen:<z>"`` replay the reference
    lying-wire exchange inside the event scan (same accept/reject
    primitives, ``core/robust.py``), with per-round reject counts in
    ``History.screen_rejects``; measurements mask attackers out exactly
    like ``run_adpsgd`` does."""
    rounds = rounds or cfg.rounds
    n = cfg.num_workers
    byz = robust_agg.byzantine_mask(cfg.byzantine, n)
    has_byz = bool(byz.any())
    robust_mode, screen_z = robust_agg.parse_robust(cfg.robust)
    if robust_mode in ("trimmed", "median"):
        raise ValueError(
            "trimmed/median robust gossip is synchronous-engine only "
            "(a 2-sample pairwise exchange has no trim window); AD-PSGD "
            "takes cfg.robust='screen:<z>'")
    screen = robust_mode == "screen"
    atk_kind, atk_scale = (robust_agg.parse_attack(cfg.byzantine_attack)
                           if has_byz else ("signflip", 1.0))
    batched = seeds is not None
    seed_list = ([int(s) for s in np.asarray(seeds).reshape(-1)]
                 if batched else [int(cfg.seed)])
    interp = (jax.default_backend() == "cpu") if interpret is None \
        else interpret
    codec = compression.parse_mode(cfg.compress)
    if codec.kind == "leafmap":
        raise ValueError(
            "per-leaf codec maps (compress='leafmap:...') are "
            "synchronous-engine only; AD-PSGD's pairwise exchange has no "
            "leafmap form yet")
    compress = codec.kind != "none"
    if (has_byz or screen) and compress:
        raise ValueError(
            "cfg.byzantine / cfg.robust do not compose with cfg.compress")
    if adapter is None:
        adapter = modelspec.adapter_for(cfg, data, hidden=hidden)
    skey = compression.sparsify_base_key(cfg.seed)  # rand-k mask stream
    if schedule is None:
        schedule = adpsgd_schedule(cluster, cfg, rounds=rounds,
                                   time_budget=time_budget,
                                   p_model=adapter.param_count)
    elif time_budget is not None:
        raise ValueError(
            "time_budget only applies while GENERATING a schedule; an "
            "explicit schedule= replays verbatim (apply the budget in "
            "adpsgd_schedule instead)")
    tau = schedule.tau

    rngs = [np.random.default_rng(s) for s in seed_list]
    stacked0 = []
    for s in seed_list:
        key = jax.random.PRNGKey(s)
        p0 = adapter.init(key)
        stacked0.append(jax.tree.map(
            lambda l: jnp.broadcast_to(l, (n,) + l.shape), p0))
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *stacked0)
    snap = stacked                       # snapshots start at the init rows
    k_abs = codec.resolve_k(adapter.param_count)
    # codec state rows, or a [S, W, 1] dummy for stateless runs (see
    # run_dfl_fused) — the stateless pair exchange returns its state
    # rows untouched, so the dummy shape survives the event scan
    err = (compression.state_init(
        jnp.stack([_flatten_workers(s) for s in stacked0]),
        codec.kind, cfg.error_feedback)
        if compress and compression.carries_state(codec.kind,
                                                  cfg.error_feedback)
        else jnp.zeros((len(seed_list), n, 1), jnp.float32))
    stale = jnp.zeros((len(seed_list), n), jnp.int32)
    histn = jnp.zeros((len(seed_list), n), jnp.float32)  # screening EMA
    tx = jnp.asarray(test_x[:eval_subset])
    ty = jnp.asarray(test_y[:eval_subset])

    counts = {len(r.events) for r in schedule.rounds}
    if len(counts) > 1:
        raise ValueError(
            f"fused AD-PSGD scans a rectangular [rounds, events] grid; "
            f"got rounds with differing event counts {sorted(counts)} "
            f"(generated schedules always have N events per round)")
    n_ev = counts.pop() if counts else 0

    hists = [History() for _ in seed_list]
    if screen:
        for hist in hists:
            hist.screen_rejects = []
    done = 0
    while done < len(schedule.rounds):
        seg = schedule.rounds[done:done + ADPSGD_FUSE_ROUNDS]
        iidx = np.array([[e.worker for e in r.events] for r in seg],
                        np.int32)
        jidx = np.array([[e.partner for e in r.events] for r in seg],
                        np.int32)
        # global event indices — the reference loop's per-event counter,
        # i.e. the rand-k mask step (every round has exactly n_ev events)
        eidx = (done * n_ev + np.arange(len(seg) * n_ev)).reshape(
            len(seg), n_ev).astype(np.int32)
        lrs = np.array([r.lr for r in seg], np.float32)
        keep = np.stack([r.keep for r in seg])
        rw = np.stack([r.donor_w for r in seg]).astype(np.float32)
        ew, cw = [], []
        for r in seg:
            a = r.alive
            # metrics describe the HONEST fleet (same mask as run_adpsgd)
            m = (a & ~byz) if has_byz and (a & ~byz).any() else a
            ew.append(m / m.sum() if m.any() and not m.all()
                      else np.full(n, 1.0 / n))
            cw.append(m / m.sum() if m.any() else np.full(n, 1.0 / n))
        # per-seed batch tensors in event order, replaying the reference
        # loop's batch-stream consumption draw for draw
        bx = np.zeros((len(seed_list), len(seg), n_ev, tau,
                       cfg.batch_size) + data.x.shape[1:], data.x.dtype)
        by = np.zeros((len(seed_list), len(seg), n_ev, tau,
                       cfg.batch_size), np.int32)
        for si, rng in enumerate(rngs):
            for t, r in enumerate(seg):
                round_shards = (shards.shards_at(done + t)
                                if hasattr(shards, "shards_at") else shards)
                for k, e in enumerate(r.events):
                    shard = round_shards[e.worker]
                    ix = rng.integers(0, len(shard), (tau, cfg.batch_size))
                    bx[si, t, k] = data.x[shard[ix]]
                    by[si, t, k] = data.y[shard[ix]]

        (stacked, snap, err, stale, histn), outs = _adpsgd_scan(
            stacked, snap, err, stale, histn,
            jnp.asarray(bx), jnp.asarray(by),
            jnp.asarray(iidx), jnp.asarray(jidx), jnp.asarray(eidx),
            jnp.asarray(lrs), jnp.asarray(keep), jnp.asarray(rw),
            jnp.asarray(np.stack(ew), dtype=jnp.float32),
            jnp.asarray(np.stack(cw), dtype=jnp.float32),
            jnp.asarray(byz), jnp.float32(atk_scale),
            jnp.float32(screen_z), skey, jnp.float32(cfg.sparse_gamma),
            tx, ty, adapter=adapter, tau=tau, interpret=interp,
            kind=codec.kind, k=k_abs, ef=cfg.error_feedback,
            screen=screen, attack=atk_kind if has_byz else "")
        outs = {k: np.asarray(v) for k, v in outs.items()}
        # the scan carries its own staleness counters; they must agree
        # with the host schedule replay event for event (the documented
        # invariant — a drifted join-reset or partner-increment rule in
        # either implementation fails every fused run immediately)
        sched_st = np.array([[e.staleness for e in r.events] for r in seg])
        if not np.array_equal(outs["event_staleness"][0], sched_st):
            raise AssertionError(
                "fused AD-PSGD scan staleness counters diverged from the "
                "host schedule replay (engine.adpsgd_schedule)")

        for t, r in enumerate(seg):
            for si, hist in enumerate(hists):
                hist.records.append(RoundRecord(
                    round=done + t, round_time=0.0, waiting_time=0.0,
                    accuracy=float(outs["acc"][si, t]),
                    loss=float(outs["loss"][si, t]),
                    mean_tau=float(tau), num_links=schedule.num_links,
                    consensus=float(outs["consensus"][si, t]),
                    cumulative_time=r.clock,
                    staleness=r.mean_staleness))
                if screen:
                    hist.screen_rejects.append(int(outs["rejects"][si, t]))
        done += len(seg)
    for si, hist in enumerate(hists):
        hist.final_params = jax.tree.map(lambda l, si=si: l[si], stacked)
    return hists if batched else hists[0]
