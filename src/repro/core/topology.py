"""P2P network topology: adjacency/Laplacian algebra, connectivity,
mixing matrices, and matching decomposition (Sec. II-A, Eq. 1, 5-6).

Everything here is host-side coordinator math (numpy), deliberately
outside jit: topologies are round-static control inputs.
"""
from __future__ import annotations

import numpy as np


# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------

def full_topology(n: int) -> np.ndarray:
    """Complete graph K_n — FedHP's default base topology A^0 (the
    controller prunes links from it, Alg. 3)."""
    a = np.ones((n, n), dtype=np.int8) - np.eye(n, dtype=np.int8)
    return a


def ring_topology(n: int) -> np.ndarray:
    """Ring — the D-PSGD [12] / AD-PSGD [23] baseline topology."""
    a = np.zeros((n, n), dtype=np.int8)
    if n == 1:
        return a
    idx = np.arange(n)
    a[idx, (idx + 1) % n] = 1
    a[idx, (idx - 1) % n] = 1
    if n == 2:
        a = np.clip(a, 0, 1)
    return a


def erdos_topology(n: int, p: float, rng: np.random.Generator) -> np.ndarray:
    """Erdős–Rényi base topology, retried until connected."""
    for _ in range(1000):
        u = rng.random((n, n))
        a = ((u + u.T) / 2 < p).astype(np.int8)
        np.fill_diagonal(a, 0)
        if is_connected(a):
            return a
    # fall back: ring + random chords
    a = ring_topology(n)
    return a


def make_base_topology(n: int, spec: str, seed: int = 0) -> np.ndarray:
    """Parse a base-topology spec string: full | ring | erdos:<p>."""
    if spec == "full":
        return full_topology(n)
    if spec == "ring":
        return ring_topology(n)
    if spec.startswith("erdos:"):
        p = float(spec.split(":", 1)[1])
        return erdos_topology(n, p, np.random.default_rng(seed))
    raise ValueError(f"unknown topology spec {spec!r}")


# ---------------------------------------------------------------------------
# Spectral / connectivity (Eq. 1; Assumption 4)
# ---------------------------------------------------------------------------

def laplacian(adj: np.ndarray) -> np.ndarray:
    """Graph Laplacian L = D - A (Eq. 1; spectral connectivity input)."""
    adj = np.asarray(adj, dtype=np.float64)
    return np.diag(adj.sum(axis=1)) - adj


def algebraic_connectivity(adj: np.ndarray) -> float:
    """lambda_2 of the Laplacian; > 0 iff the graph is connected."""
    n = adj.shape[0]
    if n == 1:
        return 1.0  # single vertex: trivially "connected"
    vals = np.linalg.eigvalsh(laplacian(adj))
    return float(vals[1])


def is_connected(adj: np.ndarray) -> bool:
    """BFS connectivity (cheaper and exact vs eigenvalue tolerance)."""
    n = adj.shape[0]
    if n <= 1:
        return True
    seen = np.zeros(n, dtype=bool)
    stack = [0]
    seen[0] = True
    while stack:
        i = stack.pop()
        for j in np.nonzero(adj[i])[0]:
            if not seen[j]:
                seen[j] = True
                stack.append(int(j))
    return bool(seen.all())


def connected_components(adj: np.ndarray,
                         nodes: np.ndarray | None = None) -> list[np.ndarray]:
    """Connected components of the subgraph induced by ``nodes`` (default:
    all vertices). Returns a list of index arrays."""
    n = adj.shape[0]
    nodes = np.arange(n) if nodes is None else np.asarray(nodes)
    in_sub = np.zeros(n, bool)
    in_sub[nodes] = True
    seen = np.zeros(n, bool)
    comps: list[np.ndarray] = []
    for start in nodes:
        if seen[start]:
            continue
        stack = [int(start)]
        seen[start] = True
        comp = [int(start)]
        while stack:
            i = stack.pop()
            for j in np.nonzero(adj[i])[0]:
                if in_sub[j] and not seen[j]:
                    seen[j] = True
                    comp.append(int(j))
                    stack.append(int(j))
        comps.append(np.array(sorted(comp)))
    return comps


def repair_connectivity(adj: np.ndarray, alive: np.ndarray | None = None,
                        cost: np.ndarray | None = None) -> np.ndarray:
    """Cheapest-reconnect pass (churn tolerance): if the alive-induced
    subgraph is disconnected, greedily add the min-cost cross-component
    edge until one component remains (Kruskal over the component graph).

    ``cost`` is an (N,N) link-time matrix (e.g. beta); unit costs when
    None. Dead rows/columns are zeroed in the result. Returns a new array.
    """
    adj = np.array(adj, copy=True)
    n = adj.shape[0]
    alive = np.ones(n, bool) if alive is None else np.asarray(alive, bool)
    dead = np.nonzero(~alive)[0]
    adj[dead, :] = 0
    adj[:, dead] = 0
    live = np.nonzero(alive)[0]
    if len(live) <= 1:
        return adj
    cost = np.ones((n, n)) if cost is None else np.asarray(cost, np.float64)
    comps = connected_components(adj, live)
    while len(comps) > 1:
        best: tuple[float, int, int] | None = None
        base = comps[0]
        for other in comps[1:]:
            sub = cost[np.ix_(base, other)]
            k = int(np.argmin(sub))
            i, j = base[k // len(other)], other[k % len(other)]
            c = float(sub.flat[k])
            if best is None or c < best[0]:
                best = (c, int(i), int(j))
        _, i, j = best
        adj[i, j] = adj[j, i] = 1
        comps = connected_components(adj, live)
    return adj


# ---------------------------------------------------------------------------
# Mixing matrices (Eq. 5-6; Assumption 4)
# ---------------------------------------------------------------------------

def mixing_matrix_uniform(adj: np.ndarray) -> np.ndarray:
    """Paper's Eq. (6): w_ij = 1/(u_max+1); symmetric doubly stochastic."""
    adj = np.asarray(adj, dtype=np.float64)
    n = adj.shape[0]
    if n == 1:
        return np.ones((1, 1))
    u_max = adj.sum(axis=1).max()
    w = adj / (u_max + 1.0)
    np.fill_diagonal(w, 0.0)
    w += np.diag(1.0 - w.sum(axis=1))
    return w


def mixing_matrix_metropolis(adj: np.ndarray) -> np.ndarray:
    """Metropolis–Hastings weights: w_ij = 1/(1+max(d_i,d_j)).

    Beyond-paper option: strictly better spectral gap than Eq. (6) on
    irregular graphs while remaining symmetric doubly stochastic and
    requiring only neighbor-degree knowledge.
    """
    adj = np.asarray(adj, dtype=np.float64)
    n = adj.shape[0]
    if n == 1:
        return np.ones((1, 1))
    deg = adj.sum(axis=1)
    w = np.zeros_like(adj)
    for i in range(n):
        for j in np.nonzero(adj[i])[0]:
            w[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
    w += np.diag(1.0 - w.sum(axis=1))
    return w


def spectral_gap_rho(w: np.ndarray) -> float:
    """rho = max(|lambda_2|, |lambda_N|) of the mixing matrix (Assumption 4)."""
    n = w.shape[0]
    if n == 1:
        return 0.0
    vals = np.sort(np.linalg.eigvalsh((w + w.T) / 2))
    return float(max(abs(vals[0]), abs(vals[-2])))


# ---------------------------------------------------------------------------
# Matching decomposition (TPU gossip: one collective-permute per matching)
# ---------------------------------------------------------------------------

def matching_decomposition(adj: np.ndarray) -> list[list[tuple[int, int]]]:
    """Greedy edge-coloring of the topology into matchings.

    Each matching is a set of vertex-disjoint undirected edges; on TPU a
    matching executes as ONE `lax.ppermute` whose permutation swaps each
    edge's endpoints (an involution). Vizing guarantees <= Delta+1 matchings;
    the greedy bound is 2*Delta-1, in practice ~Delta for our graphs.
    """
    n = adj.shape[0]
    edges = [(i, j) for i in range(n) for j in range(i + 1, n) if adj[i, j]]
    # sort by degree-sum so high-degree vertices get colored first
    deg = adj.sum(axis=1)
    edges.sort(key=lambda e: -(deg[e[0]] + deg[e[1]]))
    matchings: list[list[tuple[int, int]]] = []
    used: list[set[int]] = []
    for (i, j) in edges:
        for m, u in zip(matchings, used):
            if i not in u and j not in u:
                m.append((i, j))
                u.update((i, j))
                break
        else:
            matchings.append([(i, j)])
            used.append({i, j})
    return matchings


def matchings_to_perms(matchings: list[list[tuple[int, int]]],
                       n: int) -> np.ndarray:
    """(M, N) permutation table: perm[m, i] = partner of i in matching m
    (or i itself if unmatched). Each row is an involution."""
    perms = np.tile(np.arange(n), (len(matchings), 1))
    for m, match in enumerate(matchings):
        for (i, j) in match:
            perms[m, i] = j
            perms[m, j] = i
    return perms


def validate_topology(adj: np.ndarray) -> None:
    """Reject adjacency matrices that break the Sec. II-A graph model:
    must be square, symmetric (undirected), 0/1 and self-loop-free."""
    adj = np.asarray(adj)
    if adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
        raise ValueError(f"adjacency must be square, got {adj.shape}")
    if not np.array_equal(adj, adj.T):
        raise ValueError("adjacency must be symmetric (undirected graph)")
    if np.any(np.diag(adj) != 0):
        raise ValueError("no self loops allowed")
    if not np.isin(adj, (0, 1)).all():
        raise ValueError("adjacency entries must be 0/1")
