"""P2P network topology: adjacency/Laplacian algebra, connectivity,
mixing matrices, and matching decomposition (Sec. II-A, Eq. 1, 5-6).

Everything here is host-side coordinator math (numpy), deliberately
outside jit: topologies are round-static control inputs.

Two representations coexist:

- dense ``[N, N]`` 0/1 adjacency matrices — the original small-W path;
- sparse ``[E, 2]`` edge arrays (undirected, each row ``i < j``) with
  per-edge mixing weights — the large-W path, where anything O(N^2)
  (dense mixing matrices, row scans) is off the table. The edge-list
  helpers (``edges_from_adj``, ``ring_edges``, ``edge_mixing_weights``,
  ``connected_components_edges``, ``UnionFind``) never materialize a
  dense matrix.
"""
from __future__ import annotations

import warnings

import numpy as np


# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------

def full_topology(n: int) -> np.ndarray:
    """Complete graph K_n — FedHP's default base topology A^0 (the
    controller prunes links from it, Alg. 3)."""
    a = np.ones((n, n), dtype=np.int8) - np.eye(n, dtype=np.int8)
    return a


def ring_topology(n: int) -> np.ndarray:
    """Ring — the D-PSGD [12] / AD-PSGD [23] baseline topology."""
    a = np.zeros((n, n), dtype=np.int8)
    if n == 1:
        return a
    idx = np.arange(n)
    a[idx, (idx + 1) % n] = 1
    a[idx, (idx - 1) % n] = 1
    if n == 2:
        a = np.clip(a, 0, 1)
    return a


def erdos_topology(n: int, p: float, rng: np.random.Generator) -> np.ndarray:
    """Erdős–Rényi base topology, retried until connected.

    If 1000 draws never produce a connected graph (tiny ``p``), falls
    back to a ring plus seeded random chords — connected by the ring,
    with the chords recovering some of the requested edge density (a
    bare ring has the worst spectral gap of any connected topology, so
    silently returning one would sabotage low-``p`` specs). The
    fallback warns so callers can tell the spec was unsatisfiable.
    """
    for _ in range(1000):
        u = rng.random((n, n))
        a = ((u + u.T) / 2 < p).astype(np.int8)
        np.fill_diagonal(a, 0)
        if is_connected(a):
            return a
    # fall back: ring + seeded random chords
    warnings.warn(
        f"erdos_topology(n={n}, p={p}): no connected draw in 1000 tries;"
        " falling back to ring + random chords", RuntimeWarning,
        stacklevel=2)
    a = ring_topology(n)
    if n > 3:
        # aim for the requested expected edge count, minus the ring's n
        # edges; always add at least one chord so the fallback never
        # degrades to a bare ring
        target = max(1, int(round(p * n * (n - 1) / 2)) - n)
        iu, ju = np.triu_indices(n, k=1)
        free = np.nonzero(a[iu, ju] == 0)[0]
        take = min(target, free.size)
        if take > 0:
            sel = free[rng.choice(free.size, size=take, replace=False)]
            a[iu[sel], ju[sel]] = 1
            a[ju[sel], iu[sel]] = 1
    return a


def barabasi_albert_topology(n: int, m: int,
                             rng: np.random.Generator) -> np.ndarray:
    """Barabási–Albert preferential attachment: scale-free degree
    distribution, the complex-network regime where degree heterogeneity
    drives convergence as hard as compute heterogeneity (arxiv
    2312.04504). Each arriving vertex attaches ``m`` edges to existing
    vertices with probability proportional to their current degree.

    Starts from a complete core of ``m + 1`` vertices, so the graph is
    connected by construction. Requires ``1 <= m < n``.
    """
    if not 1 <= m < n:
        raise ValueError(f"barabasi_albert needs 1 <= m < n, got m={m} n={n}")
    a = np.zeros((n, n), dtype=np.int8)
    core = m + 1
    a[:core, :core] = full_topology(core)
    # repeated-nodes list: each endpoint appears once per incident edge,
    # so a uniform draw from it IS the preferential-attachment law
    targets: list[int] = [v for i in range(core) for v in (i,) * m]
    for v in range(core, n):
        chosen: set[int] = set()
        while len(chosen) < m:
            chosen.add(int(targets[int(rng.integers(0, len(targets)))]))
        for u in chosen:
            a[v, u] = a[u, v] = 1
            targets.extend((v, u))
    return a


def watts_strogatz_topology(n: int, k: int, p: float,
                            rng: np.random.Generator) -> np.ndarray:
    """Watts–Strogatz small world: ring lattice with ``k`` neighbors per
    vertex (``k/2`` each side, ``k`` even) where each lattice edge is
    rewired to a random endpoint with probability ``p`` — short path
    lengths at ring-like degree regularity.

    Rewired draws are retried until connected (100 tries); if ``p`` is
    so high the rewiring keeps disconnecting the lattice, falls back to
    the unrewired lattice (always connected) and warns, mirroring
    ``erdos_topology``'s unsatisfiable-spec behavior.
    """
    if not (2 <= k < n and k % 2 == 0):
        raise ValueError(f"watts_strogatz needs even 2 <= k < n, "
                         f"got k={k} n={n}")
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"rewiring probability must be in [0, 1], got {p}")
    idx = np.arange(n)
    lattice = np.zeros((n, n), dtype=np.int8)
    for off in range(1, k // 2 + 1):
        lattice[idx, (idx + off) % n] = 1
        lattice[(idx + off) % n, idx] = 1
    for _ in range(100):
        a = lattice.copy()
        for off in range(1, k // 2 + 1):
            for i in range(n):
                j = (i + off) % n
                if a[i, j] and rng.random() < p:
                    free = np.nonzero((a[i] == 0) & (idx != i))[0]
                    if free.size == 0:
                        continue
                    t = int(free[int(rng.integers(0, free.size))])
                    a[i, j] = a[j, i] = 0
                    a[i, t] = a[t, i] = 1
        if is_connected(a):
            return a
    warnings.warn(
        f"watts_strogatz_topology(n={n}, k={k}, p={p}): no connected "
        "rewiring in 100 tries; falling back to the unrewired lattice",
        RuntimeWarning, stacklevel=2)
    return lattice


def rack_assignment(n: int, racks: int) -> np.ndarray:
    """Worker -> rack map for the geographic topology and correlated
    failure schedules: ``n`` workers split into ``racks`` contiguous
    blocks (sizes differing by at most one), returned as an ``[n]``
    int64 array of rack ids."""
    if not 1 <= racks <= n:
        raise ValueError(f"need 1 <= racks <= n, got racks={racks} n={n}")
    out = np.empty(n, dtype=np.int64)
    for r, block in enumerate(np.array_split(np.arange(n), racks)):
        out[block] = r
    return out


def geo_topology(n: int, racks: int, rng: np.random.Generator) -> np.ndarray:
    """Geographic/rack-correlated topology: workers live in ``racks``
    contiguous racks (``rack_assignment``), each rack internally
    complete (cheap intra-rack links), racks joined in a ring by one
    seeded uplink each (rack ``r`` -> rack ``r+1`` between random
    members) — dense locally, sparse globally, connected by
    construction. The same rack map drives
    ``ChurnSchedule.generate_correlated`` outages, so a rack failure
    takes out exactly one dense neighborhood."""
    assign = rack_assignment(n, racks)
    a = np.zeros((n, n), dtype=np.int8)
    same = assign[:, None] == assign[None, :]
    a[same] = 1
    np.fill_diagonal(a, 0)
    if racks > 1:
        for r in range(racks):
            src = np.nonzero(assign == r)[0]
            dst = np.nonzero(assign == (r + 1) % racks)[0]
            i = int(src[int(rng.integers(0, src.size))])
            j = int(dst[int(rng.integers(0, dst.size))])
            a[i, j] = a[j, i] = 1
    return a


def make_base_topology(n: int, spec: str, seed: int = 0) -> np.ndarray:
    """Parse a base-topology spec string.

    Forms: ``full`` | ``ring`` | ``erdos:<p>`` | ``ba:<m>`` |
    ``ws:<k>:<p>`` | ``geo:<racks>`` (see README's spec-string table).
    All families pass ``validate_topology`` and convert to the sparse
    engine's edge lists via ``edges_from_adj`` unchanged.
    """
    if spec == "full":
        return full_topology(n)
    if spec == "ring":
        return ring_topology(n)
    if spec.startswith("erdos:"):
        p = float(spec.split(":", 1)[1])
        return erdos_topology(n, p, np.random.default_rng(seed))
    if spec.startswith("ba:"):
        m = int(spec.split(":", 1)[1])
        return barabasi_albert_topology(n, m, np.random.default_rng(seed))
    if spec.startswith("ws:"):
        _, k, p = spec.split(":", 2)
        return watts_strogatz_topology(n, int(k), float(p),
                                       np.random.default_rng(seed))
    if spec.startswith("geo:"):
        racks = int(spec.split(":", 1)[1])
        return geo_topology(n, racks, np.random.default_rng(seed))
    raise ValueError(f"unknown topology spec {spec!r}")


# ---------------------------------------------------------------------------
# Spectral / connectivity (Eq. 1; Assumption 4)
# ---------------------------------------------------------------------------

def laplacian(adj: np.ndarray) -> np.ndarray:
    """Graph Laplacian L = D - A (Eq. 1; spectral connectivity input)."""
    adj = np.asarray(adj, dtype=np.float64)
    return np.diag(adj.sum(axis=1)) - adj


def algebraic_connectivity(adj: np.ndarray) -> float:
    """lambda_2 of the Laplacian; > 0 iff the graph is connected."""
    n = adj.shape[0]
    if n == 1:
        return 1.0  # single vertex: trivially "connected"
    vals = np.linalg.eigvalsh(laplacian(adj))
    return float(vals[1])


def is_connected(adj: np.ndarray) -> bool:
    """BFS connectivity (cheaper and exact vs eigenvalue tolerance)."""
    n = adj.shape[0]
    if n <= 1:
        return True
    seen = np.zeros(n, dtype=bool)
    stack = [0]
    seen[0] = True
    while stack:
        i = stack.pop()
        for j in np.nonzero(adj[i])[0]:
            if not seen[j]:
                seen[j] = True
                stack.append(int(j))
    return bool(seen.all())


def connected_components(adj: np.ndarray,
                         nodes: np.ndarray | None = None) -> list[np.ndarray]:
    """Connected components of the subgraph induced by ``nodes`` (default:
    all vertices). Returns a list of index arrays."""
    n = adj.shape[0]
    nodes = np.arange(n) if nodes is None else np.asarray(nodes)
    in_sub = np.zeros(n, bool)
    in_sub[nodes] = True
    seen = np.zeros(n, bool)
    comps: list[np.ndarray] = []
    for start in nodes:
        if seen[start]:
            continue
        stack = [int(start)]
        seen[start] = True
        comp = [int(start)]
        while stack:
            i = stack.pop()
            for j in np.nonzero(adj[i])[0]:
                if in_sub[j] and not seen[j]:
                    seen[j] = True
                    comp.append(int(j))
                    stack.append(int(j))
        comps.append(np.array(sorted(comp)))
    return comps


class UnionFind:
    """Disjoint-set forest with path compression + union by size.

    The workhorse behind the edge-list connectivity helpers and
    ``repair_connectivity``: component queries in near-O(1) without ever
    scanning dense adjacency rows.
    """

    def __init__(self, n: int):
        self.parent = np.arange(n, dtype=np.int64)
        self.size = np.ones(n, dtype=np.int64)
        self.count = n                      # number of disjoint sets

    def find(self, i: int) -> int:
        """Root of ``i``'s set (with path compression)."""
        p = self.parent
        root = i
        while p[root] != root:
            root = p[root]
        while p[i] != root:                 # compress
            p[i], i = root, p[i]
        return int(root)

    def union(self, i: int, j: int) -> bool:
        """Merge the sets of ``i`` and ``j``; True if they were disjoint."""
        ri, rj = self.find(i), self.find(j)
        if ri == rj:
            return False
        if self.size[ri] < self.size[rj]:
            ri, rj = rj, ri
        self.parent[rj] = ri
        self.size[ri] += self.size[rj]
        self.count -= 1
        return True


# ---------------------------------------------------------------------------
# Edge-list representation (sparse gossip path; no dense row scans)
# ---------------------------------------------------------------------------

def edges_from_adj(adj: np.ndarray) -> np.ndarray:
    """Dense adjacency -> ``[E, 2]`` int32 undirected edge array, each
    row ``i < j``, sorted row-major (the boundary op between the dense
    planner output and the sparse engine)."""
    i, j = np.nonzero(np.triu(np.asarray(adj), k=1))
    return np.stack([i, j], axis=1).astype(np.int32)


def adj_from_edges(edges: np.ndarray, n: int) -> np.ndarray:
    """``[E, 2]`` edge array -> dense int8 adjacency (small-W parity and
    validation only; defeats the point at large W)."""
    a = np.zeros((n, n), dtype=np.int8)
    e = np.asarray(edges).reshape(-1, 2)
    if e.size:
        a[e[:, 0], e[:, 1]] = 1
        a[e[:, 1], e[:, 0]] = 1
    return a


def ring_edges(n: int) -> np.ndarray:
    """Ring topology directly as an ``[n, 2]`` edge array (no dense
    [n, n] intermediate) — the D-PSGD baseline at large W."""
    if n <= 1:
        return np.zeros((0, 2), dtype=np.int32)
    if n == 2:
        return np.array([[0, 1]], dtype=np.int32)
    idx = np.arange(n - 1, dtype=np.int32)
    chain = np.stack([idx, idx + 1], axis=1)
    return np.concatenate([np.array([[0, n - 1]], np.int32), chain])


def degrees_from_edges(edges: np.ndarray, n: int) -> np.ndarray:
    """Vertex degrees of an ``[E, 2]`` edge array via bincount (O(E))."""
    e = np.asarray(edges).reshape(-1, 2)
    return np.bincount(e.reshape(-1), minlength=n).astype(np.int64)


def mask_edges(edges: np.ndarray, alive: np.ndarray) -> np.ndarray:
    """Drop edges touching dead workers (the edge-list analogue of
    zeroing dead rows/columns of the adjacency)."""
    e = np.asarray(edges).reshape(-1, 2)
    alive = np.asarray(alive, bool)
    keep = alive[e[:, 0]] & alive[e[:, 1]]
    return e[keep]


def edge_mixing_weights(edges: np.ndarray, n: int,
                        mixing: str = "uniform") -> np.ndarray:
    """Per-edge mixing weight ``w_e = W[i, j]`` from degrees alone, in
    O(E) — bit-identical to the off-diagonal entries of the dense
    ``mixing_matrix_uniform`` (Eq. 6) / ``mixing_matrix_metropolis``
    matrices, without building them. Self-weights are implicit: the
    sparse update ``y_i = x_i + sum_e w_e (x_j - x_i)`` already encodes
    ``W_ii = 1 - sum_j W_ij``.
    """
    e = np.asarray(edges).reshape(-1, 2)
    if e.shape[0] == 0:
        return np.zeros((0,), np.float64)
    deg = degrees_from_edges(e, n)
    if mixing == "uniform":
        u_max = deg.max()
        return np.full(e.shape[0], 1.0 / (u_max + 1.0))
    if mixing == "metropolis":
        return 1.0 / (1.0 + np.maximum(deg[e[:, 0]], deg[e[:, 1]]))
    raise ValueError(f"unknown mixing {mixing!r}")


def directed_edges(edges: np.ndarray,
                   weights: np.ndarray) -> tuple[np.ndarray, np.ndarray,
                                                 np.ndarray]:
    """Undirected ``[E, 2]`` + weights -> directed ``(src, dst, w)``
    arrays of length 2E (both orientations), the device-side gossip
    format: ``y[dst] += w * (x[src] - x[dst])``."""
    e = np.asarray(edges).reshape(-1, 2).astype(np.int32)
    w = np.asarray(weights, np.float32).reshape(-1)
    src = np.concatenate([e[:, 0], e[:, 1]])
    dst = np.concatenate([e[:, 1], e[:, 0]])
    return src, dst, np.concatenate([w, w])


def connected_components_edges(edges: np.ndarray, n: int,
                               nodes: np.ndarray | None = None
                               ) -> list[np.ndarray]:
    """Connected components from an edge array via union-find — O(E α)
    instead of the dense BFS's O(N^2) row scans. Matches
    ``connected_components``: components ordered by smallest member,
    members sorted."""
    nodes = np.arange(n) if nodes is None else np.asarray(nodes)
    in_sub = np.zeros(n, bool)
    in_sub[nodes] = True
    uf = UnionFind(n)
    for i, j in mask_edges(edges, in_sub):
        uf.union(int(i), int(j))
    groups: dict[int, list[int]] = {}
    for v in sorted(int(x) for x in nodes):
        groups.setdefault(uf.find(v), []).append(v)
    return [np.array(g) for g in groups.values()]


def is_connected_edges(edges: np.ndarray, n: int) -> bool:
    """Edge-array connectivity check (union-find; O(E α))."""
    if n <= 1:
        return True
    uf = UnionFind(n)
    for i, j in np.asarray(edges).reshape(-1, 2):
        uf.union(int(i), int(j))
    return uf.count == 1


def repair_connectivity(adj: np.ndarray, alive: np.ndarray | None = None,
                        cost: np.ndarray | None = None) -> np.ndarray:
    """Cheapest-reconnect pass (churn tolerance): if the alive-induced
    subgraph is disconnected, greedily add the GLOBAL min-cost
    cross-component edge until one component remains — true Kruskal
    over the component graph, so the added edges form a minimum-cost
    spanning forest of the components (ties broken row-major on the
    live-index grid, keeping the repair a pure function of its inputs).

    Components are tracked with a union-find instead of re-running BFS
    after every added edge; candidate costs live in one live x live
    matrix whose intra-component entries are masked as the merges
    happen, so the whole repair is O(L^2) after the initial component
    pass rather than O(C L^2) BFS re-scans.

    ``cost`` is an (N,N) link-time matrix (e.g. beta); unit costs when
    None. Dead rows/columns are zeroed in the result. Returns a new array.
    """
    adj = np.array(adj, copy=True)
    n = adj.shape[0]
    alive = np.ones(n, bool) if alive is None else np.asarray(alive, bool)
    dead = np.nonzero(~alive)[0]
    adj[dead, :] = 0
    adj[:, dead] = 0
    live = np.nonzero(alive)[0]
    nl = len(live)
    if nl <= 1:
        return adj
    uf = UnionFind(nl)                       # over live-local indices
    loc = np.full(n, -1, np.int64)
    loc[live] = np.arange(nl)
    li, lj = np.nonzero(np.triu(adj[np.ix_(live, live)], k=1))
    for a, b in zip(li, lj):
        uf.union(int(a), int(b))
    if uf.count == 1:
        return adj
    if cost is None:
        sub = np.ones((nl, nl))
    else:
        sub = np.asarray(cost, np.float64)[np.ix_(live, live)].copy()
    # mask intra-component candidates (incl. the diagonal) once
    members: dict[int, list[int]] = {}
    for v in range(nl):
        members.setdefault(uf.find(v), []).append(v)
    for g in members.values():
        sub[np.ix_(g, g)] = np.inf
    while uf.count > 1:
        k = int(np.argmin(sub))              # first flat min: deterministic
        a, b = divmod(k, nl)
        adj[live[a], live[b]] = adj[live[b], live[a]] = 1
        ra, rb = uf.find(a), uf.find(b)
        ga, gb = members.pop(ra), members.pop(rb)
        sub[np.ix_(ga, gb)] = np.inf
        sub[np.ix_(gb, ga)] = np.inf
        uf.union(a, b)
        members[uf.find(a)] = ga + gb
    return adj


# ---------------------------------------------------------------------------
# Mixing matrices (Eq. 5-6; Assumption 4)
# ---------------------------------------------------------------------------

def mixing_matrix_uniform(adj: np.ndarray) -> np.ndarray:
    """Paper's Eq. (6): w_ij = 1/(u_max+1); symmetric doubly stochastic."""
    adj = np.asarray(adj, dtype=np.float64)
    n = adj.shape[0]
    if n == 1:
        return np.ones((1, 1))
    u_max = adj.sum(axis=1).max()
    w = adj / (u_max + 1.0)
    np.fill_diagonal(w, 0.0)
    w += np.diag(1.0 - w.sum(axis=1))
    return w


def mixing_matrix_metropolis(adj: np.ndarray) -> np.ndarray:
    """Metropolis–Hastings weights: w_ij = 1/(1+max(d_i,d_j)).

    Beyond-paper option: strictly better spectral gap than Eq. (6) on
    irregular graphs while remaining symmetric doubly stochastic and
    requiring only neighbor-degree knowledge.
    """
    adj = np.asarray(adj, dtype=np.float64)
    n = adj.shape[0]
    if n == 1:
        return np.ones((1, 1))
    deg = adj.sum(axis=1)
    # vectorized degree broadcast: at W=2048 the old per-edge Python loop
    # dominated replan time for irregular (BA/geo) graphs
    w = np.where(adj > 0, 1.0 / (1.0 + np.maximum.outer(deg, deg)), 0.0)
    w += np.diag(1.0 - w.sum(axis=1))
    return w


def spectral_gap_rho(w: np.ndarray) -> float:
    """rho = max(|lambda_2|, |lambda_N|) of the mixing matrix (Assumption 4)."""
    n = w.shape[0]
    if n == 1:
        return 0.0
    vals = np.sort(np.linalg.eigvalsh((w + w.T) / 2))
    return float(max(abs(vals[0]), abs(vals[-2])))


# ---------------------------------------------------------------------------
# Matching decomposition (TPU gossip: one collective-permute per matching)
# ---------------------------------------------------------------------------

def matching_decomposition(adj: np.ndarray) -> list[list[tuple[int, int]]]:
    """Greedy edge-coloring of the topology into matchings.

    Each matching is a set of vertex-disjoint undirected edges; on TPU a
    matching executes as ONE `lax.ppermute` whose permutation swaps each
    edge's endpoints (an involution). Vizing guarantees <= Delta+1 matchings;
    the greedy bound is 2*Delta-1, in practice ~Delta for our graphs.
    """
    n = adj.shape[0]
    edges = [(i, j) for i in range(n) for j in range(i + 1, n) if adj[i, j]]
    # sort by degree-sum so high-degree vertices get colored first
    deg = adj.sum(axis=1)
    edges.sort(key=lambda e: -(deg[e[0]] + deg[e[1]]))
    matchings: list[list[tuple[int, int]]] = []
    used: list[set[int]] = []
    for (i, j) in edges:
        for m, u in zip(matchings, used):
            if i not in u and j not in u:
                m.append((i, j))
                u.update((i, j))
                break
        else:
            matchings.append([(i, j)])
            used.append({i, j})
    return matchings


def matchings_to_perms(matchings: list[list[tuple[int, int]]],
                       n: int) -> np.ndarray:
    """(M, N) permutation table: perm[m, i] = partner of i in matching m
    (or i itself if unmatched). Each row is an involution."""
    perms = np.tile(np.arange(n), (len(matchings), 1))
    for m, match in enumerate(matchings):
        for (i, j) in match:
            perms[m, i] = j
            perms[m, j] = i
    return perms


def validate_topology(adj: np.ndarray) -> None:
    """Reject adjacency matrices that break the Sec. II-A graph model:
    must be square, symmetric (undirected), 0/1 and self-loop-free."""
    adj = np.asarray(adj)
    if adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
        raise ValueError(f"adjacency must be square, got {adj.shape}")
    if not np.array_equal(adj, adj.T):
        raise ValueError("adjacency must be symmetric (undirected graph)")
    if np.any(np.diag(adj) != 0):
        raise ValueError("no self loops allowed")
    if not np.isin(adj, (0, 1)).all():
        raise ValueError("adjacency entries must be 0/1")
