"""Consensus-distance machinery (Sec. II-C, IV-A; Eq. 7-9, 34-39, 43).

The coordinator only ever sees distances measured along topology edges
(worker i can compute ||x_i - x_j|| only for j in N_i). Unmeasured pairs are
estimated via the triangle-inequality shortest path (Floyd-Warshall,
Eq. 37-38) and EMA-smoothed (Eq. 39). The consensus budget D_max follows the
EMA of the mean local-update norm (Eq. 43, after Kong et al. [35]).
"""
from __future__ import annotations

import numpy as np

_INF = np.float64(np.inf)


def measured_distance_matrix(adj: np.ndarray,
                             pair_dist: np.ndarray) -> np.ndarray:
    """Mask a full pairwise-distance matrix down to topology edges.

    In the real system workers report only edge distances; simulation
    computes the full matrix and this mask models the coordinator's view.
    """
    d = np.where(adj > 0, pair_dist, _INF)
    np.fill_diagonal(d, 0.0)
    return d


# Beyond this worker count the exact O(N^3) Floyd-Warshall is replaced by a
# bounded-hop min-plus relaxation over the measured edge list (O(hops * E * N)).
FW_DENSE_MAX = 512


def _bounded_hop_estimate(d: np.ndarray, hops: int) -> np.ndarray:
    """Min-plus relaxation restricted to the measured edges.

    Each hop applies d[:, j] <- min(d[:, j], d[:, i] + w_ij) simultaneously
    over every measured (undirected, so both orientations) edge, so after
    ``hops`` passes d[i, j] is the exact shortest path among paths of at most
    ``hops + 1`` edges — longer detours are ignored, which upper-bounds the
    true shortest path exactly like the triangle inequality does (Eq. 37).
    Cost per hop is O(E * N) with one reduceat, no N x N x N blowup.
    """
    n = d.shape[0]
    fin = np.isfinite(d)
    np.fill_diagonal(fin, False)
    ii, jj = np.nonzero(fin)
    if ii.size == 0:
        return d
    order = np.argsort(jj, kind="stable")
    ii, jj = ii[order], jj[order]
    w = d[ii, jj]
    starts = np.flatnonzero(np.r_[True, jj[1:] != jj[:-1]])
    dest = jj[starts]
    for _ in range(hops):
        cand = d[:, ii] + w[None, :]                       # [N, 2E]
        mins = np.minimum.reduceat(cand, starts, axis=1)   # [N, U]
        before = d[:, dest]
        after = np.minimum(before, mins)
        if np.array_equal(before, after):
            break
        d[:, dest] = after
    return d


def floyd_warshall_estimate(edge_dist: np.ndarray, *,
                            max_dense: int = FW_DENSE_MAX,
                            hops: int = 3) -> np.ndarray:
    """Eq. (37)-(38): estimate unmeasured pair distances as the shortest
    path over measured edges.

    For n <= ``max_dense`` this is the exact vectorized Floyd-Warshall
    (O(N^3) — fine to a few hundred workers). Beyond the threshold it
    switches to ``_bounded_hop_estimate``: ``hops`` rounds of min-plus
    relaxation along the measured edge list, O(hops * E * N) total. Paths
    longer than hops+1 edges stay at their previous estimate (the caller
    falls back to the prior EMA for non-finite entries), which matters
    little in practice: the planner keeps topologies low-diameter, and
    Eq. 39 re-smooths every round.
    """
    d = np.array(edge_dist, dtype=np.float64)
    n = d.shape[0]
    if n <= max_dense:
        for p in range(n):
            # d_ij <- min(d_ij, d_ip + d_pj)
            cand = d[:, p:p + 1] + d[p:p + 1, :]
            np.minimum(d, cand, out=d)
        return d
    return _bounded_hop_estimate(d, hops)


class ConsensusTracker:
    """Coordinator-side consensus-distance state across rounds."""

    def __init__(self, num_workers: int, beta1: float = 0.5,
                 beta2: float = 0.1):
        self.n = num_workers
        self.beta1 = float(beta1)   # Eq. (39) EMA for estimated distances
        self.beta2 = float(beta2)   # Eq. (43) EMA for D_max
        self.dist = np.zeros((num_workers, num_workers))
        self.d_max = 0.0
        self._rounds = 0
        # dynamic membership: rows/cols of absent workers are dropped so the
        # Floyd-Warshall estimate never routes through (or budgets for) a
        # worker that has churned out
        self.present = np.ones(num_workers, bool)

    def sync_membership(self, alive: np.ndarray) -> None:
        """Reconcile tracker state with the round's alive set.

        Departed workers' rows/columns are zeroed (no stale estimates carry
        over, and Eq. 36 stops charging their pairs). Newly joined workers
        start from the mean surviving pair distance — a pessimistic fresh
        prior that keeps the budget check meaningful until their first
        measured edges arrive.
        """
        alive = np.asarray(alive, bool)
        departed = self.present & ~alive
        joined = alive & ~self.present
        if departed.any():
            self.dist[departed, :] = 0.0
            self.dist[:, departed] = 0.0
        if joined.any():
            stay = np.nonzero(alive & self.present)[0]
            if len(stay) > 1:
                sub = self.dist[np.ix_(stay, stay)]
                fill = float(sub.sum() / max(len(stay) * (len(stay) - 1), 1))
            else:
                fill = 0.0
            for w in np.nonzero(joined)[0]:
                self.dist[w, alive] = fill
                self.dist[alive, w] = fill
                self.dist[w, w] = 0.0
        self.present = alive.copy()

    def update(self, adj: np.ndarray, edge_dist: np.ndarray,
               mean_update_norm: float) -> np.ndarray:
        """Ingest round-h measurements; return the smoothed full estimate.

        adj: (N,N) round topology. edge_dist: (N,N) with entries valid only
        where adj==1 (others ignored). mean_update_norm: (1/N) sum ||g_i||.
        """
        masked = measured_distance_matrix(adj, edge_dist)
        est = floyd_warshall_estimate(masked)
        # Disconnected pairs (shouldn't happen: topology is connected) ->
        # fall back to previous value.
        est = np.where(np.isfinite(est), est, self.dist)
        if self._rounds == 0:
            smoothed = est
        else:
            # Eq. (39): EMA only where unmeasured; measured edges are exact.
            smoothed = np.where(
                adj > 0, est,
                (1 - self.beta1) * self.dist + self.beta1 * est)
        np.fill_diagonal(smoothed, 0.0)
        self.dist = smoothed
        # Eq. (43): D_max^h = (1-beta2) D_max^{h-1} + beta2 * mean ||g||
        if self._rounds == 0:
            self.d_max = float(mean_update_norm)
        else:
            self.d_max = ((1 - self.beta2) * self.d_max
                          + self.beta2 * float(mean_update_norm))
        self._rounds += 1
        return self.dist

    def mean_distance(self) -> float:
        """Mean estimated pairwise distance over present off-diagonal
        pairs — the scalar consensus signal the compression feedback path
        (``controller.SparsityScheduler``) tightens k against."""
        mask = np.outer(self.present, self.present)
        np.fill_diagonal(mask, False)
        m = int(mask.sum())
        return float((self.dist * mask).sum() / m) if m else 0.0

    def average_consensus_bound(self, adj: np.ndarray) -> float:
        """Eq. (36): E D^{h+1} <= (1/N^2) sum_ij (1 - a_ij) D_ij, summed and
        normalized over the present worker set only."""
        off = (1 - adj) * self.dist
        np.fill_diagonal(off, 0.0)
        mask = np.outer(self.present, self.present)
        m = max(int(self.present.sum()), 1)
        return float((off * mask).sum() / (m * m))

    def satisfies_budget(self, adj: np.ndarray) -> bool:
        """First constraint of Eq. (42)."""
        return self.average_consensus_bound(adj) <= self.d_max + 1e-12


def consensus_distance_to_mean(stacked_models: np.ndarray) -> np.ndarray:
    """Eq. (8): D_i = ||xbar - x_i|| for (N, P) stacked flat models.

    Only available in simulation / tests (no PS in production, per paper)."""
    mean = stacked_models.mean(axis=0, keepdims=True)
    return np.linalg.norm(stacked_models - mean, axis=1)


def pairwise_distances(stacked_models: np.ndarray) -> np.ndarray:
    """Eq. (7): full pairwise L2 matrix for (N, P) stacked flat models."""
    sq = (stacked_models ** 2).sum(axis=1)
    g = stacked_models @ stacked_models.T
    d2 = np.maximum(sq[:, None] + sq[None, :] - 2 * g, 0.0)
    np.fill_diagonal(d2, 0.0)
    return np.sqrt(d2)
