"""FedHP adaptive control algorithm (Sec. IV-B, Alg. 3).

Jointly determines per-worker local updating frequencies tau_i and the round
topology A^h: greedily remove the slowest links (search step sqrt(|E|),
halved on failure) subject to (a) connectivity and (b) the consensus-distance
budget (Eq. 42), assigning taus that equalize per-worker round time (Eq. 40)
with the pace set by the theory-optimal tau* (Remark 2).

Deviation noted in DESIGN.md: the greedy objective is the true round
completion time max_i t_i (the quantity Eq. 12 minimizes) rather than the
pace-setter's T_l; the two coincide up to the tau>=1 clamp. The paper's "LP"
has one free variable once the pace-setter is fixed, so the closed-form
equalization is exact.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.core import topology as topo
from repro.core.compression import Codec
from repro.core.consensus import ConsensusTracker


@dataclass
class ControlDecision:
    """One coordinator decision (Alg. 3 output): the round topology A^h,
    per-worker taus (Eq. 40 equalization around the pace-setter's
    theory-optimal tau*, Remark 2), the predicted round/waiting times
    (Eq. 10-11), the Eq. 36 consensus bound the topology was accepted
    under, and the wire ratio the Eq. 10 comm term was scaled by (1.0
    for a compression-blind solve)."""

    adj: np.ndarray
    taus: np.ndarray                  # (N,) int per-worker local frequencies
    round_time: float                 # max_i t_i (predicted)
    waiting_time: float               # Eq. (11) predicted average waiting
    tau_pace: int                     # tau of the pace-setting worker
    pace_worker: int
    consensus_bound: float            # Eq. (36) value for this topology
    wire_ratio: float = 1.0           # comm divisor the solve used
    matchings: list = field(default_factory=list)

    @property
    def num_links(self) -> int:
        """Undirected edge count of the decided topology."""
        return int(self.adj.sum() // 2)


def theory_tau_star(n: int, f1: float, smooth_l: float, rounds: int,
                    eta: float, sigma: float, tau_max: int,
                    comm_floor: int = 1) -> int:
    """Remark 2 / Alg. 3 line 2: tau* = sqrt(N f(xbar^1) / (L H eta^2 sigma^2)).

    Guarded: if any estimate is degenerate (early rounds) fall back to
    tau_max/2. ``comm_floor`` additionally lower-bounds tau so the pace
    setter's compute amortizes its per-round communication time (the L and
    sigma plug-in estimates are noisy — Alg. 1 lines 4-5 — and a tau below
    the floor makes every round communication-dominated, which Eq. 41's
    objective can never favor; implementation choice recorded in
    DESIGN.md §8).
    """
    lo = max(1, min(comm_floor, tau_max))
    denom = smooth_l * rounds * (eta ** 2) * (sigma ** 2)
    if denom <= 0 or f1 <= 0 or not math.isfinite(denom):
        return max(lo, tau_max // 2)
    tau = math.sqrt(n * f1 / denom)
    if not math.isfinite(tau):
        return max(lo, tau_max // 2)
    return int(min(max(tau, lo), tau_max))


def equalized_taus(adj: np.ndarray, mu: np.ndarray, beta: np.ndarray,
                   tau_star: int, tau_max: int,
                   alive: np.ndarray | None = None
                   ) -> tuple[np.ndarray, int]:
    """Eq. (40): assign taus so every worker's t_i matches the pace-setter.

    Pace-setter l = argmin_i (tau* mu_i + max_j beta_ij): the worker that can
    finish a tau*-step round fastest. Everyone else gets
    tau_i = floor((t_l - comm_i) / mu_i) clamped to [1, tau_max].
    Under churn the pace-setter and the equalization run over the surviving
    set only; departed workers get tau 0. Returns (taus, pace_worker).
    """
    n = adj.shape[0]
    alive = np.ones(n, bool) if alive is None else np.asarray(alive, bool)
    comm = link_times(adj, beta)
    t_full = np.where(alive, tau_star * mu + comm, np.inf)
    pace = int(np.argmin(t_full))
    t_pace = float(t_full[pace])
    with np.errstate(divide="ignore", invalid="ignore"):
        taus = np.floor((t_pace - comm) / np.maximum(mu, 1e-12))
    taus = np.clip(taus, 1, tau_max).astype(np.int64)
    taus[pace] = tau_star
    taus[~alive] = 0
    return taus, pace


def link_times(adj: np.ndarray, beta: np.ndarray) -> np.ndarray:
    """Per-worker communication time: max_{j in N_i} beta_ij (Eq. 10)."""
    masked = np.where(adj > 0, beta, 0.0)
    return masked.max(axis=1)


def evaluate_topology(adj: np.ndarray, mu: np.ndarray, beta: np.ndarray,
                      tau_star: int, tau_max: int,
                      alive: np.ndarray | None = None) -> ControlDecision:
    """Score one candidate topology: equalize taus (Eq. 40), then predict
    its round time max_i t_i and average waiting time (Eq. 10-11) — the
    objective Alg. 3's greedy link removal minimizes."""
    n = adj.shape[0]
    alive = np.ones(n, bool) if alive is None else np.asarray(alive, bool)
    taus, pace = equalized_taus(adj, mu, beta, tau_star, tau_max, alive)
    comm = link_times(adj, beta)
    t = np.where(alive, taus * mu + comm, 0.0)
    round_time = float(t[alive].max()) if alive.any() else 0.0
    waiting = float((round_time - t[alive]).mean()) if alive.any() else 0.0
    return ControlDecision(
        adj=adj, taus=taus, round_time=round_time, waiting_time=waiting,
        tau_pace=int(taus[pace]), pace_worker=pace, consensus_bound=0.0)


class AdaptiveController:
    """Coordinator-side Alg. 3 driver, stateful across rounds."""

    def __init__(self, base_adj: np.ndarray, tau_max: int = 50,
                 epsilon: float = float("inf")):
        topo.validate_topology(base_adj)
        if not topo.is_connected(base_adj):
            raise ValueError("base topology must be connected")
        self.base_adj = np.asarray(base_adj, dtype=np.int8)
        self.n = base_adj.shape[0]
        self.tau_max = int(tau_max)
        self.epsilon = float(epsilon)

    # -- Alg. 3 -------------------------------------------------------------
    def decide(self, mu: np.ndarray, beta: np.ndarray,
               tracker: ConsensusTracker, *, f1: float, smooth_l: float,
               sigma: float, eta: float, rounds: int,
               alive: np.ndarray | None = None,
               wire_ratio: float = 1.0) -> ControlDecision:
        """One coordinator decision (Alg. 3).

        mu: (N,) per-iteration computing times. beta: (N,N) link times.
        alive: optional bool mask; dead workers' links are stripped first
        (fault tolerance: vertex removal + topology repair).
        wire_ratio: the active codec's uncompressed/compressed wire-bits
        ratio — every Eq. 10 comm term in the solve (the comm floor under
        tau*, the Eq. 40 equalization and the greedy link-removal
        objective) uses the effective link times beta / wire_ratio, so
        the planned (tau, topology) trades the wire the engines actually
        pay: a cheaper wire lowers the comm floor (tau* stops being
        forced up to amortize links) and makes slow links cheaper to keep
        under the Eq. 42 consensus budget.
        """
        mu = np.asarray(mu, dtype=np.float64)
        beta = np.asarray(beta, dtype=np.float64)
        if wire_ratio != 1.0:
            beta = beta / max(float(wire_ratio), 1e-12)
        adj = np.array(self.base_adj, copy=True)
        mask = np.ones(self.n, bool) if alive is None \
            else np.asarray(alive, dtype=bool)
        if not mask.all():
            adj = prune_dead(adj, mask, cost=beta)
        live = np.nonzero(mask)[0]

        def live_connected(a: np.ndarray) -> bool:
            return topo.is_connected(a[np.ix_(live, live)])

        # comm floor: the pace setter should compute at least as long as it
        # communicates, else rounds are wire-bound regardless of topology
        link = beta[adj > 0]
        mu_live = mu[mask] if mask.any() else mu
        comm_floor = int(math.ceil(
            float(np.median(link)) / max(float(mu_live.min()), 1e-9))) \
            if link.size else 1
        tau_star = theory_tau_star(max(len(live), 1), f1, smooth_l, rounds,
                                   eta, sigma, self.tau_max,
                                   comm_floor=comm_floor)
        best = evaluate_topology(adj, mu, beta, tau_star, self.tau_max, mask)
        best.consensus_bound = tracker.average_consensus_bound(adj)

        s = self.n
        flag = True
        while True:
            num_links = int(best.adj.sum() // 2)
            if flag:
                s = max(1, int(math.isqrt(max(num_links, 1))))
            # select the s slowest links removable under Eq. (42)
            cand = self._removal_candidates(best.adj, beta, tracker, s)
            improved = False
            if cand:
                trial = np.array(best.adj, copy=True)
                for (i, j) in cand:
                    trial[i, j] = trial[j, i] = 0
                    if not live_connected(trial):
                        trial[i, j] = trial[j, i] = 1
                        continue
                    if not tracker.satisfies_budget(trial):
                        trial[i, j] = trial[j, i] = 1
                        continue
                d = evaluate_topology(trial, mu, beta, tau_star,
                                      self.tau_max, mask)
                if d.round_time < best.round_time and \
                        d.waiting_time <= self.epsilon:
                    d.consensus_bound = tracker.average_consensus_bound(d.adj)
                    best = d
                    improved = True
            if improved:
                flag = True
            else:
                if s == 1:
                    break
                s = max(1, s // 2)
                flag = False

        best.matchings = topo.matching_decomposition(best.adj)
        best.wire_ratio = float(wire_ratio)
        return best

    def _removal_candidates(self, adj: np.ndarray, beta: np.ndarray,
                            tracker: ConsensusTracker,
                            s: int) -> list[tuple[int, int]]:
        """Alg. 3 line 9: s slowest links whose individual removal keeps the
        consensus-distance budget (the joint check happens during removal).

        Fully vectorized over the edge list: removing one edge (i, j) adds
        exactly dist[i, j] + dist[j, i] (present-masked) to the Eq. 36 sum,
        so every candidate's budget check is the base bound plus that delta —
        no per-candidate O(n^2) trial matrices (was the dominant planner cost
        at large W)."""
        iu, ju = np.nonzero(np.triu(adj, k=1))
        if iu.size == 0:
            return []
        order = np.argsort(-beta[iu, ju], kind="stable")  # ties: row-major
        iu, ju = iu[order], ju[order]
        mask = np.outer(tracker.present, tracker.present)
        m = max(int(tracker.present.sum()), 1)
        base = tracker.average_consensus_bound(adj)
        delta = (tracker.dist[iu, ju] * mask[iu, ju]
                 + tracker.dist[ju, iu] * mask[ju, iu]) / (m * m)
        ok = np.nonzero(base + delta <= tracker.d_max + 1e-12)[0][:s]
        return [(int(iu[t]), int(ju[t])) for t in ok]


class SparsityScheduler:
    """The replan-cadence compression feedback path (beyond-paper,
    ChocoSGD x DySTop-flavored): as the fleet's consensus distance
    shrinks, each gossip payload carries less information per coordinate,
    so the sparse codec's keep count k is tightened — halved whenever the
    tracked consensus distance has halved since the last tightening,
    never below ``floor_frac`` of the initial spec. Tightening on a
    halving ladder (instead of scaling k continuously) bounds the jit
    specializations a changing k costs the engines at
    ~log2(1/floor_frac), and the factor-2 hysteresis keeps the decision
    robust to the ~1e-5 cross-engine float drift in the measured
    distances — both engines must replay identical codec sequences for
    the differential harness to hold.

    Driven by ``algorithms.FedHPStrategy`` at ``cfg.replan_every``
    cadence (``cfg.tighten_k``); the tightened codec rides to the engines
    in ``RoundPlan.codec``.
    """

    def __init__(self, codec: Codec, floor_frac: float = 0.125):
        if not codec.is_sparse:
            raise ValueError(f"k-tightening needs a sparse codec, "
                             f"got {codec.mode!r}")
        self.codec = codec
        self.floor_frac = float(floor_frac)
        self._k0 = codec.k
        self._d_ref: float | None = None

    def step(self, d_now: float) -> Codec:
        """Feed the current tracked consensus distance; returns the codec
        to plan and gossip with (possibly one halving tighter)."""
        if not (math.isfinite(d_now) and d_now > 0.0):
            return self.codec
        if self._d_ref is None:
            self._d_ref = float(d_now)
            return self.codec
        k_floor = self._k0 * self.floor_frac
        if self._k0 >= 1.0:
            # an absolute keep count must stay absolute: halving across
            # 1.0 would silently reinterpret k as a fraction of P and
            # EXPAND the payload instead of tightening it
            k_floor = max(k_floor, 1.0)
        if d_now < 0.5 * self._d_ref and self.codec.k > k_floor:
            self.codec = self.codec.with_k(max(self.codec.k / 2.0, k_floor))
            self._d_ref = float(d_now)
        return self.codec


def prune_dead(adj: np.ndarray, alive: np.ndarray,
               cost: np.ndarray | None = None) -> np.ndarray:
    """Vertex removal for churned-out workers + cheapest-reconnect repair:
    if the prune disconnects the survivors, the minimum-cost (link-time)
    cross-component edges are added back until the alive subgraph is one
    component (``topology.repair_connectivity``)."""
    return topo.repair_connectivity(adj, np.asarray(alive, bool), cost)
