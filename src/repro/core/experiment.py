"""Experiment harness wiring data + cluster + strategy — shared by tests,
benchmarks (one per paper figure), and examples."""
from __future__ import annotations

from dataclasses import replace

import numpy as np

from repro.configs.base import FedHPConfig
from repro.core import engine
from repro.core import modelspec
from repro.core.algorithms import make_strategy
from repro.core.topology import make_base_topology
from repro.data.partition import DriftingPartition, pskew_partition
from repro.simulation.cluster import ChurnSchedule, SimCluster


def model_bits_for(cfg: FedHPConfig, *, dim: int = 32,
                   num_classes: int = 10) -> float:
    """Uncompressed wire payload (bits) of one model transfer for the
    model ``cfg.model`` names: 32 bits x the adapter's TRUE parameter
    count (the historical hard-coded 7.3e3*32 synthetic constant is
    gone — Eq. 10 comm charging now follows the actual model)."""
    return modelspec.get_adapter(getattr(cfg, "model", "mlp"), dim=dim,
                                 num_classes=num_classes).model_bits


def churn_from_config(cfg: FedHPConfig,
                      rounds: int | None = None) -> ChurnSchedule | None:
    """Generate the seeded churn schedule cfg describes (None if disabled)."""
    if cfg.churn_rate <= 0.0:
        return None
    return ChurnSchedule.generate(
        cfg.num_workers, rounds or cfg.rounds, rate=cfg.churn_rate,
        seed=cfg.churn_seed, min_alive=cfg.churn_min_alive,
        straggle_factor=cfg.straggle_factor,
        straggle_duration=cfg.straggle_duration)


def setup_experiment(cfg: FedHPConfig, *, non_iid_p: float = 0.1,
                     num_samples: int = 6000, dim: int = 32,
                     num_classes: int = 10, spread: float = 1.0,
                     fail_at: dict | None = None,
                     churn: ChurnSchedule | None = None,
                     rounds: int | None = None):
    """Build (data, test split, shards, cluster) for one experiment.

    ``cfg.model`` picks the model family (core/modelspec.py), which in
    turn picks the dataset: Gaussian-blob classification rows for the
    MLP, the class-labeled Markov token corpus for registry LMs — both
    carry per-sample labels, so the p-skew / drifting partitions work
    unchanged. ``SimCluster.model_bits`` comes from the adapter's true
    parameter count (32 bits per param)."""
    adapter = modelspec.get_adapter(getattr(cfg, "model", "mlp"), dim=dim,
                                    num_classes=num_classes)
    data = adapter.make_data(num_samples, seed=cfg.seed, spread=spread)
    n_test = max(num_samples // 6, 256)
    test_x, test_y = data.x[:n_test], data.y[:n_test]
    train = replace_dataset(data, data.x[n_test:], data.y[n_test:])
    if cfg.drift_every > 0:
        # time-varying non-IID: the class -> group pinning rotates every
        # drift_every rounds; shift 0 reproduces the static partition
        # below exactly (same seed stream)
        shards = DriftingPartition(train.y, cfg.num_workers, non_iid_p,
                                   cfg.seed + 1, cfg.drift_every)
    else:
        rng = np.random.default_rng(cfg.seed + 1)
        shards = pskew_partition(train.y, cfg.num_workers, non_iid_p, rng)
    if churn is None:
        churn = churn_from_config(cfg, rounds)
    cluster = SimCluster(cfg.num_workers, model_bits=adapter.model_bits,
                         seed=cfg.seed, fail_at=fail_at or {}, churn=churn)
    return train, test_x, test_y, shards, cluster


def replace_dataset(data, x, y):
    from repro.data.synthetic import Dataset
    return Dataset(x, y, data.num_classes)


def run_algorithm(algorithm: str, cfg: FedHPConfig, *, non_iid_p: float = 0.1,
                  rounds: int | None = None, mixing: str = "uniform",
                  fail_at: dict | None = None, spread: float = 1.0,
                  churn: ChurnSchedule | None = None,
                  time_budget: float | None = None,
                  fused: bool = False, seeds=None,
                  num_samples: int = 6000, mesh=None):
    """Run one (algorithm, non-IID level) cell and return its History.

    ``fused=True`` routes the run through the scan-based engines
    (``core.fused.run_dfl_fused`` for the synchronous strategies,
    ``core.fused.run_adpsgd_fused`` for the event-driven AD-PSGD) —
    equivalent trajectories, far fewer host round trips; ``seeds``
    (fused only) batches S experiments through one vmapped scan and
    returns ``list[History]``. ``num_samples`` sizes the synthetic
    dataset — raise it for large-W runs so every worker shard stays
    non-empty.

    ``mesh`` (or ``cfg.sharded``) runs the synchronous engines on the
    sharded path (``runtime/shardexec``): the [W, P] worker matrix
    splits over the mesh's worker axis, cross-shard gossip rides
    ppermute-routed edge tables. Not available for AD-PSGD.
    """
    if seeds is not None and not fused:
        raise ValueError("seeds batching requires fused=True")
    cfg = replace(cfg, algorithm=algorithm)
    if mesh is not None:
        cfg = replace(cfg, sharded=True)
    train, tx, ty, shards, cluster = setup_experiment(
        cfg, non_iid_p=non_iid_p, fail_at=fail_at, spread=spread,
        churn=churn, rounds=rounds, num_samples=num_samples)
    if algorithm == "adpsgd":
        if mesh is not None or cfg.sharded:
            raise ValueError("the sharded path covers the synchronous "
                             "engines only (AD-PSGD's event loop scatters "
                             "single rows — shard-hostile by design)")
        if fused:
            from repro.core.fused import run_adpsgd_fused
            return run_adpsgd_fused(train, tx, ty, shards, cluster, cfg,
                                    rounds=rounds, time_budget=time_budget,
                                    seeds=seeds)
        return engine.run_adpsgd(train, tx, ty, shards, cluster, cfg,
                                 rounds=rounds, time_budget=time_budget)
    base = make_base_topology(cfg.num_workers, cfg.base_topology, cfg.seed)
    strategy = make_strategy(cfg, base)
    if fused:
        from repro.core.fused import run_dfl_fused
        return run_dfl_fused(train, tx, ty, shards, cluster, cfg, strategy,
                             rounds=rounds, mixing=mixing,
                             time_budget=time_budget, seeds=seeds,
                             mesh=mesh)
    return engine.run_dfl(train, tx, ty, shards, cluster, cfg, strategy,
                          rounds=rounds, mixing=mixing,
                          time_budget=time_budget, mesh=mesh)
