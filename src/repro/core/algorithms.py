"""DFL algorithm strategies: FedHP (ours, Alg. 1-3) and the paper's four
baselines — D-PSGD, LD-SGD, PENS (synchronous; AD-PSGD is event-driven and
lives in ``engine.run_adpsgd``).

A strategy decides, per round, the topology A^h and per-worker local
updating frequencies tau_i^h, using only the measurements reported at the
end of round h-1 (the coordinator's information set, Alg. 2).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import FedHPConfig
from repro.core import compression, topology as topo
from repro.core.compression import Codec
from repro.core.consensus import ConsensusTracker
from repro.core.controller import AdaptiveController, SparsityScheduler


@dataclass
class RoundPlan:
    """One round's coordinator output: topology, per-worker taus, any
    per-worker overhead, and (adaptive compression only) the wire codec
    the round must gossip and be billed under — ``None`` means the
    engine uses ``cfg.compress`` unchanged. The codec may only refine
    the configured codec's k (same kind); both engines read it through
    the same plan replay, which keeps their wire charges bit-identical."""

    adj: np.ndarray
    taus: np.ndarray
    extra_time: np.ndarray | None = None    # per-worker overhead (e.g. PENS)
    codec: Codec | None = None              # tightened wire codec (FedHP)


class Strategy:
    """Base: fixed base topology, fixed tau (what D-PSGD does on a ring)."""

    name = "base"
    # adaptive strategies plan from the previous round's measurements, so
    # the fused engine must surface observations between scan segments;
    # static (observation-free) strategies fuse the whole horizon
    adaptive = False

    def __init__(self, cfg: FedHPConfig, base_adj: np.ndarray):
        self.cfg = cfg
        self.base_adj = np.asarray(base_adj, dtype=np.int8)
        self.n = base_adj.shape[0]
        self.alive = np.ones(self.n, bool)

    def _membership(self, alive: np.ndarray | None) -> np.ndarray:
        """Record the round's alive set (churn is applied at round start,
        before planning) and return it as a bool mask."""
        if alive is not None:
            self.alive = np.asarray(alive, bool)
        return self.alive

    def _restrict(self, adj: np.ndarray) -> np.ndarray:
        """Drop departed workers' links; cheapest-reconnect the survivors
        if the departure disconnected the round topology."""
        if self.alive.all():
            return adj
        return topo.repair_connectivity(adj, self.alive)

    def plan(self, h: int, alive: np.ndarray | None = None) -> RoundPlan:
        """Fixed plan: the base topology (churn-restricted) at tau_init."""
        self._membership(alive)
        taus = np.full(self.n, self.cfg.tau_init, np.int64)
        taus[~self.alive] = 0
        return RoundPlan(self._restrict(self.base_adj.copy()), taus)

    def observe(self, h: int, *, adj, mu, beta, edge_dist, update_norms,
                smooth_l, sigma, loss, cross_loss=None, alive=None,
                wire_ratio: float = 1.0) -> None:
        """Ingest the round's measurements. ``wire_ratio`` is the
        uncompressed/compressed wire-bits ratio the engine actually
        charged this round (1.0 uncompressed) — the feedback the
        compression-aware planner learns the effective link times from."""
        if alive is not None:
            self.alive = np.asarray(alive, bool)


class DPSGDStrategy(Strategy):
    """D-PSGD [12]: synchronous, ring topology, identical tau."""

    name = "dpsgd"

    def __init__(self, cfg: FedHPConfig, base_adj: np.ndarray):
        super().__init__(cfg, base_adj)
        self.ring = topo.ring_topology(self.n)

    def plan(self, h: int, alive: np.ndarray | None = None) -> RoundPlan:
        """Fixed ring at tau_init every round (churn-restricted)."""
        self._membership(alive)
        taus = np.full(self.n, self.cfg.tau_init, np.int64)
        taus[~self.alive] = 0
        return RoundPlan(self._restrict(self.ring.copy()), taus)


class LDSGDStrategy(Strategy):
    """LD-SGD [21]: alternates I1 communication-free local rounds with I2
    gossip rounds (communication-efficient decentralized SGD)."""

    name = "ldsgd"

    def plan(self, h: int, alive: np.ndarray | None = None) -> RoundPlan:
        """I1 communication-free local rounds, then I2 ring-gossip rounds."""
        self._membership(alive)
        i1, i2 = self.cfg.ldsgd_i1, self.cfg.ldsgd_i2
        period = max(i1 + i2, 1)
        taus = np.full(self.n, self.cfg.tau_init, np.int64)
        taus[~self.alive] = 0
        if (h % period) < i1:                        # local-only round
            return RoundPlan(np.zeros_like(self.base_adj), taus)
        return RoundPlan(self._restrict(topo.ring_topology(self.n)), taus)


class PENSStrategy(Strategy):
    """PENS [22]: performance-based neighbor selection. Each round a worker
    samples `pens_sample` random peers, evaluates their models on its local
    data, and gossips with the `pens_top_m` lowest-loss (most similar
    distribution) peers. Selection costs extra compute+comm time — the
    overhead the paper measures in Fig. 7."""

    name = "pens"
    adaptive = True

    def __init__(self, cfg: FedHPConfig, base_adj: np.ndarray):
        super().__init__(cfg, base_adj)
        self.rng = np.random.default_rng(cfg.seed + 17)
        self._cross = None                      # [N,N] loss of model j on data i
        self._mu = np.full(self.n, 0.1)
        self._beta = np.full((self.n, self.n), 1.0)

    def plan(self, h: int, alive: np.ndarray | None = None) -> RoundPlan:
        """Sample pens_sample peers, keep the pens_top_m lowest-loss ones
        (round 0: random), charging the selection overhead as extra_time."""
        live = self._membership(alive)
        taus = np.full(self.n, self.cfg.tau_init, np.int64)
        taus[~live] = 0
        m, s = self.cfg.pens_top_m, self.cfg.pens_sample
        adj = np.zeros((self.n, self.n), np.int8)
        samples = np.zeros(self.n)
        pool = np.nonzero(live)[0]
        for i in pool:
            if len(pool) < 2:       # lone survivor: nothing to sample
                break
            cand = self.rng.choice([j for j in pool if j != i],
                                   size=min(s, len(pool) - 1), replace=False)
            samples[i] = len(cand)
            if self._cross is None:             # round 0: random top_m
                pick = cand[:m]
            else:
                pick = cand[np.argsort(self._cross[i, cand])[:m]]
            adj[i, pick] = 1
        adj = np.maximum(adj, adj.T)            # symmetrize
        np.fill_diagonal(adj, 0)
        adj = self._restrict(adj)               # keep gossip well-defined
        sub = adj[np.ix_(pool, pool)]
        if len(pool) > 1 and not topo.is_connected(sub):
            adj = np.maximum(adj, topo.repair_connectivity(
                topo.ring_topology(self.n), live))
        # selection overhead: receive + evaluate `s` candidate models
        extra = samples * (self._mu * 2.0) + \
            samples * np.median(self._beta[self._beta > 0]) \
            if (self._beta > 0).any() else samples * self._mu * 2.0
        return RoundPlan(adj, taus, extra_time=extra)

    def observe(self, h, *, adj, mu, beta, edge_dist, update_norms,
                smooth_l, sigma, loss, cross_loss=None, alive=None,
                wire_ratio: float = 1.0):
        """PENS feedback: the cross-loss matrix for neighbor selection
        plus the mu/beta estimates its selection overhead is priced by."""
        super().observe(h, adj=adj, mu=mu, beta=beta, edge_dist=edge_dist,
                        update_norms=update_norms, smooth_l=smooth_l,
                        sigma=sigma, loss=loss, alive=alive,
                        wire_ratio=wire_ratio)
        if cross_loss is not None:
            self._cross = cross_loss
        self._mu, self._beta = mu, beta


class FedHPStrategy(Strategy):
    """The paper's adaptive control (Alg. 1-3): joint tau + topology."""

    name = "fedhp"
    adaptive = True

    def __init__(self, cfg: FedHPConfig, base_adj: np.ndarray):
        super().__init__(cfg, base_adj)
        self.controller = AdaptiveController(base_adj, tau_max=cfg.tau_max,
                                             epsilon=cfg.epsilon)
        self.tracker = ConsensusTracker(self.n, beta1=cfg.beta1,
                                        beta2=cfg.beta2)
        self._mu = None
        self._beta = None
        self._f1 = None                         # f(xbar^1), fixed at round 1
        self._L = 1.0
        self._sigma = 1.0
        self.last_decision = None
        # compression awareness: the codec the run gossips under, the
        # replan-cadence k-tightening scheduler (sparse codecs only), and
        # the wire ratio learned from the engine's observe() feedback —
        # the Eq. 10 comm divisor the next decide() solves against
        codec = compression.parse_mode(cfg.compress)
        self.codec = codec if codec.kind != "none" else None
        self.k_scheduler = (SparsityScheduler(codec, cfg.sparse_k_floor)
                            if codec.is_sparse and cfg.tighten_k else None)
        self._wire_ratio = 1.0

    def _plan_codec(self, h: int) -> Codec | None:
        """The codec round h gossips and is billed under: the configured
        one, tightened at ``replan_every`` cadence when the feedback path
        is on (both engines replay plan() at those rounds, so the codec
        sequence — and with it the wire charge — stays bit-identical)."""
        if self.k_scheduler is None:
            return self.codec
        if h % max(self.cfg.replan_every, 1) == 0:
            return self.k_scheduler.step(self.tracker.mean_distance())
        return self.k_scheduler.codec

    def plan(self, h: int, alive: np.ndarray | None = None) -> RoundPlan:
        """One Alg. 3 decision (joint tau + topology) against the learned
        wire ratio, carrying the (possibly tightened) codec in the plan."""
        live = self._membership(alive)
        # membership can change between observe() and plan() (churn is
        # applied at round start): reconcile the tracker before deciding
        self.tracker.sync_membership(live)
        codec = self._plan_codec(h)
        if self._mu is None:                    # round 0: no measurements yet
            taus = np.full(self.n, self.cfg.tau_init, np.int64)
            taus[~live] = 0
            return RoundPlan(self._restrict(self.base_adj.copy()), taus,
                             codec=codec)
        wire = self._wire_ratio if self.cfg.planner_wire_aware else 1.0
        d = self.controller.decide(
            self._mu, self._beta, self.tracker, f1=self._f1,
            smooth_l=self._L, sigma=self._sigma, eta=self.cfg.lr,
            rounds=self.cfg.rounds, alive=live, wire_ratio=wire)
        self.last_decision = d
        return RoundPlan(d.adj, d.taus, codec=codec)

    def observe(self, h, *, adj, mu, beta, edge_dist, update_norms,
                smooth_l, sigma, loss, cross_loss=None, alive=None,
                wire_ratio: float = 1.0):
        """Alg. 1 feedback plus the engine's actual wire ratio — the
        planner learns the comm divisor it solves the next round with
        rather than assuming one (one-round lag, identical in both
        engines)."""
        super().observe(h, adj=adj, mu=mu, beta=beta, edge_dist=edge_dist,
                        update_norms=update_norms, smooth_l=smooth_l,
                        sigma=sigma, loss=loss, alive=alive,
                        wire_ratio=wire_ratio)
        self._mu, self._beta = np.asarray(mu), np.asarray(beta)
        self._wire_ratio = float(wire_ratio)
        if self._f1 is None:
            self._f1 = float(loss)
        self._L = max(float(smooth_l), 1e-6)
        self._sigma = max(float(sigma), 1e-6)
        self.tracker.update(adj, edge_dist, float(np.mean(update_norms)))


STRATEGIES = {
    "base": Strategy,
    "fedhp": FedHPStrategy,
    "dpsgd": DPSGDStrategy,
    "ldsgd": LDSGDStrategy,
    "pens": PENSStrategy,
}


def make_strategy(cfg: FedHPConfig, base_adj: np.ndarray) -> Strategy:
    """Instantiate the strategy ``cfg.algorithm`` names over ``base_adj``."""
    if cfg.algorithm == "adpsgd":
        raise ValueError("AD-PSGD is asynchronous; use engine.run_adpsgd")
    return STRATEGIES[cfg.algorithm](cfg, base_adj)
