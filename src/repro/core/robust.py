"""Byzantine attack models + robust gossip aggregation.

Threat model (lie-on-wire): workers in ``cfg.byzantine`` train their
LOCAL row honestly but transmit a corrupted copy every gossip exchange —
sign-flipped (``"signflip[:scale]"`` sends ``-scale * x``) or norm-blown
(``"largenorm[:scale]"`` sends ``scale * x``). Honest workers cannot
tell attackers from peers, so the countermeasure is aggregation-side:
instead of the weighted Eq. 5 mix, each worker robust-averages the
multiset ``{x_i} ∪ {T_j : j ∈ N(i)}`` of its own row plus the
transmitted neighbor rows, coordinate-wise:

- ``"trimmed:<b>"`` — drop the ``b`` largest and ``b`` smallest values
  per coordinate, then average the rest (``b`` a fraction of the closed
  neighborhood when < 1, an absolute count otherwise; always clamped to
  ``(cnt - 1) // 2`` so at least one value survives). Tolerates up to
  ``b`` attackers per neighborhood.
- ``"median"`` — the coordinate-wise median (maximal breakdown point,
  slowest consensus).

Two device forms mirror the two gossip representations:

- dense: gather the neighbor rows into a ``[W, D_max + 1, P]`` block
  via a host-built padded index table, mask + sort, and window / index
  into the sorted values (``robust_gossip_dense``);
- sparse (trimmed mean only): genuine segment ops over the directed
  edge list — ``segment_sum`` totals, then ``b`` peeling steps that each
  locate the per-(segment, coordinate) extreme with ``segment_max`` /
  ``segment_min`` and exclude exactly one attaining edge (ties broken by
  lowest edge index via a ``segment_min`` over masked edge ids), so
  ``y = (sum - peeled extremes) / (cnt - 2b)`` without ever gathering a
  dense neighbor block (``trimmed_mean_edges``). The coordinate-wise
  median has no peeling form, so sparse median runs route through the
  gathered dense form built from the edge list.

Both forms compute the same real-valued statistic; float summation
order differs, so cross-form trajectories agree to ~1e-5 like the
dense-vs-sparse plain gossip pair. Robust modes ignore mixing weights
(a weighted trimmed mean would let one high-degree attacker outvote the
window) and do not compose with compressed gossip — every engine
rejects ``robust != "none"`` + ``cfg.compress != "none"`` loudly,
because Eq. 10 would charge the compressed wire while robust
aggregation ships raw rows.

AD-PSGD's pairwise exchange has no neighborhood to trim over (a
2-sample window has no interior), so the async engines get
``"screen:<z>"`` instead: per-event accept/reject screening of the
incoming peer payload against the receiving worker's own recent update
history (DySTop-style). Each worker keeps an EMA of the norms of its
OWN local-SGD deltas (never wire data, so attackers cannot poison it);
an incoming payload ``t`` is accepted iff ``||t - x_self|| <= z * h``
once the history is seeded, with a cosine sanity check
(``cos(t, x_self) >= 0``) covering the one-event warmup window before
the first own-delta lands. On rejection the endpoint keeps its
self-model and the exchange is skipped; event order, staleness
accounting, and the Eq. 10 clock are untouched (screening is
data-plane only), so the fused/reference schedules stay identical.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def parse_attack(spec: str) -> tuple[str, float]:
    """``"signflip[:scale]"`` / ``"largenorm[:scale]"`` -> (kind, scale).

    Default scales: signflip 1.0 (classic sign inversion), largenorm
    10.0 (a blown-up copy of the honest row)."""
    head, _, tail = spec.partition(":")
    if head == "signflip":
        return "signflip", float(tail) if tail else 1.0
    if head == "largenorm":
        return "largenorm", float(tail) if tail else 10.0
    raise ValueError(f"unknown byzantine attack {spec!r}")


def parse_robust(spec: str) -> tuple[str, float]:
    """``"none"`` | ``"trimmed:<b>"`` | ``"median"`` | ``"screen:<z>"``
    -> (mode, b).

    ``b`` is the trim count for ``trimmed`` — a fraction of each closed
    neighborhood when < 1, an absolute count otherwise (0 for
    none/median) — and the z-threshold for ``screen`` (AD-PSGD
    accept/reject screening; must be > 0)."""
    if spec == "none":
        return "none", 0.0
    if spec == "median":
        return "median", 0.0
    if spec.startswith("trimmed:"):
        b = float(spec.split(":", 1)[1])
        if b < 0:
            raise ValueError(f"trim count must be >= 0, got {b}")
        return "trimmed", b
    if spec.startswith("screen:"):
        z = float(spec.split(":", 1)[1])
        if z <= 0:
            raise ValueError(f"screen threshold must be > 0, got {z}")
        return "screen", z
    raise ValueError(f"unknown robust mode {spec!r}")


def byzantine_mask(byzantine: tuple[int, ...], n: int) -> np.ndarray:
    """``cfg.byzantine`` -> boolean [N] mask (validated against N)."""
    m = np.zeros(n, bool)
    for w in byzantine:
        if not 0 <= w < n:
            raise ValueError(f"byzantine worker {w} outside fleet of {n}")
        m[w] = True
    return m


@partial(jax.jit, static_argnames=("kind",))
def apply_attack(flat, byz, scale, *, kind: str):
    """Transmitted copy of the [W, P] matrix: byzantine rows are
    replaced by the attack's corruption, honest rows pass through."""
    if kind == "signflip":
        bad = -scale * flat
    elif kind == "largenorm":
        bad = scale * flat
    else:
        raise ValueError(f"unknown byzantine attack kind {kind!r}")
    return jnp.where(byz[:, None], bad, flat)


# ---------------------------------------------------------------------------
# Plain (non-robust) mixing of a corrupted wire
# ---------------------------------------------------------------------------

@jax.jit
def gossip_byz_dense(flat, transmitted, mix):
    """Eq. 5 when the wire lies: ``y_i = W_ii x_i + sum_j W_ij T_j`` —
    each worker mixes the TRANSMITTED neighbor rows with its own honest
    row (the baseline the robust modes are measured against)."""
    mixed = jnp.tensordot(mix, transmitted, axes=1)
    d = jnp.diagonal(mix)[:, None]
    return mixed + d * (flat - transmitted)


@jax.jit
def gossip_byz_edges(flat, transmitted, src, dst, w):
    """Sparse twin of ``gossip_byz_dense``: the ``segment_sum`` identity
    with the transmitted copy on the source side —
    ``y[dst] += w_e (T[src] - x[dst])``."""
    delta = w.astype(jnp.float32)[:, None] * (transmitted[src] - flat[dst])
    return flat + jax.ops.segment_sum(delta, dst,
                                      num_segments=flat.shape[0])


# ---------------------------------------------------------------------------
# Robust aggregation — dense (gather + sort) form
# ---------------------------------------------------------------------------

def neighbor_table(adj: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Host-side padded neighbor index table of a dense adjacency:
    ``(nbr [W, D_max] int32, deg [W] int32)`` — row i lists N(i) then
    pads with 0 (padding is masked on device via ``deg``). D_max is at
    least 1 so the device block never has a zero axis."""
    n = adj.shape[0]
    deg = np.asarray(adj).sum(axis=1).astype(np.int32)
    d_max = max(int(deg.max()) if n else 0, 1)
    nbr = np.zeros((n, d_max), np.int32)
    for i in range(n):
        js = np.nonzero(adj[i])[0]
        nbr[i, :js.size] = js
    return nbr, deg


def resolve_trim(b: float, cnt) -> jnp.ndarray:
    """Per-worker trim count from the spec's ``b`` and the closed
    neighborhood sizes ``cnt``: fractional b scales with cnt, and the
    result is clamped to ``(cnt - 1) // 2`` so the trimmed window is
    never empty."""
    cnt = jnp.asarray(cnt, jnp.int32)
    if b < 1.0:
        bi = jnp.floor(b * cnt.astype(jnp.float32)).astype(jnp.int32)
    else:
        bi = jnp.full_like(cnt, jnp.int32(int(b)))
    return jnp.minimum(bi, (cnt - 1) // 2)


@partial(jax.jit, static_argnames=("mode", "b"))
def robust_gossip_dense(flat, transmitted, nbr, deg, *, b: float,
                        mode: str):
    """Coordinate-wise robust aggregation over each worker's closed
    neighborhood, gathered dense: worker i's multiset is its own honest
    row plus the transmitted rows of its neighbors. Workers with no
    neighbors keep their row exactly. ``b`` is the spec's trim knob
    (fraction or absolute; ignored for median)."""
    d_pad = nbr.shape[1]
    gathered = transmitted[nbr]                        # [W, D, P]
    mask = jnp.arange(d_pad)[None, :] < deg[:, None]   # [W, D]
    vals = jnp.concatenate(
        [flat[:, None, :],
         jnp.where(mask[:, :, None], gathered, jnp.inf)], axis=1)
    cnt = deg + 1                                      # closed neighborhood
    sv = jnp.sort(vals, axis=1)          # ascending; +inf padding sinks last
    pos = jnp.arange(d_pad + 1)[None, :, None]
    if mode == "trimmed":
        bi = resolve_trim(b, cnt)[:, None, None]
        win = (pos >= bi) & (pos < (cnt[:, None, None] - bi))
        y = jnp.where(win, jnp.where(jnp.isfinite(sv), sv, 0.0), 0.0)
        y = y.sum(axis=1) / (cnt[:, None] - 2 * bi[:, :, 0])
    elif mode == "median":
        lo = ((cnt - 1) // 2)[:, None, None]
        hi = (cnt // 2)[:, None, None]
        vlo = jnp.take_along_axis(sv, lo, axis=1)[:, 0, :]
        vhi = jnp.take_along_axis(sv, hi, axis=1)[:, 0, :]
        y = 0.5 * (vlo + vhi)
    else:
        raise ValueError(f"unknown robust mode {mode!r}")
    return jnp.where((deg > 0)[:, None], y, flat)


# ---------------------------------------------------------------------------
# Robust aggregation — sparse (segment-op) form
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("num_workers", "b_max", "b"))
def trimmed_mean_edges(flat, transmitted, src, dst, *, b: float,
                       num_workers: int, b_max: int):
    """Segment-op trimmed mean over the directed edge list — no dense
    neighbor block. The closed neighborhood becomes an extended edge
    list: the transmitted source rows segmented by destination, plus one
    honest self edge per worker. ``b_max`` static peeling steps each
    remove the current per-(segment, coordinate) max and min —
    ``segment_max``/``segment_min`` locate the extreme, then a
    ``segment_min`` over the edge ids of the attaining edges excludes
    exactly one (tie-safe) — after which the trimmed mean is the masked
    ``segment_sum`` over the survivors divided by ``cnt - 2 b_i``.
    Workers whose clamped per-worker trim ``b_i`` is below the step
    index stop peeling; workers with no incoming edges keep their row.
    ``b_max`` must be >= ``max_i b_i`` (callers pass the fleet-wide
    bound so every worker finishes its trim)."""
    w = num_workers
    p = flat.shape[1]
    vals = jnp.concatenate(
        [transmitted[src].astype(jnp.float32),
         flat.astype(jnp.float32)], axis=0)            # [E + W, P]
    seg = jnp.concatenate([dst, jnp.arange(w, dtype=dst.dtype)])
    m = vals.shape[0]
    deg = jax.ops.segment_sum(jnp.ones(src.shape[0], jnp.float32), dst,
                              num_segments=w)
    cnt = (deg + 1.0).astype(jnp.int32)                # closed neighborhood
    bi = resolve_trim(b, cnt)
    keep = jnp.ones((m, p), bool)
    eid = jnp.arange(m, dtype=jnp.int32)[:, None]
    for step in range(b_max):
        active = (jnp.int32(step) < bi)[seg][:, None]  # [E + W, 1]
        for sense in (1.0, -1.0):
            sv = jnp.where(keep, sense * vals, -jnp.inf)
            ext = jax.ops.segment_max(sv, seg, num_segments=w)
            attain = keep & (sense * vals == ext[seg]) & active
            cand = jnp.where(attain, eid, jnp.int32(m))
            winner = jax.ops.segment_min(cand, seg, num_segments=w)
            keep = keep & ~(attain & (eid == winner[seg]))
    trimmed = jax.ops.segment_sum(jnp.where(keep, vals, 0.0), seg,
                                  num_segments=w)
    y = trimmed / (cnt - 2 * bi).astype(jnp.float32)[:, None]
    return jnp.where((deg > 0)[:, None], y, flat)


# ---------------------------------------------------------------------------
# AD-PSGD accept/reject screening ("screen:<z>")
# ---------------------------------------------------------------------------

# EMA smoothing for each worker's own-delta-norm history. A quarter-step
# EMA tracks the decaying SGD update norms fast enough that z stays a
# small constant, without a single large early step dominating forever.
SCREEN_EMA_ALPHA = 0.25


def attack_row(row, is_byz, scale, *, kind: str):
    """Single-row twin of :func:`apply_attack` for the pairwise AD-PSGD
    exchange: the transmitted copy of one worker's flat row, corrupted
    iff ``is_byz`` (traced bool scalar)."""
    if kind == "signflip":
        bad = -scale * row
    elif kind == "largenorm":
        bad = scale * row
    else:
        raise ValueError(f"unknown byzantine attack kind {kind!r}")
    return jnp.where(is_byz, bad, row)


def screen_fold(h, nd_own):
    """Fold one own-delta norm ``nd_own`` into the scalar EMA history
    ``h``. An unseeded history (``h == 0``) is seeded directly with the
    first observed norm so the z-test activates after one local step."""
    a = jnp.float32(SCREEN_EMA_ALPHA)
    return jnp.where(h > 0, (1 - a) * h + a * nd_own, nd_own)


def screen_accept(x_self, t_peer, h, z: float):
    """Accept/reject verdict for one incoming AD-PSGD payload.

    ``x_self`` is the endpoint's current flat row, ``t_peer`` the flat
    row that arrived on the wire, ``h`` the endpoint's own-delta-norm
    EMA. Seeded history (``h > 0``) applies the z-test
    ``||t_peer - x_self|| <= z * h`` — honest peers sit within a few
    update norms of any worker they gossip with, while sign-flipped or
    norm-blown payloads land ~||x|| away, orders of magnitude above the
    update scale. Before the first own delta seeds ``h`` the cosine
    fallback ``<t_peer, x_self> >= 0`` still catches direction-inverting
    attacks (signflip) at the very first event; a largenorm payload can
    leak through this one-event warmup window, which the z-test then
    closes. Returns a traced bool scalar."""
    nd = jnp.linalg.norm(t_peer - x_self)
    cos_ok = jnp.vdot(t_peer, x_self) >= 0
    return jnp.where(h > 0, nd <= jnp.float32(z) * h, cos_ok)
