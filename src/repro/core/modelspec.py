"""ModelAdapter: the bridge between the DFL engines and any model.

The engines (``core/engine.py``, ``core/fused.py``) operate on two
representations of the fleet's parameters — per-worker pytrees for the
SGD/measurement math and the flat ``[W, P]`` f32 matrix for
gossip/compression — and historically hard-coded the synthetic MLP from
``simulation/model.py`` as the only model. A ``ModelAdapter`` owns
everything model-specific:

  - ``init(key)``: one worker's parameter pytree;
  - ``loss(params, batch)`` with the engines' uniform ``{"x", "y"}``
    batch contract (features/tokens in ``x``, labels in ``y``);
  - ``accuracy(params, x, y)``: the scalar the paper's completion-time
    metric tracks (classification accuracy for the MLP; the bounded
    inverse per-token perplexity ``exp(-loss)`` for LM families);
  - ``flatten_one`` / ``unflatten_one``: the ravel/unravel pair with a
    STATIC leaf layout (``jax.tree`` leaf order, row-major per leaf,
    cast to f32) — identical to the engines' ``_flatten_row`` /
    ``_flatten_workers``, so the Pallas gossip/quantize/sparsify kernels
    keep operating on the same ``[W, P]`` matrix untouched;
  - ``leaf_offsets()``: the (name, start, size, shape, dtype) table of
    that layout — the ground truth ``core/compression.py``'s per-leaf
    codec maps (``compress="leafmap:..."``) compile against;
  - ``param_count`` / ``model_bits``: the true payload size Eq. 10 comm
    charging and ``SimCluster.model_bits`` derive from (no more 7.3k
    synthetic constant);
  - ``make_data(...)``: the synthetic dataset family the model trains on
    (Gaussian blobs for the MLP, the class-structured Markov LM corpus
    for registry families).

Adapters are value objects: ``__eq__``/``__hash__`` key on the canonical
spec string, so they serve as ``jax.jit`` static arguments with cache
hits across runs, engines, and tests.

Spec syntax (``FedHPConfig.model``):

  - ``"mlp"`` / ``"mlp:<hidden>"`` — the synthetic classifier
    (``simulation/model.py``); data dims come from the dataset.
  - ``"<family>:key=val,..."`` — a registry model
    (``models/registry.py``), token families only (dense / moe /
    hybrid / xlstm; encdec and vlm need modality inputs the DFL batch
    pipeline does not carry). Keys: ``d`` (d_model), ``layers``,
    ``heads``, ``kv`` (kv heads), ``ff`` (d_ff), ``vocab``, ``seq``
    (sequence length of the training corpus), ``experts`` /
    ``experts_per_token`` (moe), ``classes`` (document classes in the
    synthetic corpus). Example: ``"dense:d=32,layers=2,heads=2,ff=64,
    vocab=64,seq=16"``. Registry DFL models default to float32 leaves
    (the flat gossip path is f32 exact).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data import synthetic
from repro.simulation import model as _mlp

FP32_BITS = 32

# token-stream families the DFL batch pipeline can feed ({"tokens",
# "labels"} built from an [N, S] int corpus); encdec needs audio frames
# and vlm patch embeddings — neither fits the engines' batch contract
DFL_FAMILIES = ("dense", "moe", "hybrid", "xlstm")

_SPEC_KEYS = {
    "d": "d_model", "d_model": "d_model",
    "layers": "num_layers", "l": "num_layers",
    "heads": "num_heads", "kv": "num_kv_heads",
    "ff": "d_ff", "d_ff": "d_ff",
    "vocab": "vocab_size",
    "experts": "num_experts",
    "experts_per_token": "experts_per_token",
    "slstm_every": "slstm_every",
    "ssm_every": "ssm_every",
    "ssm_state": "ssm_state",
}


@dataclass(frozen=True)
class LeafInfo:
    """One leaf of the adapter's flat layout: ``flat[start:start+size]``
    holds ``name``'s row-major values (f32 on the wire; ``dtype`` is the
    pytree-side storage dtype the unflatten casts back to)."""

    name: str
    start: int
    size: int
    shape: tuple[int, ...]
    dtype: str

    @property
    def stop(self) -> int:
        """End offset (exclusive) of this leaf in the flat vector."""
        return self.start + self.size


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


class ModelAdapter:
    """Uniform model interface for the DFL engines (see module doc).

    Construct via ``get_adapter`` / ``adapter_for`` (cached) rather than
    directly; equality and hashing key on the canonical ``spec`` string
    so adapters behave as jit static arguments.
    """

    def __init__(self, spec: str):
        self.spec = spec

    # --- identity: spec-keyed so jit caches hit across instances ---
    def __eq__(self, other):
        return isinstance(other, ModelAdapter) and self.spec == other.spec

    def __hash__(self):
        return hash((type(self).__name__, self.spec))

    def __repr__(self):
        return f"{type(self).__name__}({self.spec!r})"

    # --- model math (overridden per adapter family) ---
    def init(self, key):
        """One worker's parameter pytree from a PRNGKey."""
        raise NotImplementedError

    def loss(self, params, batch):
        """Scalar training loss for a ``{"x", "y"}`` batch."""
        raise NotImplementedError

    def accuracy(self, params, x, y):
        """Scalar [0, 1] quality metric on an eval batch."""
        raise NotImplementedError

    def make_data(self, num_samples: int, *, seed: int = 0,
                  spread: float = 1.0) -> synthetic.Dataset:
        """The synthetic dataset family this model trains on."""
        raise NotImplementedError

    # --- static layout (shared implementation) ---
    @property
    def template(self):
        """ShapeDtypeStruct pytree of ``init``'s output (no compute)."""
        if not hasattr(self, "_template"):
            self._template = jax.eval_shape(
                lambda: self.init(jax.random.PRNGKey(0)))
        return self._template

    def leaf_offsets(self) -> tuple[LeafInfo, ...]:
        """The flat layout's leaf-offset table, in ``jax.tree`` leaf
        order — the order ``flatten_one`` concatenates (and
        ``jax.flatten_util.ravel_pytree`` flattens) in."""
        if not hasattr(self, "_leaves"):
            infos, off = [], 0
            pairs = jax.tree_util.tree_flatten_with_path(self.template)[0]
            for path, leaf in pairs:
                size = int(np.prod(leaf.shape)) if leaf.shape else 1
                infos.append(LeafInfo(_leaf_name(path), off, size,
                                      tuple(leaf.shape),
                                      str(leaf.dtype)))
                off += size
            self._leaves = tuple(infos)
        return self._leaves

    @property
    def param_count(self) -> int:
        """P: exact number of scalar parameters (flat vector length)."""
        return sum(l.size for l in self.leaf_offsets())

    @property
    def model_bits(self) -> float:
        """Uncompressed wire payload of one model transfer (Eq. 10):
        32 bits per parameter — the value ``SimCluster.model_bits`` and
        the engines' ``p_wire`` derive from."""
        return float(FP32_BITS * self.param_count)

    def flatten_one(self, params):
        """ONE worker's pytree -> [P] f32 vector (leaf order, row-major
        per leaf) — identical to ``engine._flatten_row``."""
        return jnp.concatenate(
            [l.reshape(-1).astype(jnp.float32)
             for l in jax.tree.leaves(params)])

    def unflatten_one(self, vec):
        """Inverse of ``flatten_one``: [P] -> pytree, casting each leaf
        back to its storage dtype."""
        leaves = []
        for info in self.leaf_offsets():
            leaves.append(vec[info.start:info.stop]
                          .reshape(info.shape).astype(info.dtype))
        return jax.tree.unflatten(jax.tree.structure(self.template),
                                  leaves)


class MlpAdapter(ModelAdapter):
    """The synthetic 3-layer classifier (``simulation/model.py``) as
    just another adapter — numerically identical to the engines'
    historical hard-coded path, keeping every existing test meaningful."""

    def __init__(self, dim: int, hidden: int, num_classes: int):
        super().__init__(f"mlp:dim={dim},hidden={hidden},"
                         f"classes={num_classes}")
        self.dim = dim
        self.hidden = hidden
        self.num_classes = num_classes

    def init(self, key):
        """The exact ``init_classifier`` pytree (w1/b1/w2/b2/w3/b3)."""
        return _mlp.init_classifier(key, self.dim, self.hidden,
                                    self.num_classes)

    def loss(self, params, batch):
        """Softmax cross-entropy of the classifier."""
        return _mlp.classifier_loss(params, batch)

    def accuracy(self, params, x, y):
        """Top-1 classification accuracy."""
        return _mlp.accuracy(params, x, y)

    def make_data(self, num_samples: int, *, seed: int = 0,
                  spread: float = 1.0) -> synthetic.Dataset:
        """Gaussian-mixture blobs (``make_classification_data``)."""
        return synthetic.make_classification_data(
            num_samples=num_samples, dim=self.dim,
            num_classes=self.num_classes, spread=spread, seed=seed)


class RegistryAdapter(ModelAdapter):
    """A ``models/registry.py`` family behind the adapter interface.

    The engines' batch ``x`` is an ``[B, S]`` int32 token block from the
    class-structured Markov corpus (``make_token_data``); the LM loss
    trains next-token prediction on ``x`` itself (``y`` — the document
    class — only drives the non-IID partition). ``accuracy`` is the
    bounded inverse per-token perplexity ``exp(-loss)`` so completion-
    time targets stay in [0, 1] across model families."""

    def __init__(self, cfg: ModelConfig, seq_len: int, num_classes: int,
                 spec: str):
        super().__init__(spec)
        self.cfg = cfg
        self.seq_len = seq_len
        self.num_classes = num_classes

    def init(self, key):
        """The registry family's nested parameter pytree."""
        from repro.models import registry
        return registry.init_params(self.cfg, key)

    def loss(self, params, batch):
        """Next-token LM loss: ``x[..., :-1]`` predicts ``x[..., 1:]``.

        Leading batch dims collapse to one ([..., S] -> [B', S]): the
        engines' Alg. 1 measurements evaluate each worker on the full
        [W, 256, S] eval stack, and the mean token loss is invariant to
        the reshape."""
        from repro.models import registry
        tokens = batch["x"].astype(jnp.int32)
        tokens = tokens.reshape((-1, tokens.shape[-1]))
        loss, _ = registry.loss_fn(self.cfg, params,
                                   {"tokens": tokens[:, :-1],
                                    "labels": tokens[:, 1:]})
        return loss

    def accuracy(self, params, x, y):
        """Inverse per-token perplexity exp(-loss) in [0, 1]."""
        return jnp.exp(-self.loss(params, {"x": x, "y": y}))

    def make_data(self, num_samples: int, *, seed: int = 0,
                  spread: float = 1.0) -> synthetic.Dataset:
        """Class-structured Markov-chain LM corpus (p-skew friendly)."""
        return synthetic.make_token_data(
            num_sequences=num_samples, seq_len=self.seq_len,
            vocab_size=self.cfg.vocab_size,
            num_classes=self.num_classes, seed=seed)


def _parse_kv(body: str) -> dict[str, int]:
    out = {}
    if not body:
        return out
    for item in body.split(","):
        key, sep, val = item.partition("=")
        if not sep:
            raise ValueError(f"model spec item {item!r} is not key=val")
        out[key.strip()] = int(val)
    return out


@lru_cache(maxsize=64)
def get_adapter(spec: str, *, dim: int = 32, hidden: int = 64,
                num_classes: int = 10) -> ModelAdapter:
    """Parse a ``cfg.model`` spec into a (cached) adapter.

    ``dim``/``hidden``/``num_classes`` apply to the MLP family only
    (its shapes come from the classification dataset); registry specs
    carry their own dims. Raises ValueError for non-token registry
    families (encdec / vlm) — their batches need modality inputs the
    DFL pipeline does not carry."""
    family, _, body = str(spec).partition(":")
    family = family.strip() or "mlp"
    if family == "mlp":
        if body:
            hidden = int(body)
        return MlpAdapter(dim, hidden, num_classes)
    if family not in DFL_FAMILIES:
        raise ValueError(
            f"model family {family!r} cannot train under DFL: supported "
            f"families are ('mlp',) + {DFL_FAMILIES} (encdec/vlm need "
            "modality inputs the engines' batch pipeline does not carry)")
    kv = _parse_kv(body)
    seq_len = kv.pop("seq", 16)
    n_classes = kv.pop("classes", 8)
    fields = {_SPEC_KEYS[k]: v for k, v in kv.items() if k in _SPEC_KEYS}
    unknown = [k for k in kv if k not in _SPEC_KEYS]
    if unknown:
        raise ValueError(f"unknown model spec keys {unknown}; "
                         f"known: {sorted(set(_SPEC_KEYS))} + seq, classes")
    base = dict(name=f"dfl-{family}", family=family, num_layers=2,
                d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                vocab_size=64, dtype="float32", remat="none")
    if family == "moe":
        base.update(num_experts=4, experts_per_token=2)
    if family == "hybrid":
        base.update(ssm_state=16, ssm_every=2)
    if family == "xlstm":
        base.update(slstm_every=2)
    base.update(fields)
    cfg = ModelConfig(**base)
    # canonical spec: sorted resolved fields, so equivalent key spellings
    # ("d=32" vs "d_model=32") hash to the same jit cache entry
    canon = (f"{family}:" + ",".join(
        f"{k}={v}" for k, v in sorted(
            dataclasses.asdict(cfg).items())
        if not isinstance(v, (tuple, str)) and v)
        + f",seq={seq_len},classes={n_classes}")
    return RegistryAdapter(cfg, seq_len, n_classes, canon)


def adapter_for(cfg, data=None, hidden: int = 64) -> ModelAdapter:
    """The adapter a run's ``FedHPConfig`` names, with MLP shape dims
    taken from ``data`` (the engines' call pattern; defaults reproduce
    the historical hard-coded classifier exactly)."""
    spec = getattr(cfg, "model", "mlp")
    if data is not None and str(spec).partition(":")[0] in ("mlp", ""):
        return get_adapter(spec, dim=int(data.x.shape[-1]),
                           hidden=hidden,
                           num_classes=int(data.num_classes))
    return get_adapter(spec, hidden=hidden)
