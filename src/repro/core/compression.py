"""Compressed gossip codecs: int8 quantization and top-k / rand-k
sparsification with error feedback (ChocoSGD / DeepSqueeze-style,
beyond-paper) — the single source of the compensated update every call
site implements.

``cfg.compress`` selects the codec (``parse_mode`` -> ``Codec``):

  - ``"int8"`` — the flattened parameter vector [P] is laid out as a
    [rows, cols] matrix (``flat_tile_shape``: cols = min(1024, P),
    rows = ceil(P/cols), zero-padded to rows*cols) and quantized per
    (8, 1024) tile: int8 payload of rows*cols bytes plus one f32 scale
    per tile (the scale side-channel is <0.05% of the payload at real
    model sizes).
  - ``"topk:<k>"`` — each worker keeps the k largest-magnitude
    coordinates of its payload and ships (value, index) pairs; the rest
    are zero on the wire. k is a fraction of P when < 1, an absolute
    count otherwise.
  - ``"randk:<k>"`` — k coordinates drawn from a seeded stream shared by
    sender and receiver (``sparsify_base_key``), so only the k values
    plus the mask seed go on the wire — ~2x fewer bits than top-k at
    equal k, at the price of ignoring coordinate magnitudes.

All codecs share one state shape — a per-worker [W, P] buffer next to
the params — but its meaning is per codec (``carries_state`` /
``state_init``):

  - int8: the error-feedback residual,
        z = x + e,  ŷ = C(z),  e' = z - ŷ,
        x' = x + sum_j W_ij (ŷ_j - ŷ_i)
    (identical in ``engine.run_dfl``, ``fused.run_dfl_fused`` and
    ``runtime/collectives.gossip_compressed_fn``);
  - top-k (EF on): the tracked public copy x̂ (ChocoSGD form — raw
    parameters with a plain residual are unstable under gossip),
        q = topk(x - x̂),  x̂' = x̂ + q,
        x' = x + gamma (W @ x̂' - x̂')   (gamma = cfg.sparse_gamma);
  - rand-k: no state — the shared mask ships the drawn coordinates
    exactly and the rest await a later draw (intermittent exact gossip).

For a row-stochastic W every form is an exact no-op through an identity
mix, and for a doubly stochastic W the fleet average of x is preserved
exactly; the stateful codecs then remove the per-worker compression
bias over rounds, while naive compressed mixing (EF off) stalls — at
the int8 step floor, or with the never-shipped small coordinates frozen
for naive top-k (tests/test_compression.py).

Eq. 10 accounting: a compressed link transfers ``codec.wire_bits(P)``
instead of 32 P bits, so comm time scales down by ``codec.wire_ratio(P)``
(~3.5-4x int8, 1/(2f) top-k, ~1/f rand-k at keep-fraction f) — both
engines charge beta / wire_ratio on compressed runs, and the adaptive
planner solves tau*/topology against the same ratio
(``controller.AdaptiveController.decide(wire_ratio=...)``; see
docs/PLANNER.md).

The Pallas kernels (``kernels/quantize_block.py``,
``kernels/sparsify_block.py``) and the jnp oracles (``kernels/ref.py``)
share this tiling; the fused engines encode through the kernels, the
reference engines through the oracles, and the differential harness
(tests/test_fused_equivalence.py) proves the round trips
interchangeable — bit-identical payloads for the pure-select sparse
codecs, 1-ulp for the int8 dequantize multiply.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.gossip_mix import pad_to_blocks
from repro.kernels.quantize_block import (BLOCK_COLS, BLOCK_ROWS,
                                          dequantize_block_2d,
                                          quantize_block_2d)
from repro.kernels.sparsify_block import sparsify_block_2d

COMPRESS_MODES = ("none", "int8", "topk:<k>", "randk:<k>",
                  "leafmap:<pat>=<codec>,...")
SPARSE_KINDS = ("topk", "randk")
UNIFORM_KINDS = ("none", "int8", "topk", "randk")

FP32_BITS = 32
INT8_BITS = 8
SCALE_BITS = 32
INDEX_BITS = 32     # top-k ships one explicit coordinate index per value
SEED_BITS = 32      # rand-k ships only the shared mask seed

# rand-k mask stream constant: folds cfg.seed into a stream independent
# of the batch-sampling / model-init / AD-PSGD partner streams
_SPARSE_STREAM = 0x5A


@dataclass(frozen=True)
class Codec:
    """One parsed ``cfg.compress`` wire codec.

    ``kind`` is one of none | int8 | topk | randk; ``k`` is the sparse
    keep spec — a fraction of P when in (0, 1), an absolute coordinate
    count when >= 1, and 0 for the non-sparse kinds. A ``Codec`` is the
    currency of the compression-aware planner: ``RoundPlan.codec``
    carries the (possibly tightened) codec from the strategy into both
    engines, which resolve k against the actual parameter count and
    charge Eq. 10 comm time / ``wire_ratio``.
    """

    kind: str
    k: float = 0.0

    @property
    def is_sparse(self) -> bool:
        """True for the top-k / rand-k sparsification kinds."""
        return self.kind in SPARSE_KINDS

    @property
    def mode(self) -> str:
        """The ``cfg.compress`` string this codec round-trips to."""
        return f"{self.kind}:{self.k:g}" if self.is_sparse else self.kind

    def with_k(self, k: float) -> "Codec":
        """Same kind, new keep spec (the planner's k-tightening step)."""
        return Codec(self.kind, float(k))

    def resolve_k(self, num_params: int) -> int:
        """The absolute per-row coordinate count for a P-sized payload."""
        if not self.is_sparse:
            return 0
        k = self.k * num_params if self.k < 1.0 else self.k
        return int(min(max(round(k), 1), num_params))

    def wire_bits(self, num_params: int) -> int:
        """Bits on the wire for one model transfer under this codec."""
        if self.kind == "none":
            return FP32_BITS * num_params
        if self.kind == "int8":
            rows, cols = flat_tile_shape(num_params)
            br, bc, rp, cp = pad_to_blocks(rows, cols, BLOCK_ROWS,
                                           BLOCK_COLS)
            n_tiles = (rp // br) * (cp // bc)
            return INT8_BITS * rows * cols + SCALE_BITS * n_tiles
        k = self.resolve_k(num_params)
        if self.kind == "topk":
            return k * (FP32_BITS + INDEX_BITS)
        return k * FP32_BITS + SEED_BITS                    # randk

    def wire_ratio(self, num_params: int) -> float:
        """Uncompressed / compressed wire bits — the Eq. 10 comm divisor
        and the ratio the adaptive planner solves tau*/topology against."""
        return FP32_BITS * num_params / self.wire_bits(num_params)


def parse_mode(mode) -> "Codec | LeafmapCodec":
    """Parse a ``cfg.compress`` value (or pass a ``Codec`` through).

    Accepts ``"none"``, ``"int8"``, ``"topk:<k>"`` and ``"randk:<k>"``
    with k a positive fraction (< 1, of P) or absolute count (>= 1),
    plus the per-leaf map ``"leafmap:<pat>=<codec>,...,default=<codec>"``
    (``parse_leafmap``) — e.g.
    ``"leafmap:embed=randk:0.05,ln=none,default=int8"``.
    """
    if isinstance(mode, (Codec, LeafmapCodec)):
        return mode
    if mode in ("none", "int8"):
        return Codec(str(mode))
    kind, sep, arg = str(mode).partition(":")
    if kind == "leafmap" and sep:
        return parse_leafmap(arg)
    if kind in SPARSE_KINDS and sep:
        try:
            k = float(arg)
        except ValueError:
            k = 0.0
        if k > 0.0:
            return Codec(kind, k)
    raise ValueError(f"compress must be one of {COMPRESS_MODES} "
                     f"(k a positive fraction of P or an absolute "
                     f"count), got {mode!r}")


def validate_mode(mode: str) -> str:
    """Check a ``cfg.compress`` value against the supported wire modes
    (raises ValueError) and return it unchanged."""
    parse_mode(mode)
    return mode


# ---------------------------------------------------------------------------
# per-leaf codec maps ("leafmap:..."): heterogeneous codecs over one model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LeafSegment:
    """One contiguous run ``flat[:, start:stop]`` of the wire vector that
    a single uniform ``Codec`` applies to. Built by
    ``LeafmapCodec.compile`` from the adapter's leaf-offset table
    (adjacent leaves with the same codec merge into one segment; sparse
    k fractions resolve against the MERGED segment length)."""

    start: int
    stop: int
    codec: Codec
    k_abs: int = 0

    @property
    def size(self) -> int:
        """Segment length in parameters."""
        return self.stop - self.start


@dataclass(frozen=True)
class LeafmapCodec:
    """A per-leaf codec map: each model leaf gossips under its own wire
    codec (embeddings rand-k hard, layernorms uncompressed, the rest
    int8 — the heterogeneous-codec direction for edge devices with
    wildly different link budgets).

    ``rules`` are (substring-pattern, Codec) pairs matched against the
    adapter's leaf path names in order (first match wins, case-
    insensitive); ``default`` covers unmatched leaves. The parsed form
    is layout-free; engines call ``compile(adapter.leaf_offsets())`` to
    bind it to a concrete flat layout, producing the ``segments`` table
    every payload/wire computation runs over. Frozen + tuple-valued, so
    a compiled map is hashable and rides ``jax.jit`` as a static
    argument.

    Wire accounting is the exact per-segment sum: each segment
    contributes its own codec's ``wire_bits(segment length)`` (int8
    tiling, top-k value+index pairs, rand-k values+seed, raw f32), and
    ``wire_ratio`` divides the uncompressed total by that sum. A
    leafmap is never ``is_sparse`` — the planner's k-tightening
    scheduler only refines uniform sparse codecs."""

    rules: tuple
    default: Codec
    segments: tuple = ()
    kind: str = "leafmap"

    @property
    def is_sparse(self) -> bool:
        """False: k-tightening applies to uniform sparse codecs only."""
        return False

    @property
    def compiled(self) -> bool:
        """Whether ``compile`` has bound this map to a leaf layout."""
        return bool(self.segments)

    @property
    def mode(self) -> str:
        """The ``cfg.compress`` string this map round-trips to."""
        body = ",".join(f"{pat}={c.mode}" for pat, c in self.rules)
        sep = "," if body else ""
        return f"leafmap:{body}{sep}default={self.default.mode}"

    def codec_for(self, leaf_name: str) -> Codec:
        """The codec a leaf path maps to (first matching rule wins)."""
        name = leaf_name.lower()
        for pat, codec in self.rules:
            if pat in name:
                return codec
        return self.default

    def compile(self, leaves) -> "LeafmapCodec":
        """Bind to an adapter's leaf-offset table (objects with
        ``name``/``start``/``stop`` attributes, contiguous from 0).
        Adjacent same-codec leaves merge into one segment; sparse k
        specs resolve to absolute counts per merged segment."""
        runs: list[list] = []
        for leaf in leaves:
            codec = self.codec_for(leaf.name)
            if runs and runs[-1][2] == codec and runs[-1][1] == leaf.start:
                runs[-1][1] = leaf.stop
            else:
                runs.append([leaf.start, leaf.stop, codec])
        segs = tuple(
            LeafSegment(a, b, c, c.resolve_k(b - a)) for a, b, c in runs)
        return LeafmapCodec(self.rules, self.default, segs)

    def _require_compiled(self):
        if not self.segments:
            raise ValueError(
                "LeafmapCodec must be compiled against a model's leaf "
                "layout (adapter.leaf_offsets()) before wire accounting "
                "or payload encoding — engines do this automatically")

    def resolve_k(self, num_params: int) -> int:
        """Per-segment k is already resolved at compile time; the
        engines' uniform-codec k slot is unused (0)."""
        return 0

    def wire_bits(self, num_params: int = 0) -> int:
        """Exact bits on the wire for one model transfer: the sum of
        each segment's own codec accounting (``num_params`` is ignored —
        the compiled segment table fixes the payload)."""
        self._require_compiled()
        return sum(s.codec.wire_bits(s.size) for s in self.segments)

    def wire_ratio(self, num_params: int = 0) -> float:
        """Uncompressed / compressed wire bits, from the segment table
        (the Eq. 10 comm divisor; ``num_params`` ignored, see
        ``wire_bits``)."""
        self._require_compiled()
        total = self.segments[-1].stop
        return FP32_BITS * total / self.wire_bits()


def parse_leafmap(body: str) -> LeafmapCodec:
    """Parse the body of ``"leafmap:<pat>=<codec>,...,default=<codec>"``.

    Each comma-separated item maps a leaf-path substring pattern to a
    uniform codec string (``none`` / ``int8`` / ``topk:<k>`` /
    ``randk:<k>``); the reserved pattern ``default`` sets the codec for
    unmatched leaves (``none`` if absent)."""
    rules: list[tuple[str, Codec]] = []
    default = Codec("none")
    for item in body.split(","):
        if not item.strip():
            continue
        pat, sep, codec_str = item.partition("=")
        if not sep:
            raise ValueError(
                f"leafmap item {item!r} is not <pattern>=<codec>")
        codec = parse_mode(codec_str.strip())
        if not isinstance(codec, Codec):
            raise ValueError("leafmap entries must be uniform codecs, "
                             f"got {codec_str!r}")
        if pat.strip().lower() == "default":
            default = codec
        else:
            rules.append((pat.strip().lower(), codec))
    return LeafmapCodec(tuple(rules), default)


def leafmap_carries_state(lcodec: LeafmapCodec, error_feedback: bool) -> bool:
    """Whether any segment evolves the [W, P] codec-state buffer (the
    buffer is fleet-shaped either way; segments interpret their own
    slice — int8 residual, top-k public copy x̂, or dead zeros)."""
    return any(carries_state(s.codec.kind, error_feedback)
               for s in lcodec.segments)


def leafmap_state_init(flat, lcodec: LeafmapCodec, error_feedback: bool):
    """Per-segment ``state_init`` on [..., W, P]: x̂ segments start at
    the (globally known) initial params, the rest at zero."""
    lcodec._require_compiled()
    parts = [state_init(flat[..., s.start:s.stop], s.codec.kind,
                        error_feedback) for s in lcodec.segments]
    return jnp.concatenate(parts, axis=-1)


def leafmap_state_after_join(err, keep_col, flat, lcodec: LeafmapCodec,
                             error_feedback: bool):
    """Per-segment ``state_after_join``: joined rows re-anchor x̂
    segments at the blended row and zero the residual segments."""
    parts = [state_after_join(err[..., s.start:s.stop], keep_col,
                              flat[..., s.start:s.stop], s.codec.kind,
                              error_feedback) for s in lcodec.segments]
    return jnp.concatenate(parts, axis=-1)


def leafmap_gamma_mask(lcodec: LeafmapCodec,
                       error_feedback: bool) -> "np.ndarray":
    """[P] f32 mask, 1.0 on coordinates whose segment mixes through the
    damped x̂-tracked top-k consensus step (where ``sparse_gamma``
    applies), 0.0 elsewhere. Static per compiled map."""
    import numpy as np
    lcodec._require_compiled()
    mask = np.zeros(lcodec.segments[-1].stop, np.float32)
    for s in lcodec.segments:
        if s.codec.kind == "topk" and error_feedback:
            mask[s.start:s.stop] = 1.0
    return mask


def leafmap_payload(flat, err, lcodec: LeafmapCodec, *,
                    error_feedback: bool = True, key=None, step=None):
    """Per-segment wire round trip on [W, P] -> (payload, new_state).

    Each segment applies its own codec exactly as the uniform paths do:
    ``none`` ships raw values, int8 the EF-compensated round trip
    ŷ = C(x + e), top-k (EF on) advances the tracked public copy x̂ by
    the top-k innovation (the payload IS x̂' — its mixing delta is
    damped by ``sparse_gamma`` via ``leafmap_gamma_mask``), rand-k the
    segment's shared seeded mask (keys folded on the segment start so
    segments draw independent masks). The returned state concatenates
    each segment's own state semantics back into one [W, P] buffer."""
    lcodec._require_compiled()
    pays, states = [], []
    for s in lcodec.segments:
        x = flat[..., s.start:s.stop]
        e = err[..., s.start:s.stop]
        c = s.codec
        if c.kind == "none":
            pays.append(x)
            states.append(e)
        elif c.kind == "topk" and error_feedback:
            q = sparsify_rows(x - e, "topk", s.k_abs)
            xhat = e + q
            pays.append(xhat)
            states.append(xhat)
        elif c.kind == "randk":
            skey = jax.random.fold_in(key, s.start)
            pays.append(sparsify_rows(x, "randk", s.k_abs, key=skey,
                                      step=step))
            states.append(e)
        else:                       # int8 (EF or naive), naive top-k
            ef_seg = carries_state(c.kind, error_feedback) \
                and c.kind != "topk"
            z = x + e if ef_seg else x
            yhat = encode_rows(z, c.kind, s.k_abs, key=key, step=step)
            pays.append(yhat)
            states.append(z - yhat if ef_seg else e)
    return (jnp.concatenate(pays, axis=-1),
            jnp.concatenate(states, axis=-1))


def leafmap_gossip_ref(flat, err, mix, lcodec: LeafmapCodec, *,
                       error_feedback: bool = True, key=None, step=None,
                       gamma: float = 1.0, edges=None):
    """One leafmap-compressed gossip round on the flattened [W, P]
    params — ``compressed_gossip_ref``'s per-leaf twin, shared verbatim
    by the reference and fused engines (so their leafmap trajectories
    are bit-identical by construction).

    Mixing is column-independent, so applying the combined per-segment
    payload through ONE mixing delta is exactly per-segment mixing:

        x' = x + g ⊙ (W @ payload - payload)

    with g the per-coordinate step size — ``sparse_gamma`` on x̂-tracked
    top-k segments, 1 elsewhere. ``edges=(src, dst, w)`` selects the
    sparse edge-list delta (``edge_mix_delta``) like the uniform path."""
    payload, new_err = leafmap_payload(flat, err, lcodec,
                                       error_feedback=error_feedback,
                                       key=key, step=step)
    if edges is not None:
        delta = edge_mix_delta(payload, *edges, flat.shape[0])
    else:
        delta = jnp.tensordot(mix, payload, axes=1) - payload
    gmask = jnp.asarray(leafmap_gamma_mask(lcodec, error_feedback))
    gvec = gmask * gamma + (1.0 - gmask)
    return flat + gvec[None, :] * delta, new_err


# ---------------------------------------------------------------------------
# wire accounting (Eq. 10 extension)
# ---------------------------------------------------------------------------

def flat_tile_shape(num_params: int) -> tuple[int, int]:
    """[P] -> the [rows, cols] layout both engines quantize/mix through."""
    cols = min(BLOCK_COLS, num_params)
    rows = -(-num_params // cols)
    return rows, cols


def wire_bits(num_params: int, mode: str = "int8") -> int:
    """Bits on the wire for one model transfer under ``mode`` (for int8,
    padding included — the payload ships the whole [rows, cols] grid)."""
    return parse_mode(mode).wire_bits(num_params)


def wire_ratio(num_params: int, mode: str = "int8") -> float:
    """Uncompressed / compressed wire bits — the comm-time divisor in
    Eq. 10 (1.0 for ``mode="none"``)."""
    return parse_mode(mode).wire_ratio(num_params)


# ---------------------------------------------------------------------------
# quantize -> dequantize round trips on the shared layout
# ---------------------------------------------------------------------------

def quantize_2d_ref(z2):
    """jnp-oracle twin of ``quantize_block_2d`` (same padding shim)."""
    r, c = z2.shape
    br, bc, rp, cp = pad_to_blocks(r, c, BLOCK_ROWS, BLOCK_COLS)
    if (rp, cp) != (r, c):
        z2 = jnp.pad(z2, ((0, rp - r), (0, cp - c)))
    q, s = ref.quantize_block_ref(z2, br, bc)
    return q[:r, :c], s


def dequantize_2d_ref(q2, scales, dtype=jnp.float32):
    """jnp-oracle twin of ``dequantize_block_2d``."""
    r, c = q2.shape
    br, bc, rp, cp = pad_to_blocks(r, c, BLOCK_ROWS, BLOCK_COLS)
    if (rp, cp) != (r, c):
        q2 = jnp.pad(q2, ((0, rp - r), (0, cp - c)))
    x = ref.dequantize_block_ref(q2, scales, dtype)
    return x[:r, :c]


def quantize_flat(z_flat):
    """[n] -> (q int8 [rows, cols], scales f32) in the shared wire layout.
    Used by ``runtime/collectives`` so the sharded path quantizes exactly
    like the core engines."""
    n = z_flat.shape[-1]
    rows, cols = flat_tile_shape(n)
    z2 = jnp.pad(z_flat, (0, rows * cols - n)).reshape(rows, cols)
    return quantize_2d_ref(z2)


def dequantize_flat(q2, scales, n: int):
    """Inverse of ``quantize_flat``: back to the [n] vector."""
    return dequantize_2d_ref(q2, scales).reshape(-1)[:n]


def qdq_rows(z, *, use_kernel: bool = False, interpret: bool = False):
    """z: [W, P] -> ŷ: [W, P], one int8 round trip per worker row.

    ``use_kernel=True`` routes through the Pallas kernels (the fused
    engine's path); otherwise the jnp oracles. Both produce bit-identical
    ŷ on the same input — the differential harness depends on it.
    """
    w, p = z.shape
    rows, cols = flat_tile_shape(p)
    z3 = jnp.pad(z, ((0, 0), (0, rows * cols - p))).reshape(w, rows, cols)
    if use_kernel:
        def qdq(zi):
            q, s = quantize_block_2d(zi, interpret=interpret)
            return dequantize_block_2d(q, s, interpret=interpret)
    else:
        def qdq(zi):
            return dequantize_2d_ref(*quantize_2d_ref(zi))
    y3 = jax.vmap(qdq)(z3)
    return y3.reshape(w, -1)[:, :p]


# ---------------------------------------------------------------------------
# top-k / rand-k sparsification on the shared layout
# ---------------------------------------------------------------------------

def sparsify_base_key(seed: int):
    """The rand-k mask stream for one run: derived from ``cfg.seed`` on a
    dedicated fold so it is independent of the batch-sampling, model-init
    and AD-PSGD partner streams, and SHARED by both engines (and all
    vmapped seed lanes) — sender and receiver agree on the mask, which is
    why rand-k ships no indices."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), _SPARSE_STREAM)


def randk_scores(key, step, num_params: int):
    """[P] uniform keep scores, deterministic in (key, step) — ``step``
    is the round index for the synchronous engines and the global event
    index for AD-PSGD, so the mask changes every exchange but replays
    identically in the reference and fused engines.

    The mask is SHARED by every worker in the exchange (one draw per
    step, not per worker): with per-worker masks a coordinate one
    endpoint ships and the other doesn't would mix a raw parameter value
    against zero, and under error feedback the unsent coordinates
    inflate until that mismatch is catastrophic. A shared mask makes
    rand-k exact intermittent gossip — the drawn coordinates mix fully,
    the rest wait for a later draw — which is also what lets the wire
    format ship no indices (both ends derive the mask from the seed)."""
    return jax.random.uniform(jax.random.fold_in(key, step), (num_params,))


def sparsify_rows(z, kind: str, k: int, *, key=None, step=None,
                  use_kernel: bool = False, interpret: bool = False):
    """z: [W, P] -> ŷ: [W, P], keeping k coordinates per row (top-k: the
    largest |z| per worker; rand-k: one seeded uniform draw shared by all
    rows) and zeroing the rest.

    The keep threshold (k-th largest gate value per row) is computed with
    ``lax.top_k`` in both paths; ``use_kernel=True`` applies it through
    the Pallas mask-and-pack kernel (``kernels/sparsify_block.py``, the
    fused engines' path) on the [rows, cols] wire layout, otherwise via
    the jnp oracle select. Both are pure selects of the same mask, so
    the outputs are bit-identical."""
    w, p = z.shape
    if kind == "topk":
        gate = jnp.abs(z).astype(jnp.float32)
    elif kind == "randk":
        gate = jnp.broadcast_to(randk_scores(key, step, p), (w, p))
    else:
        raise ValueError(f"not a sparse codec kind: {kind!r}")
    kth = jax.lax.top_k(gate, k)[0][:, -1]
    if not use_kernel:
        return jnp.where(gate >= kth[:, None], z,
                         jnp.zeros_like(z)).astype(z.dtype)
    rows, cols = flat_tile_shape(p)
    pad = rows * cols - p
    z3 = jnp.pad(z, ((0, 0), (0, pad))).reshape(w, rows, cols)
    g3 = jnp.pad(gate, ((0, 0), (0, pad)),
                 constant_values=-1.0).reshape(w, rows, cols)
    y3 = jax.vmap(lambda zi, gi, t: sparsify_block_2d(
        zi, gi, t, interpret=interpret)[0])(z3, g3, kth)
    return y3.reshape(w, -1)[:, :p]


def encode_rows(z, kind: str = "int8", k: int = 0, *, key=None, step=None,
                use_kernel: bool = False, interpret: bool = False):
    """The codec round trip ŷ = C(z) for a batch of worker rows [W, P] —
    the single dispatch every compressed call site goes through."""
    if kind == "int8":
        return qdq_rows(z, use_kernel=use_kernel, interpret=interpret)
    return sparsify_rows(z, kind, k, key=key, step=step,
                         use_kernel=use_kernel, interpret=interpret)


# ---------------------------------------------------------------------------
# the compensated update (canonical form)
# ---------------------------------------------------------------------------

def carries_state(kind: str, error_feedback: bool) -> bool:
    """Whether the codec evolves the per-worker [W, P] state buffer.

    int8 carries the EF residual e; top-k (EF on) carries the tracked
    public copy x̂ (ChocoSGD-style — see ``compressed_gossip_ref``);
    rand-k carries nothing: its shared mask ships the drawn coordinates
    exactly and the rest are not an unsent *increment* but raw state
    awaiting a later draw — feeding them back as error would
    double-count parameters."""
    if kind == "randk":
        return False
    return error_feedback


def state_init(flat, kind: str, error_feedback: bool):
    """The codec-state buffer at round 0 for initial params ``flat``
    [..., W, P]: zeros for the int8 residual, the (globally known)
    initial params for top-k's public copy x̂."""
    if kind == "topk" and error_feedback:
        return flat
    return jnp.zeros_like(flat)


def state_after_join(err, keep_col, flat, kind: str, error_feedback: bool):
    """Reset joined workers' codec state after the donor-average re-init:
    the residual owes nothing (zeros); the top-k public copy x̂ becomes
    the blended row itself — the blend weights are deterministic, so
    every peer can reconstruct it (shared knowledge stays shared).
    ``keep_col``: [W, 1] join mask; ``flat``: the post-blend [W, P]."""
    if kind == "topk" and error_feedback:
        return jnp.where(keep_col, flat, err)
    return jnp.where(keep_col, 0.0, err)


def compress_decompress(flat, err, *, error_feedback: bool = True,
                        kind: str = "int8", k: int = 0, key=None,
                        step=None, use_kernel: bool = False,
                        interpret: bool = False):
    """(x [W, P], e [W, P]) -> (ŷ, e'): the wire payload each worker
    would send under the int8 / rand-k / naive-top-k codecs, plus the
    residual carried to the next round (rand-k carries none — see
    ``carries_state``). Top-k with error feedback does NOT go through
    this roundtrip form — its state is the tracked public copy x̂, see
    ``compressed_gossip_ref``."""
    ef = carries_state(kind, error_feedback) and kind != "topk"
    z = flat + err if ef else flat
    yhat = encode_rows(z, kind, k, key=key, step=step,
                       use_kernel=use_kernel, interpret=interpret)
    new_err = z - yhat if ef else err
    return yhat, new_err


def edge_mix_delta(v, src, dst, w, num_workers: int):
    """Sparse ``(W @ v - v)``: for a row-stochastic mixing matrix the
    mixing delta is ``sum_{j != i} W_ij (v_j - v_i)``, computable from
    directed edges ``(src, dst, w)`` alone via ``segment_sum`` — O(E P)
    instead of the dense tensordot's O(W^2 P). ``num_workers`` must be
    static (it sizes the scatter)."""
    delta = w.astype(jnp.float32)[:, None] * (v[src] - v[dst])
    return jax.ops.segment_sum(delta, dst, num_segments=num_workers)


def compressed_gossip_ref(flat, err, mix, *, error_feedback: bool = True,
                          kind: str = "int8", k: int = 0, key=None,
                          step=None, gamma: float = 1.0,
                          use_kernel: bool = False,
                          interpret: bool = False, edges=None,
                          mix_delta_fn=None):
    """One compressed gossip round on the flattened [W, P] params — the
    jnp reference the engines and tests share, for any codec.

    int8 / rand-k / naive top-k mix the wire round trip ŷ with the same
    tensordot as ``engine._gossip``:

        x' = x + (W @ ŷ - ŷ),        e' = z - ŷ  (int8 EF only)

    Top-k with error feedback is the ChocoSGD form — compressing raw
    parameters with a plain residual is unstable under gossip (workers
    ship an inflated coordinate at different times, and the compensated
    mix then subtracts multiples of live values), so the state buffer
    tracks the public copy x̂ every peer can reconstruct from past
    payloads, the wire carries the top-k innovation, and the consensus
    step is damped by ``gamma``:

        q  = topk_k(x - x̂)           (the payload: k values + indices)
        x̂' = x̂ + q
        x' = x + gamma (W @ x̂' - x̂')

    Innovations shrink as x̂ tracks x, so the feedback loop is stable for
    gamma below a sparsity-dependent bound (cfg.sparse_gamma; see
    tests/test_compression.py for the convergent-vs-naive property).
    Both forms preserve the fleet average exactly for doubly stochastic
    W and are exact no-ops through an identity mix.

    ``edges=(src, dst, w)`` switches the mixing delta to the sparse
    edge-list form (``edge_mix_delta``; pass ``mix=None``) — the same
    compensated update, O(E P) instead of O(W^2 P).

    ``mix_delta_fn`` overrides the delta entirely (pass ``mix=None``):
    the sharded path (``runtime/collectives``) injects its ppermute-routed
    per-shard delta here so the payload/state/update formulas stay this
    single implementation, with only the routing swapped.
    """
    def mix_delta(v):
        if mix_delta_fn is not None:
            return mix_delta_fn(v)
        if edges is not None:
            return edge_mix_delta(v, *edges, flat.shape[0])
        return jnp.tensordot(mix, v, axes=1) - v

    if kind == "topk" and error_feedback:
        q = sparsify_rows(flat - err, "topk", k, use_kernel=use_kernel,
                          interpret=interpret)
        xhat = err + q
        mixed = flat + gamma * mix_delta(xhat)
        return mixed, xhat
    yhat, new_err = compress_decompress(flat, err,
                                        error_feedback=error_feedback,
                                        kind=kind, k=k, key=key, step=step,
                                        use_kernel=use_kernel,
                                        interpret=interpret)
    mixed = flat + mix_delta(yhat)
    return mixed, new_err


def compressed_pair_ref(xi, xj, ei, ej, *, error_feedback: bool = True,
                        kind: str = "int8", k: int = 0, key=None,
                        step=None, gamma: float = 1.0,
                        use_kernel: bool = False, interpret: bool = False):
    """One compressed AD-PSGD pairwise exchange — the compensated update
    restricted to a single edge with the doubly stochastic 2x2 mix
    W = [[.5, .5], [.5, .5]]:

        x_i' = x_i + ½ (ŷ_j - ŷ_i),   x_j' = x_j + ½ (ŷ_i - ŷ_j)

    with ŷ = C(x + e) per endpoint for int8 (residuals carry per
    worker), ŷ = C(x) for rand-k (both endpoints share the event's mask
    draw — ``step`` is the global event index) and naive top-k, and the
    x̂-tracked form for top-k with error feedback (the pairwise case of
    ``compressed_gossip_ref``):

        q = topk_k(x - x̂) per endpoint,  x̂' = x̂ + q,
        x_i' = x_i + ½ gamma (x̂_j' - x̂_i')  (x_j' symmetric)

    The endpoints do NOT become equal — unlike the exact average — but
    their SUM is preserved exactly. Takes and returns [P] rows plus the
    two state rows. ``use_kernel=True`` routes the round trip through
    the Pallas kernels (the fused engine's path); both paths produce
    bit-identical payloads for the sparse codecs and 1-ulp ŷ for int8."""
    if kind == "topk" and error_feedback:
        q = sparsify_rows(jnp.stack([xi - ei, xj - ej]), "topk", k,
                          use_kernel=use_kernel, interpret=interpret)
        xhat_i, xhat_j = ei + q[0], ej + q[1]
        half = 0.5 * gamma * (xhat_j - xhat_i)
        return xi + half, xj - half, xhat_i, xhat_j
    ef = carries_state(kind, error_feedback)
    z = jnp.stack([xi + ei, xj + ej]) if ef else jnp.stack([xi, xj])
    yhat = encode_rows(z, kind, k, key=key, step=step,
                       use_kernel=use_kernel, interpret=interpret)
    half = 0.5 * (yhat[1] - yhat[0])
    xi2 = xi + half
    xj2 = xj - half
    if ef:
        ei, ej = z[0] - yhat[0], z[1] - yhat[1]
    return xi2, xj2, ei, ej
