"""Compressed gossip: int8 exchange with error feedback (ChocoSGD /
DeepSqueeze-style, beyond-paper) — the single source of the compensated
update every call site implements.

Wire format (per worker, per round with communication):
  - the flattened parameter vector [P] is laid out as a [rows, cols]
    matrix (``flat_tile_shape``: cols = min(1024, P), rows = ceil(P/cols),
    zero-padded to rows*cols) and quantized per (8, 1024) tile — int8
    payload of rows*cols bytes plus one f32 scale per tile (the scale
    side-channel is <0.05% of the payload at real model sizes);
  - the compensated update (identical in ``engine.run_dfl``,
    ``fused.run_dfl_fused`` and ``runtime/collectives.
    gossip_compressed_fn``):

        z_i  = x_i + e_i          (e_i: per-worker residual, 0 if EF off)
        ŷ_i  = dequant(quant(z_i))   (what goes on the wire)
        e_i' = z_i - ŷ_i          (error feedback; e_i unchanged if off)
        x_i' = x_i + sum_j W_ij (ŷ_j - ŷ_i)

    For a row-stochastic W the mixing term is (W @ ŷ)_i - ŷ_i, so a
    round-trip through an identity mix is an exact no-op, and for a
    doubly stochastic W the fleet average of x is preserved exactly —
    error feedback then removes the per-worker quantization bias over
    rounds (naive quantized mixing stalls at the int8 step floor; see
    tests/test_compression.py).

Eq. 10 accounting: a compressed link transfers ``wire_bits(P, "int8")``
instead of 32 P bits, so comm time scales down by ``wire_ratio(P)``
(~3.5-4x) — both engines charge beta / wire_ratio on compressed runs.

The Pallas kernels (``kernels/quantize_block.py``) and the jnp oracles
(``kernels/ref.py``) share this tiling; the fused engine quantizes through
the kernels, the reference engine through the oracles, and the
differential harness (tests/test_fused_equivalence.py) proves the two
round trips interchangeable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.gossip_mix import pad_to_blocks
from repro.kernels.quantize_block import (BLOCK_COLS, BLOCK_ROWS,
                                          dequantize_block_2d,
                                          quantize_block_2d)

COMPRESS_MODES = ("none", "int8")

FP32_BITS = 32
INT8_BITS = 8
SCALE_BITS = 32


def validate_mode(mode: str) -> str:
    """Check a ``cfg.compress`` value against the supported wire modes."""
    if mode not in COMPRESS_MODES:
        raise ValueError(f"compress must be one of {COMPRESS_MODES}, "
                         f"got {mode!r}")
    return mode


# ---------------------------------------------------------------------------
# wire accounting (Eq. 10 extension)
# ---------------------------------------------------------------------------

def flat_tile_shape(num_params: int) -> tuple[int, int]:
    """[P] -> the [rows, cols] layout both engines quantize/mix through."""
    cols = min(BLOCK_COLS, num_params)
    rows = -(-num_params // cols)
    return rows, cols


def wire_bits(num_params: int, mode: str = "int8") -> int:
    """Bits on the wire for one model transfer (padding included — the
    int8 payload ships the whole [rows, cols] grid)."""
    validate_mode(mode)
    if mode == "none":
        return FP32_BITS * num_params
    rows, cols = flat_tile_shape(num_params)
    br, bc, rp, cp = pad_to_blocks(rows, cols, BLOCK_ROWS, BLOCK_COLS)
    n_tiles = (rp // br) * (cp // bc)
    return INT8_BITS * rows * cols + SCALE_BITS * n_tiles


def wire_ratio(num_params: int) -> float:
    """Uncompressed / int8 wire bits — the comm-time divisor in Eq. 10."""
    return wire_bits(num_params, "none") / wire_bits(num_params, "int8")


# ---------------------------------------------------------------------------
# quantize -> dequantize round trips on the shared layout
# ---------------------------------------------------------------------------

def quantize_2d_ref(z2):
    """jnp-oracle twin of ``quantize_block_2d`` (same padding shim)."""
    r, c = z2.shape
    br, bc, rp, cp = pad_to_blocks(r, c, BLOCK_ROWS, BLOCK_COLS)
    if (rp, cp) != (r, c):
        z2 = jnp.pad(z2, ((0, rp - r), (0, cp - c)))
    q, s = ref.quantize_block_ref(z2, br, bc)
    return q[:r, :c], s


def dequantize_2d_ref(q2, scales, dtype=jnp.float32):
    """jnp-oracle twin of ``dequantize_block_2d``."""
    r, c = q2.shape
    br, bc, rp, cp = pad_to_blocks(r, c, BLOCK_ROWS, BLOCK_COLS)
    if (rp, cp) != (r, c):
        q2 = jnp.pad(q2, ((0, rp - r), (0, cp - c)))
    x = ref.dequantize_block_ref(q2, scales, dtype)
    return x[:r, :c]


def quantize_flat(z_flat):
    """[n] -> (q int8 [rows, cols], scales f32) in the shared wire layout.
    Used by ``runtime/collectives`` so the sharded path quantizes exactly
    like the core engines."""
    n = z_flat.shape[-1]
    rows, cols = flat_tile_shape(n)
    z2 = jnp.pad(z_flat, (0, rows * cols - n)).reshape(rows, cols)
    return quantize_2d_ref(z2)


def dequantize_flat(q2, scales, n: int):
    """Inverse of ``quantize_flat``: back to the [n] vector."""
    return dequantize_2d_ref(q2, scales).reshape(-1)[:n]


def qdq_rows(z, *, use_kernel: bool = False, interpret: bool = False):
    """z: [W, P] -> ŷ: [W, P], one int8 round trip per worker row.

    ``use_kernel=True`` routes through the Pallas kernels (the fused
    engine's path); otherwise the jnp oracles. Both produce bit-identical
    ŷ on the same input — the differential harness depends on it.
    """
    w, p = z.shape
    rows, cols = flat_tile_shape(p)
    z3 = jnp.pad(z, ((0, 0), (0, rows * cols - p))).reshape(w, rows, cols)
    if use_kernel:
        def qdq(zi):
            q, s = quantize_block_2d(zi, interpret=interpret)
            return dequantize_block_2d(q, s, interpret=interpret)
    else:
        def qdq(zi):
            return dequantize_2d_ref(*quantize_2d_ref(zi))
    y3 = jax.vmap(qdq)(z3)
    return y3.reshape(w, -1)[:, :p]


# ---------------------------------------------------------------------------
# the compensated update (canonical form)
# ---------------------------------------------------------------------------

def compress_decompress(flat, err, *, error_feedback: bool = True,
                        use_kernel: bool = False, interpret: bool = False):
    """(x [W, P], e [W, P]) -> (ŷ, e'): the wire payload each worker
    would send, plus the residual carried to the next round."""
    z = flat + err if error_feedback else flat
    yhat = qdq_rows(z, use_kernel=use_kernel, interpret=interpret)
    new_err = z - yhat if error_feedback else err
    return yhat, new_err


def compressed_gossip_ref(flat, err, mix, *, error_feedback: bool = True):
    """One compressed gossip round on the flattened [W, P] params — the
    jnp reference the engines and tests share. The mixing term is the
    same tensordot as ``engine._gossip``, applied to ŷ:

        x' = x + (W @ ŷ - ŷ)
    """
    yhat, new_err = compress_decompress(flat, err,
                                        error_feedback=error_feedback)
    mixed = flat + (jnp.tensordot(mix, yhat, axes=1) - yhat)
    return mixed, new_err


def compressed_pair_ref(xi, xj, ei, ej, *, error_feedback: bool = True,
                        use_kernel: bool = False, interpret: bool = False):
    """One compressed AD-PSGD pairwise exchange — the compensated update
    restricted to a single edge with the doubly stochastic 2x2 mix
    W = [[.5, .5], [.5, .5]]:

        x_i' = x_i + ½ (ŷ_j - ŷ_i),   x_j' = x_j + ½ (ŷ_i - ŷ_j)

    where ŷ = dequant(quant(x + e)) per endpoint (same wire format as the
    synchronous engines). The endpoints do NOT become equal — unlike the
    exact average — but their SUM is preserved exactly, and error
    feedback removes the per-worker quantization bias over events
    (ChocoSGD extended to pairwise exchange). Takes and returns [P] rows
    plus the two residuals. ``use_kernel=True`` routes the int8 round
    trip through the Pallas kernels (the fused engine's path); both paths
    produce bit-identical ŷ."""
    z = jnp.stack([xi + ei, xj + ej]) if error_feedback \
        else jnp.stack([xi, xj])
    yhat = qdq_rows(z, use_kernel=use_kernel, interpret=interpret)
    half = 0.5 * (yhat[1] - yhat[0])
    xi2 = xi + half
    xj2 = xj - half
    if error_feedback:
        ei, ej = z[0] - yhat[0], z[1] - yhat[1]
    return xi2, xj2, ei, ej
