"""DFL execution engines over the simulated heterogeneous cluster.

``run_dfl``  — synchronous round engine (FedHP / D-PSGD / LD-SGD / PENS):
per round, the strategy plans (A^h, tau^h); workers run tau_i local SGD
steps (vmapped across the worker dimension, masked to tau_i — the same
masked-trip-count semantics the TPU runtime uses); the simulated clock
charges t_i = tau_i mu_i + max_j beta_ij (Eq. 10); gossip mixes with the
uniform matrix (Eq. 5-6); measurements (consensus distances on edges,
update norms, L/sigma estimates — Alg. 1 lines 4-5) feed back to the
strategy.

``run_adpsgd`` — event-driven asynchronous engine (AD-PSGD [23]): workers
run independently; on finishing tau local steps a worker averages models
pairwise with a random neighbor; the event clock captures staleness and
the near-zero waiting time the paper reports (Fig. 7). The event loop's
control plane (heap of finish times, partner selection, churn at round
boundaries, staleness counters) is factored into the pure host function
``adpsgd_schedule`` so the fused engine (``core/fused.run_adpsgd_fused``)
can lower the same event sequence into one ``jax.lax.scan`` — the
differential harness proves the two interchangeable.
"""
from __future__ import annotations

import dataclasses
import heapq
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedHPConfig
from repro.core import compression
from repro.core import modelspec
from repro.core import robust as robust_agg
from repro.core import topology as topo
from repro.core.algorithms import Strategy
from repro.core.consensus import pairwise_distances
from repro.kernels import ref as kernel_ref
from repro.data.synthetic import Dataset
from repro.simulation.cluster import SimCluster


@dataclass
class RoundRecord:
    """One round of ``History``: the host-side record both engines must
    reproduce bit-identically (times, taus, links, staleness) next to the
    device metrics (accuracy, loss, consensus) that match to float
    tolerance. ``staleness`` is AD-PSGD's per-round mean staleness (how
    many pairwise averages hit a worker's live row while its delta was in
    flight); synchronous engines record 0.0."""

    round: int
    round_time: float
    waiting_time: float
    accuracy: float
    loss: float
    mean_tau: float
    num_links: int
    consensus: float
    cumulative_time: float
    staleness: float = 0.0


@dataclass
class History:
    """Per-round trajectory of one run — the common result type of all
    three engines (reference, fused, AD-PSGD), so paper metrics
    (completion time to target accuracy, Fig. 3; average waiting time,
    Fig. 7) compare across engines and algorithms. ``final_params`` is
    the last [W, ...] worker-stacked parameter pytree (set by every
    engine; feeds ``checkpoint/store.py`` save -> resume — not a
    per-round field, so ``as_arrays`` ignores it). ``screen_rejects``
    is set only by screened AD-PSGD runs (``cfg.robust="screen:<z>"``):
    per-round counts of rejected pairwise payloads (up to two per
    event — each endpoint screens independently)."""

    records: list[RoundRecord] = field(default_factory=list)
    final_params: object = None
    screen_rejects: list[int] | None = None

    def completion_time(self, target_acc: float) -> float | None:
        """Paper metric: total time until the average model reaches
        `target_acc` (None if never)."""
        for r in self.records:
            if r.accuracy >= target_acc:
                return r.cumulative_time
        return None

    @property
    def final_accuracy(self) -> float:
        """Fleet-average test accuracy at the last recorded round."""
        return self.records[-1].accuracy if self.records else 0.0

    @property
    def avg_waiting(self) -> float:
        """Mean per-round waiting time (Eq. 11; the Fig. 7 metric)."""
        return float(np.mean([r.waiting_time for r in self.records])) \
            if self.records else 0.0

    def as_arrays(self) -> dict[str, np.ndarray]:
        """Column-major view of the records, one array per field."""
        keys = tuple(f.name for f in dataclasses.fields(RoundRecord))
        return {k: np.array([getattr(r, k) for r in self.records])
                for k in keys}


# ---------------------------------------------------------------------------
# jit'd worker math (vmapped over the worker dimension)
# ---------------------------------------------------------------------------

def _sgd_worker(adapter, params, bx, by, tau, lr, tau_max: int):
    """tau-masked local SGD for ONE worker (Eq. 3) under ``adapter``'s
    loss. Shared with the fused engine (core/fused.py) — the equivalence
    guarantee rests on both engines running this exact step."""

    def step(p, xs):
        k, (x, y) = xs
        g = jax.grad(adapter.loss)(p, {"x": x, "y": y})
        mask = (k < tau).astype(jnp.float32)
        return jax.tree.map(
            lambda w, gg: (w - lr * mask * gg.astype(jnp.float32)
                           ).astype(w.dtype), p, g), None

    ks = jnp.arange(tau_max)
    out, _ = jax.lax.scan(step, params, (ks, (bx, by)))
    return out


@partial(jax.jit, static_argnames=("adapter", "tau_max"))
def _local_train(adapter, stacked, batches_x, batches_y, taus, lr,
                 tau_max: int):
    """tau_i masked local SGD. stacked: [W,...] pytree; batches: [W,T,B,*]."""
    return jax.vmap(
        lambda p, bx, by, tau: _sgd_worker(adapter, p, bx, by, tau, lr,
                                           tau_max))(
            stacked, batches_x, batches_y, taus)


@jax.jit
def _gossip(stacked, mix):
    """x_i <- sum_j mix_ij x_j (Eq. 5 in matrix form)."""
    return jax.tree.map(
        lambda leaf: jnp.tensordot(mix, leaf, axes=1).astype(leaf.dtype),
        stacked)


@jax.jit
def _gossip_edges(flat, src, dst, w):
    """Sparse Eq. 5 on the flattened [W, P] matrix: the ``segment_sum``
    jnp oracle (``kernels/ref.gossip_edges_ref``) over directed edges —
    the dense ``_gossip``'s twin for ``cfg.gossip == "sparse"``. Retraces
    per distinct edge count; the fused engine pads to a static E_max."""
    return kernel_ref.gossip_edges_ref(flat, src, dst, w)


@partial(jax.jit, static_argnames=("kind", "k", "error_feedback"))
def _gossip_compressed_edges(flat, err, src, dst, w, key, step, gamma, *,
                             kind: str, k: int, error_feedback: bool):
    """Compressed sparse Eq. 5: ``_gossip_compressed`` with the mixing
    delta computed from directed edges (``compression.edge_mix_delta``)
    instead of a dense matrix — same codecs, same compensated update."""
    return compression.compressed_gossip_ref(
        flat, err, None, error_feedback=error_feedback, kind=kind, k=k,
        key=key, step=step, gamma=gamma, edges=(src, dst, w))


def _blend_joined(stacked, keep, w):
    """Rows in ``keep`` adopt the w-weighted average of the fleet; an
    all-False keep (or all-zero w) leaves the pytree untouched exactly.
    Shared with the fused engine, which precomputes keep/w host-side."""

    def leaf(l):
        mean = jnp.tensordot(w, l.astype(jnp.float32), axes=1)
        k = keep.reshape((-1,) + (1,) * (l.ndim - 1))
        return jnp.where(k, mean[None].astype(l.dtype), l)

    return jax.tree.map(leaf, stacked)


@jax.jit
def _reinit_joined(stacked, joined, donors):
    """Joining workers adopt the average of the incumbent alive models
    (a fresh worker starting from x^0 mid-run would wreck consensus)."""
    w = donors.astype(jnp.float32)
    w = w / jnp.maximum(w.sum(), 1.0)
    return _blend_joined(stacked, joined, w)


@jax.jit
def _flatten_workers(stacked):
    """[W, ...] pytree -> [W, P] matrix."""
    leaves = jax.tree.leaves(stacked)
    return jnp.concatenate(
        [l.reshape(l.shape[0], -1).astype(jnp.float32) for l in leaves],
        axis=1)


def _unflatten(flat, stacked):
    """Inverse of ``_flatten_workers`` against the template pytree."""
    leaves = jax.tree.leaves(stacked)
    out, off = [], 0
    for l in leaves:
        sz = int(np.prod(l.shape[1:])) if l.ndim > 1 else 1
        out.append(flat[:, off:off + sz].reshape(l.shape).astype(l.dtype))
        off += sz
    return jax.tree.unflatten(jax.tree.structure(stacked), out)


def _param_count(stacked) -> int:
    """P of the flattened [W, P] parameter matrix."""
    return sum(int(np.prod(l.shape[1:])) for l in jax.tree.leaves(stacked))


def _flatten_row(params):
    """ONE worker's pytree -> [P] f32 vector (row of the [W, P] layout)."""
    return jnp.concatenate(
        [l.reshape(-1).astype(jnp.float32) for l in jax.tree.leaves(params)])


def _unflatten_row(vec, template):
    """Inverse of ``_flatten_row`` against a single-worker template pytree."""
    leaves = jax.tree.leaves(template)
    out, off = [], 0
    for l in leaves:
        sz = int(np.prod(l.shape))
        out.append(vec[off:off + sz].reshape(l.shape).astype(l.dtype))
        off += sz
    return jax.tree.unflatten(jax.tree.structure(template), out)


@partial(jax.jit, static_argnames=("kind", "k", "error_feedback"))
def _gossip_compressed(flat, err, mix, key, step, gamma, *, kind: str,
                       k: int, error_feedback: bool):
    """Compressed Eq. 5 on the flattened [W, P] matrix: each worker puts
    the codec's payload on the wire (int8 round trip of z = x + e, the
    top-k innovation against the tracked public copy x̂, or the shared
    rand-k mask draw — ``kind``/``k`` from the round's codec,
    ``key``/``step`` seeding the rand-k mask, ``gamma`` damping the
    top-k consensus step), mixes with the same tensordot as ``_gossip``
    and carries the codec state (residual / x̂) forward. The update
    itself lives in ``core/compression.py`` — the fused engine and
    ``runtime/collectives`` implement the same formulas."""
    return compression.compressed_gossip_ref(
        flat, err, mix, error_feedback=error_feedback, kind=kind, k=k,
        key=key, step=step, gamma=gamma)


@partial(jax.jit, static_argnames=("lcodec", "error_feedback"))
def _gossip_leafmap(flat, err, mix, key, step, gamma, *, lcodec,
                    error_feedback: bool):
    """Per-leaf-codec Eq. 5 on the flattened [W, P] matrix: each leaf
    segment ships under its own codec (``compression.LeafmapCodec``,
    compiled against the adapter's leaf-offset table), one mixing delta
    on the combined payload, the top-k consensus damping applied only on
    the coordinates whose segment tracks x̂."""
    return compression.leafmap_gossip_ref(
        flat, err, mix, lcodec, error_feedback=error_feedback, key=key,
        step=step, gamma=gamma)


@partial(jax.jit, static_argnames=("lcodec", "error_feedback"))
def _gossip_leafmap_edges(flat, err, src, dst, w, key, step, gamma, *,
                          lcodec, error_feedback: bool):
    """``_gossip_leafmap`` with the mixing delta computed from directed
    edges instead of a dense matrix (``cfg.gossip == "sparse"``)."""
    return compression.leafmap_gossip_ref(
        flat, err, None, lcodec, error_feedback=error_feedback, key=key,
        step=step, gamma=gamma, edges=(src, dst, w))


def _measure_worker(adapter, p, q, eval_x, eval_y, probe_x, probe_y):
    """One worker's Alg. 1 measurements. NOTE the eval/probe tensors are
    the FULL [W, 256] stacks for every worker (historical semantics both
    engines must share — FedHP's decisions were tuned against it)."""
    loss_p = adapter.loss(p, {"x": eval_x, "y": eval_y})
    acc = adapter.accuracy(p, eval_x, eval_y)
    g_p = jax.grad(adapter.loss)(p, {"x": eval_x, "y": eval_y})
    g_q = jax.grad(adapter.loss)(q, {"x": eval_x, "y": eval_y})
    num = jnp.sqrt(sum(jnp.sum(jnp.square(a - b)) for a, b in
                       zip(jax.tree.leaves(g_p), jax.tree.leaves(g_q))))
    den = jnp.sqrt(sum(jnp.sum(jnp.square(a - b)) for a, b in
                       zip(jax.tree.leaves(p), jax.tree.leaves(q))))
    smooth_l = num / jnp.maximum(den, 1e-8)
    # sigma_i: variance of a small-probe gradient vs full-batch gradient
    g_s = jax.grad(adapter.loss)(p, {"x": probe_x, "y": probe_y})
    sig2 = sum(jnp.sum(jnp.square(a - b)) for a, b in
               zip(jax.tree.leaves(g_s), jax.tree.leaves(g_p)))
    upd = den
    return loss_p, acc, smooth_l, jnp.sqrt(sig2), upd


@partial(jax.jit, static_argnames=("adapter",))
def _measure(adapter, stacked, prev_stacked, eval_x, eval_y, probe_x,
             probe_y):
    """Per-worker loss/acc + Alg. 1 estimates (L_i, sigma_i) + update norms."""
    return jax.vmap(lambda p, q: _measure_worker(adapter, p, q, eval_x,
                                                 eval_y, probe_x, probe_y))(
        stacked, prev_stacked)


@partial(jax.jit, static_argnames=("adapter",))
def _cross_loss_matrix(adapter, stacked, xs, ys):
    """[N,N] loss of worker j's model on worker i's local sample batch."""

    def on_data(x, y):
        return jax.vmap(lambda p: adapter.loss(p, {"x": x, "y": y}))(
            stacked)

    return jax.vmap(on_data)(xs, ys)          # [data_i, model_j]


def _mean_accuracy(adapter, stacked, test_x, test_y,
                   alive: np.ndarray | None = None) -> tuple[float, float]:
    """Fleet-average test accuracy/loss over the alive workers (departed
    workers' frozen models are not part of the deployment)."""
    accs = jax.vmap(lambda p: adapter.accuracy(p, test_x, test_y))(stacked)
    losses = jax.vmap(
        lambda p: adapter.loss(p, {"x": test_x, "y": test_y}))(stacked)
    if alive is not None and not alive.all() and alive.any():
        w = jnp.asarray(alive, jnp.float32)
        w = w / w.sum()
        return float(jnp.dot(w, accs)), float(jnp.dot(w, losses))
    return float(jnp.mean(accs)), float(jnp.mean(losses))


# ---------------------------------------------------------------------------
# Synchronous engine
# ---------------------------------------------------------------------------

def _draw_batches(rng, data: Dataset, shards, taus_cap: int, batch: int):
    """[W, tau_max, B, *feat] index draws from each worker's shard.
    Shape/dtype follow ``data.x`` ([N, D] f32 classification rows or
    [N, S] i32 token sequences) so registry models ride the same path."""
    n = len(shards)
    bx = np.zeros((n, taus_cap, batch) + data.x.shape[1:], data.x.dtype)
    by = np.zeros((n, taus_cap, batch), np.int32)
    for w, shard in enumerate(shards):
        ix = rng.integers(0, len(shard), (taus_cap, batch))
        sel = shard[ix]
        bx[w] = data.x[sel]
        by[w] = data.y[sel]
    # numpy out: run_dfl feeds these straight into jit (implicit transfer);
    # the fused engine pads and stacks whole segments host-side first
    return bx, by


def run_dfl(data: Dataset, test_x, test_y, shards, cluster: SimCluster,
            cfg: FedHPConfig, strategy: Strategy, *, rounds: int | None = None,
            hidden: int = 64, eval_subset: int = 512,
            mixing: str = "uniform",
            time_budget: float | None = None,
            adapter: modelspec.ModelAdapter | None = None,
            init_params=None, mesh=None) -> History:
    """time_budget: stop once the simulated clock passes it — the paper's
    equal-wall-time comparison (completion time is the metric, Fig. 3).

    ``adapter`` picks the model (default: built from ``cfg.model`` via
    ``modelspec.adapter_for`` — the synthetic MLP unless the config names
    a registry family). ``init_params`` resumes from a [W, ...] stacked
    pytree (e.g. a prior run's ``History.final_params`` reloaded through
    ``checkpoint/store.py``) instead of broadcasting ``adapter.init``.

    ``mesh`` (or ``cfg.sharded``) activates the sharded path: the worker
    dim splits over the mesh's axes (``runtime/shardexec``), local SGD
    and the join blend run per-slice under shard_map, and gossip always
    takes the edge-list form routed cross-shard by ppermute — the
    per-edge weights are bit-identical to the dense off-diagonals, so
    dense-config runs stay within the differential harness tolerances.
    The host control plane (churn, plans, Eq. 10 clock) is untouched:
    host-side record fields match the single-device oracle exactly."""
    rounds = rounds or cfg.rounds
    n = cfg.num_workers
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    if adapter is None:
        adapter = modelspec.adapter_for(cfg, data, hidden=hidden)
    shard = None
    if mesh is not None or getattr(cfg, "sharded", False):
        from repro.runtime import shardexec
        shard = shardexec.WorkerShardPlan(
            mesh if mesh is not None else shardexec.default_worker_mesh(), n)
    if init_params is None:
        p0 = adapter.init(key)
        stacked = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (n,) + l.shape), p0)
    else:
        stacked = jax.tree.map(jnp.asarray, init_params)
    if shard is not None:
        stacked = shard.put_stacked(stacked)

    tx = jnp.asarray(test_x[:eval_subset])
    ty = jnp.asarray(test_y[:eval_subset])
    # fixed per-worker eval batches for the Alg. 1 estimates
    ex = np.stack([data.x[s[rng.integers(0, len(s), 256)]] for s in shards])
    ey = np.stack([data.y[s[rng.integers(0, len(s), 256)]] for s in shards])
    px, py = ex[:, :32], ey[:, :32]
    ex, ey, px, py = map(jnp.asarray, (ex, ey, px, py))

    codec0 = compression.parse_mode(cfg.compress)
    if codec0.kind == "leafmap":
        # bind the per-leaf map to THIS adapter's leaf layout; the
        # strategy re-parses cfg.compress and hands back an uncompiled
        # copy in plan.codec — the round loop substitutes this one
        codec0 = codec0.compile(adapter.leaf_offsets())
    leafmap = codec0.kind == "leafmap"
    compress = codec0.kind != "none"
    # Byzantine scenario axis (core/robust.py): attackers corrupt the
    # wire copy, robust modes replace the weighted mix with a trimmed /
    # median aggregation of the closed neighborhood. Neither composes
    # with compressed gossip (a codec's residual state assumes the mix
    # consumed what was shipped).
    byz = robust_agg.byzantine_mask(cfg.byzantine, n)
    has_byz = bool(byz.any())
    robust_mode, robust_b = robust_agg.parse_robust(cfg.robust)
    if robust_mode == "screen":
        raise ValueError(
            "cfg.robust='screen:<z>' is the AD-PSGD accept/reject rule; "
            "synchronous engines use 'trimmed:<b>' / 'median'")
    robust_active = has_byz or robust_mode != "none"
    if robust_active and compress:
        raise ValueError(
            "cfg.byzantine / cfg.robust do not compose with cfg.compress")
    atk_kind, atk_scale = (robust_agg.parse_attack(cfg.byzantine_attack)
                           if has_byz else ("signflip", 1.0))
    byz_j = jnp.asarray(byz)
    # compressed links pay Eq. 10 comm time / the codec's wire ratio
    # (int8+scales or k sparse values instead of raw f32); the adaptive
    # strategy may tighten a sparse codec's k per round via plan.codec.
    # The residual matrix is the per-worker error-feedback state (zeros
    # when EF is off — the naive compressed mode). Wire math uses the
    # adapter's true P — ``cluster.model_bits`` prices the link (beta),
    # the ratio prices the codec.
    p_model = adapter.param_count
    skey = compression.sparsify_base_key(cfg.seed)  # rand-k mask stream
    # codec state: int8 residual (zeros) or top-k public copy x̂ (the
    # globally known initial params); leafmap states are per-segment
    # slices of the same [W, P] buffer
    if compress:
        f0 = _flatten_workers(stacked)
        err = (compression.leafmap_state_init(f0, codec0,
                                              cfg.error_feedback)
               if leafmap else
               compression.state_init(f0, codec0.kind, cfg.error_feedback))
    else:
        err = None

    hist = History()
    clock = 0.0
    needs_cross = strategy.name == "pens"
    sparse_gossip = cfg.gossip == "sparse"
    if shard is not None:
        if robust_active:
            raise ValueError(
                "the sharded path does not compose with cfg.byzantine / "
                "cfg.robust (data-dependent sorts are single-device-only)")
        if leafmap:
            raise ValueError(
                "the sharded path does not support leafmap codecs yet "
                "(per-leaf payloads need per-segment routing)")
        if needs_cross:
            raise ValueError(
                "pens needs the [W, W] cross-loss matrix every round; "
                "run it on the single-device path")
    # time-varying non-IID drift: a DriftingPartition swaps shard lists
    # on its schedule; static lists pass through untouched. The batch
    # RNG consumption is shape-identical either way, so both engines
    # replay the same stream draw for draw.
    drifting = hasattr(shards, "shards_at")
    for h in range(rounds):
        alive = cluster.advance_round(h)
        joined = cluster.last_joined
        if joined.any():
            donors = alive & ~joined
            if donors.any():
                if shard is not None:
                    stacked = shard.reinit_joined(stacked, joined, donors)
                else:
                    stacked = _reinit_joined(stacked, jnp.asarray(joined),
                                             jnp.asarray(donors))
                if compress:
                    # the blended model owes nothing from the departed
                    # model's last transmission: residual resets to zero,
                    # the top-k public copy to the (deterministic, hence
                    # shared-knowledge) blended row
                    fj = _flatten_workers(stacked)
                    kc = jnp.asarray(joined if shard is None else
                                     shard.pad_host(joined, False))[:, None]
                    err = (compression.leafmap_state_after_join(
                               err, kc, fj, codec0, cfg.error_feedback)
                           if leafmap else
                           compression.state_after_join(
                               err, kc, fj, codec0.kind,
                               cfg.error_feedback))
        mu = cluster.sample_mu()
        beta = cluster.sample_beta()

        plan = strategy.plan(h, alive=alive)
        rcodec = plan.codec if plan.codec is not None else codec0
        if leafmap and rcodec.kind == "leafmap":
            rcodec = codec0           # the compiled copy (see above)
        comm_ratio = rcodec.wire_ratio(p_model) if compress else 1.0
        adj = plan.adj.copy()
        adj[~alive, :] = 0
        adj[:, ~alive] = 0
        # churn safety net: if the strategy's topology lost connectivity to
        # a departure, cheapest-reconnect the survivors (link-time cost).
        # Gate on the strategy's INTENT (plan.adj has links) rather than on
        # surviving links — `adj[alive][:, alive].sum() > 0` skipped repair
        # exactly when the survivors lost every link, silently disabling
        # gossip for the round (LD-SGD local-only rounds, with an all-zero
        # plan, still legitimately skip)
        if not alive.all() and alive.sum() > 1 and plan.adj.sum() > 0:
            adj = topo.repair_connectivity(adj, alive, cost=beta)
        taus = np.where(alive, np.clip(plan.taus, 1, cfg.tau_max), 0)
        lr = cfg.lr * (cfg.lr_decay ** h)

        # --- local updating (Eq. 3), masked to tau_i ---
        tau_cap = int(max(taus.max(), 1))
        bx, by = _draw_batches(rng, data,
                               shards.shards_at(h) if drifting else shards,
                               tau_cap, cfg.batch_size)
        prev = stacked
        if shard is not None:
            stacked = shard.local_train(
                adapter, stacked, shard.pad_host(bx), shard.pad_host(by),
                jnp.asarray(shard.pad_host(taus, 0)), jnp.float32(lr),
                tau_cap)
        else:
            stacked = _local_train(adapter, stacked, bx, by,
                                   jnp.asarray(taus), jnp.float32(lr),
                                   tau_cap)

        # --- clock (Eq. 10-11) ---
        comm = np.where(adj.sum(1) > 0,
                        np.where(adj > 0, beta, 0.0).max(1), 0.0)
        if compress:
            comm = comm / comm_ratio
        t_i = taus * mu + comm
        if plan.extra_time is not None:
            t_i = t_i + plan.extra_time * alive
        t_round = float(t_i[alive].max()) if alive.any() else 0.0
        if cluster.last_crashed.any():
            # abrupt failures: survivors block on the dead peer until the
            # detection timeout fires (crash vs graceful-leave distinction)
            t_round += cfg.crash_timeout
        waiting = float((t_round - t_i[alive]).mean()) if alive.any() else 0.0
        clock += t_round

        # --- gossip aggregation (Eq. 5-6), optionally compressed ---
        if adj.sum() > 0 and shard is not None:
            # sharded gossip always takes the edge-list form: per-edge
            # weights are bit-identical to the dense off-diagonals
            # (topology.edge_mixing_weights), the routing is one ppermute
            # per distinct shard offset (runtime/collectives); padding
            # rows have no edges and contribute exactly nothing
            e = topo.edges_from_adj(adj)
            ew = topo.edge_mixing_weights(e, n, mixing)
            src, dst, ws = topo.directed_edges(e, ew)
            flat = _flatten_workers(stacked)
            if compress:
                mixed, err = shard.gossip_compressed_edges(
                    flat, err, src, dst, ws, skey, jnp.int32(h),
                    jnp.float32(cfg.sparse_gamma), kind=rcodec.kind,
                    k=rcodec.resolve_k(p_model),
                    error_feedback=cfg.error_feedback)
            else:
                mixed = shard.gossip_edges(flat, src, dst, ws)
            stacked = _unflatten(mixed, stacked)
        elif adj.sum() > 0 and robust_active:
            # Byzantine / robust path (core/robust.py): byzantine rows
            # lie on the wire; robust modes aggregate the closed
            # neighborhood coordinate-wise instead of the weighted mix.
            # Dense gathers + sorts; sparse trims via segment-op peeling
            # (median has no segment form and uses the gathered table).
            flat = _flatten_workers(stacked)
            transmitted = (robust_agg.apply_attack(
                flat, byz_j, jnp.float32(atk_scale), kind=atk_kind)
                if has_byz else flat)
            if robust_mode == "trimmed" and sparse_gossip:
                e = topo.edges_from_adj(adj)
                src, dst, _ = topo.directed_edges(
                    e, np.zeros(e.shape[0]))
                cnt = adj.sum(1) + 1
                bi = np.minimum(
                    np.floor(robust_b * cnt) if robust_b < 1
                    else np.full(n, robust_b), (cnt - 1) // 2)
                mixed = robust_agg.trimmed_mean_edges(
                    flat, transmitted, jnp.asarray(src), jnp.asarray(dst),
                    b=robust_b, num_workers=n,
                    b_max=max(int(bi.max()), 0))
            elif robust_mode in ("trimmed", "median"):
                nbr, deg = robust_agg.neighbor_table(adj)
                mixed = robust_agg.robust_gossip_dense(
                    flat, transmitted, jnp.asarray(nbr), jnp.asarray(deg),
                    b=robust_b, mode=robust_mode)
            elif sparse_gossip:
                e = topo.edges_from_adj(adj)
                ew = topo.edge_mixing_weights(e, n, mixing)
                src, dst, ws = map(jnp.asarray, topo.directed_edges(e, ew))
                mixed = robust_agg.gossip_byz_edges(flat, transmitted,
                                                    src, dst, ws)
            else:
                mixfn = (topo.mixing_matrix_metropolis
                         if mixing == "metropolis"
                         else topo.mixing_matrix_uniform)
                mixed = robust_agg.gossip_byz_dense(
                    flat, transmitted, jnp.asarray(mixfn(adj), jnp.float32))
            stacked = _unflatten(mixed, stacked)
        elif adj.sum() > 0:
            if sparse_gossip:
                # edge-list path: per-edge weights from degrees alone
                # (bit-identical to the dense matrices' off-diagonals),
                # mixing via segment_sum — no [W, W] matrix materialized
                e = topo.edges_from_adj(adj)
                ew = topo.edge_mixing_weights(e, n, mixing)
                src, dst, ws = map(jnp.asarray, topo.directed_edges(e, ew))
                flat = _flatten_workers(stacked)
                if leafmap:
                    mixed, err = _gossip_leafmap_edges(
                        flat, err, src, dst, ws, skey, jnp.int32(h),
                        jnp.float32(cfg.sparse_gamma), lcodec=rcodec,
                        error_feedback=cfg.error_feedback)
                elif compress:
                    mixed, err = _gossip_compressed_edges(
                        flat, err, src, dst, ws, skey, jnp.int32(h),
                        jnp.float32(cfg.sparse_gamma),
                        kind=rcodec.kind, k=rcodec.resolve_k(p_model),
                        error_feedback=cfg.error_feedback)
                else:
                    mixed = _gossip_edges(flat, src, dst, ws)
                stacked = _unflatten(mixed, stacked)
            else:
                mixfn = (topo.mixing_matrix_metropolis
                         if mixing == "metropolis"
                         else topo.mixing_matrix_uniform)
                mix = jnp.asarray(mixfn(adj), jnp.float32)
                if leafmap:
                    flat = _flatten_workers(stacked)
                    mixed, err = _gossip_leafmap(
                        flat, err, mix, skey, jnp.int32(h),
                        jnp.float32(cfg.sparse_gamma), lcodec=rcodec,
                        error_feedback=cfg.error_feedback)
                    stacked = _unflatten(mixed, stacked)
                elif compress:
                    flat = _flatten_workers(stacked)
                    mixed, err = _gossip_compressed(
                        flat, err, mix, skey, jnp.int32(h),
                        jnp.float32(cfg.sparse_gamma),
                        kind=rcodec.kind, k=rcodec.resolve_k(p_model),
                        error_feedback=cfg.error_feedback)
                    stacked = _unflatten(mixed, stacked)
                else:
                    stacked = _gossip(stacked, mix)

        # --- measurements (Alg. 1 lines 4-5, 9-10) ---
        # fleet metrics cover the honest alive workers only: byzantine
        # rows are not part of the deployment being measured (their
        # local state is honest but they are adversaries, not clients)
        meas = (alive & ~byz) if has_byz and (alive & ~byz).any() else alive
        losses, accs, ls, sigs, upds = _measure(adapter, stacked, prev, ex,
                                                ey, px, py)
        if shard is not None:
            # padding rows are not part of the fleet: every per-worker
            # vector leaves the device sliced back to the real W
            losses, accs, ls, sigs, upds = (
                v[:n] for v in (losses, accs, ls, sigs, upds))
        flat = np.asarray(_flatten_workers(stacked))[:n]
        pair = pairwise_distances(flat)
        cross = None
        if needs_cross:
            cross = np.asarray(_cross_loss_matrix(adapter, stacked,
                                                  ex[:, :64], ey[:, :64]))
        strategy.observe(
            h, adj=adj, mu=mu, beta=beta, edge_dist=pair,
            update_norms=np.asarray(upds)[meas] if meas.any() else [0.0],
            smooth_l=float(np.median(np.asarray(ls)[meas])),
            sigma=float(np.median(np.asarray(sigs)[meas])),
            loss=float(np.mean(np.asarray(losses)[meas])),
            cross_loss=cross, alive=alive, wire_ratio=comm_ratio)

        mean_acc, mean_loss = _mean_accuracy(
            adapter, stacked, tx, ty,
            meas if shard is None else shard.pad_host(meas, False))
        fa = flat[meas] if meas.any() else flat
        d_bar = float(np.linalg.norm(fa - fa.mean(0), axis=1).mean())
        hist.records.append(RoundRecord(
            round=h, round_time=t_round, waiting_time=waiting,
            accuracy=mean_acc, loss=mean_loss,
            mean_tau=float(taus[alive].mean()) if alive.any() else 0.0,
            num_links=int(adj.sum() // 2), consensus=d_bar,
            cumulative_time=clock))
        if time_budget is not None and clock >= time_budget:
            break
    hist.final_params = stacked if shard is None else shard.unpad(stacked)
    return hist


# ---------------------------------------------------------------------------
# Asynchronous engine (AD-PSGD baseline): event schedule + event loop
# ---------------------------------------------------------------------------

# partner selection / event ordering draws come from a stream derived from
# (seed, _ADPSGD_STREAM) so it is independent of the batch-sampling stream:
# the fused engine can then batch per-seed batch streams over a SHARED
# event schedule (vmapped ``seeds``) without the schedules diverging
_ADPSGD_STREAM = 0xAD


@dataclass(frozen=True)
class AdpsgdEvent:
    """One processed AD-PSGD completion event (AD-PSGD [23], Alg. 1).

    ``worker`` finished tau local steps computed from its snapshot and
    atomically pairwise-averages with ``partner`` at simulated ``time``.
    ``staleness`` counts how many pairwise averages hit the worker's live
    row since its snapshot was taken — the quantity AD-PSGD's convergence
    bound is stated in; ``inflight_bound`` is the number of other
    workers' events processed in that window (staleness can never exceed
    it: each event stales at most one other row)."""

    worker: int
    partner: int
    time: float
    staleness: int
    inflight_bound: int


@dataclass(frozen=True)
class AdpsgdRound:
    """N consecutive events plus the host state their record needs.

    ``keep``/``donor_w`` describe the join re-initialization applied
    BEFORE this round's events (all-False/zero when nobody joined);
    ``alive`` is the membership in force DURING the events; ``clock`` is
    the simulated time of the round's last event (the record's
    ``cumulative_time``); ``lr`` the decayed learning rate in force."""

    events: tuple[AdpsgdEvent, ...]
    lr: float
    alive: np.ndarray
    clock: float
    keep: np.ndarray
    donor_w: np.ndarray

    @property
    def mean_staleness(self) -> float:
        """Mean staleness over the round's events (the record field)."""
        return float(np.mean([e.staleness for e in self.events]))


@dataclass(frozen=True)
class AdpsgdSchedule:
    """The complete host-side control plane of one AD-PSGD run: what the
    event loop would do, minus the device math. Both engines consume it —
    ``run_adpsgd`` event by event, ``run_adpsgd_fused`` as scan inputs —
    which is what makes their host-side records bit-identical."""

    rounds: tuple[AdpsgdRound, ...]
    tau: int
    num_links: int
    num_workers: int

    @property
    def events(self) -> list[AdpsgdEvent]:
        """All processed events, flattened in completion order."""
        return [e for r in self.rounds for e in r.events]


def adpsgd_schedule(cluster: SimCluster, cfg: FedHPConfig, *,
                    rounds: int | None = None,
                    time_budget: float | None = None,
                    p_model: int | None = None) -> AdpsgdSchedule:
    """Precompute the AD-PSGD event schedule (pure host function).

    Replays the event loop's control plane: a heap of per-worker finish
    times ``t + tau mu_i + beta_ij`` (Eq. 10 per event; compressed runs
    charge ``beta / wire_ratio``), random-neighbor partner selection over
    the alive ring, churn applied at round boundaries (every N processed
    events), and per-worker staleness counters. Events of departed
    workers are dropped; joiners are re-admitted with a fresh event.
    Consumes the cluster's RNG exactly once per event (mu, beta draws)
    plus once per join — the same draws the legacy in-line loop made.

    ``p_model`` is the adapter's true parameter count for the codec's
    wire-ratio math (both engines pass it; the ``cluster.model_bits``
    fallback keeps standalone callers working)."""
    rounds = rounds or cfg.rounds
    n = cfg.num_workers
    rng = np.random.default_rng((cfg.seed, _ADPSGD_STREAM))
    ring = topo.ring_topology(n)
    neighbors = [np.nonzero(ring[i])[0] for i in range(n)]
    tau = cfg.tau_init
    codec = compression.parse_mode(cfg.compress)
    if codec.kind == "leafmap":
        raise ValueError(
            "per-leaf codec maps (compress='leafmap:...') are "
            "synchronous-engine only; AD-PSGD's pairwise exchange has no "
            "leafmap form yet")
    rmode, _ = robust_agg.parse_robust(cfg.robust)
    if rmode in ("trimmed", "median"):
        raise ValueError(
            "trimmed/median robust gossip is synchronous-engine only "
            "(a 2-sample pairwise exchange has no trim window); AD-PSGD "
            "takes cfg.robust='screen:<z>'")
    if (rmode == "screen" or cfg.byzantine) and codec.kind != "none":
        raise ValueError(
            "cfg.byzantine / cfg.robust do not compose with cfg.compress")
    comm_ratio = codec.wire_ratio(
        p_model if p_model is not None
        else int(cluster.model_bits // compression.FP32_BITS))

    mu0 = cluster.sample_mu()
    q = [(tau * mu0[i], i) for i in range(n)]
    heapq.heapify(q)
    alive = cluster.advance_round(0)
    lr = cfg.lr
    stale = np.zeros(n, np.int64)     # averages absorbed since snapshot
    last_ev = np.full(n, -1)          # processed-event index of last event
    out: list[AdpsgdRound] = []
    cur: list[AdpsgdEvent] = []
    keep = np.zeros(n, bool)
    donor_w = np.zeros(n)
    events = 0
    clock = 0.0
    while len(out) < rounds and q:
        t_now, i = heapq.heappop(q)
        clock = t_now
        if not alive[i]:
            continue                  # churned out: event dies with it
        cand = [j for j in neighbors[i] if alive[j]]
        if not cand:                  # ring neighbors churned out: any peer
            cand = [j for j in np.nonzero(alive)[0] if j != i]
        j = int(rng.choice(cand)) if cand else int(i)
        bound = int(events - last_ev[i] - 1) if last_ev[i] >= 0 else events
        cur.append(AdpsgdEvent(int(i), j, float(clock), int(stale[i]),
                               bound))
        stale[i] = 0
        if j != i:
            stale[j] += 1             # j's in-flight delta is now staler
        last_ev[i] = events
        mu = cluster.sample_mu()[i]
        beta = cluster.sample_beta()[i, j] / comm_ratio
        heapq.heappush(q, (t_now + tau * mu + beta, i))
        events += 1
        if events % n == 0:
            out.append(AdpsgdRound(tuple(cur), lr, alive.copy(),
                                   float(clock), keep, donor_w))
            lr *= cfg.lr_decay
            cur = []
            keep = np.zeros(n, bool)
            donor_w = np.zeros(n)
            if time_budget is not None and clock >= time_budget:
                break
            # event clock -> round clock: churn for the NEXT round advances
            # after this round's record, matching run_dfl's round-start
            # semantics (a round-r event affects record r in both engines)
            alive = cluster.advance_round(len(out))
            joined = cluster.last_joined
            donors = alive & ~joined
            if joined.any() and donors.any():
                keep = joined.copy()
                donor_w = donors / donors.sum()
                # re-init == fresh snapshot: counters reset AND the
                # in-flight window restarts at the join boundary (else a
                # rejoiner's first bound would span its dead period)
                stale[joined] = 0
                last_ev[joined] = events - 1
                mu_now = cluster.sample_mu()
                for w in np.nonzero(joined)[0]:
                    heapq.heappush(q, (clock + tau * mu_now[w], int(w)))
    return AdpsgdSchedule(tuple(out), tau, int(ring.sum() // 2), n)


@partial(jax.jit, static_argnames=("adapter", "tau"))
def _adpsgd_delta(adapter, params, bx, by, lr, tau: int):
    """tau local SGD steps (Eq. 3) computed from a SNAPSHOT; returns the
    delta. AD-PSGD's defining staleness [23]: while a worker computes,
    its live model may be averaged by neighbors, and the (stale) delta is
    applied to whatever the live row has become. Shared with the fused
    engine — equivalence rests on both running this exact step."""
    def step(p, xs):
        x, y = xs
        g = jax.grad(adapter.loss)(p, {"x": x, "y": y})
        return jax.tree.map(
            lambda w, gg: (w - lr * gg.astype(jnp.float32)).astype(w.dtype),
            p, g), None
    out, _ = jax.lax.scan(step, params, (bx, by))
    return jax.tree.map(lambda a, b: a - b, out, params)


@jax.jit
def _adpsgd_average(stacked, delta, i, j):
    """Atomic AD-PSGD pairwise exchange: apply worker i's stale delta to
    its live row, then set both endpoints to the average (the 2-row
    doubly-stochastic mix W = [[.5, .5], [.5, .5]], Eq. 5 restricted to
    one edge)."""
    pi = jax.tree.map(lambda l, d: l[i] + d, stacked, delta)
    pj = jax.tree.map(lambda l: l[j], stacked)
    avg = jax.tree.map(lambda a, b: 0.5 * (a + b), pi, pj)
    return jax.tree.map(
        lambda l, a: l.at[i].set(a).at[j].set(a), stacked, avg)


@partial(jax.jit, static_argnames=("kind", "k", "error_feedback"))
def _adpsgd_exchange_compressed(stacked, err, delta, i, j, key, step,
                                gamma, *, kind: str, k: int,
                                error_feedback: bool):
    """Compressed AD-PSGD pairwise exchange (ChocoSGD-style, the pairwise
    case of ``compression.compressed_gossip_ref``): both endpoints put
    the codec's payload on the wire (int8 round trip of z = x + e, the
    top-k innovation against the tracked x̂, or the event's shared rand-k
    draw — ``key``/``step`` seed the mask, ``gamma`` damps the top-k
    half-mix) and apply the compensated half-mix; codec state carries per
    worker. Unlike the exact average the two rows do NOT become equal —
    the compression error stays in the state, keeping the fleet sum
    exact."""
    pi = jax.tree.map(lambda l, d: l[i] + d, stacked, delta)
    pj = jax.tree.map(lambda l: l[j], stacked)
    xi, xj = _flatten_row(pi), _flatten_row(pj)
    xi2, xj2, ei2, ej2 = compression.compressed_pair_ref(
        xi, xj, err[i], err[j], error_feedback=error_feedback,
        kind=kind, k=k, key=key, step=step, gamma=gamma)
    err = err.at[i].set(ei2).at[j].set(ej2)
    new_i = _unflatten_row(xi2, pi)
    new_j = _unflatten_row(xj2, pj)
    stacked = jax.tree.map(lambda l, a, b: l.at[i].set(a).at[j].set(b),
                           stacked, new_i, new_j)
    return stacked, err


@partial(jax.jit, static_argnames=("kind", "screen"))
def _adpsgd_exchange_screened(stacked, hist_h, delta, i, j, byz, atk_scale,
                              z, *, kind: str, screen: bool):
    """AD-PSGD pairwise exchange under a lying wire, optionally screened.

    Byzantine endpoints transmit a corrupted copy of their row
    (``core/robust.attack_row``); with ``screen`` on, each endpoint
    z-tests the incoming payload against its own-delta-norm EMA
    (``core/robust.screen_accept``) and keeps its self-model on
    rejection — otherwise the payload is absorbed unconditionally (the
    plain-attacked baseline). Worker i folds its fresh delta norm into
    its history BEFORE testing, so the z-test is live from the very
    first event. Self-events (i == j, all ring neighbors churned out)
    have no wire: no attack, no screening, plain average.

    Attack-free, every accept is a half-mix ``0.5 * (x_i + x_j)`` —
    commutative addition, so both rows and the plain
    ``_adpsgd_average`` trajectory agree bit-for-bit. Returns
    ``(stacked, hist_h, num_rejected)`` with num_rejected in {0, 1, 2}
    (each endpoint screens independently)."""
    pi = jax.tree.map(lambda l, d: l[i] + d, stacked, delta)
    pj = jax.tree.map(lambda l: l[j], stacked)
    xi, xj = _flatten_row(pi), _flatten_row(pj)
    wire = i != j
    ti = robust_agg.attack_row(xi, byz[i] & wire, atk_scale, kind=kind)
    tj = robust_agg.attack_row(xj, byz[j] & wire, atk_scale, kind=kind)
    if screen:
        h_i = robust_agg.screen_fold(hist_h[i], _l2_norm(delta))
        hist_h = hist_h.at[i].set(h_i)
        acc_i = ~wire | robust_agg.screen_accept(xi, tj, h_i, z)
        acc_j = ~wire | robust_agg.screen_accept(xj, ti, hist_h[j], z)
    else:
        acc_i = acc_j = jnp.bool_(True)
    row_i = jnp.where(acc_i, 0.5 * (xi + tj), xi)
    row_j = jnp.where(acc_j, 0.5 * (xj + ti), xj)
    new_i = _unflatten_row(row_i, pi)
    new_j = _unflatten_row(row_j, pj)
    stacked = jax.tree.map(lambda l, a, b: l.at[i].set(a).at[j].set(b),
                           stacked, new_i, new_j)
    nrej = (~acc_i).astype(jnp.int32) + (~acc_j).astype(jnp.int32)
    return stacked, hist_h, nrej


def _l2_norm(tree):
    """L2 norm of a pytree, taken over its f32 flattening (the norm the
    screening history tracks)."""
    return jnp.linalg.norm(_flatten_row(tree))


def run_adpsgd(data: Dataset, test_x, test_y, shards, cluster: SimCluster,
               cfg: FedHPConfig, *, rounds: int | None = None,
               hidden: int = 64, eval_subset: int = 512,
               time_budget: float | None = None,
               schedule: AdpsgdSchedule | None = None,
               adapter: modelspec.ModelAdapter | None = None) -> History:
    """Event-driven AD-PSGD [23]: random pairwise averaging on completion.

    One "round" = N worker-finish events (≈ one synchronous round of
    work), at which point metrics are sampled — comparable x-axes with
    ``run_dfl``. The control plane comes from ``adpsgd_schedule`` (pass
    an explicit ``schedule`` to replay a custom event sequence verbatim
    — ``rounds``/``time_budget`` are generation-time knobs); this
    loop runs the device math one jit dispatch per event — the semantic
    ground truth ``fused.run_adpsgd_fused`` is differentially tested
    against. ``cfg.compress`` ("int8" / "topk:<k>" / "randk:<k>")
    switches the pairwise exchange to the codec's compensated update and
    charges Eq. 10 event comm time divided by the codec's wire ratio.
    ``cfg.byzantine`` workers lie on the pairwise wire;
    ``cfg.robust="screen:<z>"`` turns on per-event accept/reject
    screening of incoming payloads (``core/robust.py``), with rejected
    counts in ``History.screen_rejects`` — screening never touches the
    schedule, so staleness/clock columns match the plain run exactly."""
    rounds = rounds or cfg.rounds
    n = cfg.num_workers
    byz = robust_agg.byzantine_mask(cfg.byzantine, n)
    has_byz = bool(byz.any())
    robust_mode, screen_z = robust_agg.parse_robust(cfg.robust)
    if robust_mode in ("trimmed", "median"):
        raise ValueError(
            "trimmed/median robust gossip is synchronous-engine only "
            "(a 2-sample pairwise exchange has no trim window); AD-PSGD "
            "takes cfg.robust='screen:<z>'")
    screen = robust_mode == "screen"
    atk_kind, atk_scale = (robust_agg.parse_attack(cfg.byzantine_attack)
                           if has_byz else ("signflip", 1.0))
    codec = compression.parse_mode(cfg.compress)
    if codec.kind == "leafmap":
        raise ValueError(
            "per-leaf codec maps (compress='leafmap:...') are "
            "synchronous-engine only; AD-PSGD's pairwise exchange has no "
            "leafmap form yet")
    compress = codec.kind != "none"
    if (has_byz or screen) and compress:
        raise ValueError(
            "cfg.byzantine / cfg.robust do not compose with cfg.compress")
    if adapter is None:
        adapter = modelspec.adapter_for(cfg, data, hidden=hidden)
    if schedule is None:
        schedule = adpsgd_schedule(cluster, cfg, rounds=rounds,
                                   time_budget=time_budget,
                                   p_model=adapter.param_count)
    elif time_budget is not None:
        raise ValueError(
            "time_budget only applies while GENERATING a schedule; an "
            "explicit schedule= replays verbatim (apply the budget in "
            "adpsgd_schedule instead)")
    rng = np.random.default_rng(cfg.seed)       # batch-sampling stream
    key = jax.random.PRNGKey(cfg.seed)
    p0 = adapter.init(key)
    stacked = jax.tree.map(lambda l: jnp.broadcast_to(l, (n,) + l.shape), p0)
    tx = jnp.asarray(test_x[:eval_subset])
    ty = jnp.asarray(test_y[:eval_subset])
    tau = schedule.tau
    err = (compression.state_init(_flatten_workers(stacked), codec.kind,
                                  cfg.error_feedback)
           if compress else None)
    k_abs = codec.resolve_k(adapter.param_count)
    skey = compression.sparsify_base_key(cfg.seed)  # rand-k mask stream
    ev_idx = 0          # global event counter: the rand-k mask step

    # per-worker snapshot taken when its computation started
    snapshots = [jax.tree.map(lambda l, i=i: l[i], stacked)
                 for i in range(n)]
    byz_j = jnp.asarray(byz)
    screened = screen or has_byz        # lying-wire exchange path
    hist_h = jnp.zeros(n, jnp.float32)  # own-delta-norm EMA per worker
    hist = History()
    if screen:
        hist.screen_rejects = []
    drifting = hasattr(shards, "shards_at")
    for rnd_idx, rnd in enumerate(schedule.rounds):
        round_shards = shards.shards_at(rnd_idx) if drifting else shards
        if rnd.keep.any():
            stacked = _blend_joined(stacked, jnp.asarray(rnd.keep),
                                    jnp.asarray(rnd.donor_w, jnp.float32))
            if compress:
                err = compression.state_after_join(
                    err, jnp.asarray(rnd.keep)[:, None],
                    _flatten_workers(stacked), codec.kind,
                    cfg.error_feedback)
            for w in np.nonzero(rnd.keep)[0]:
                snapshots[w] = jax.tree.map(lambda l, w=w: l[w], stacked)
            # re-init == fresh history: a joiner's screening EMA restarts
            # with its first post-join delta (mirrors the schedule's
            # staleness reset at the same boundary)
            hist_h = jnp.where(jnp.asarray(rnd.keep), 0.0, hist_h)
        rnd_rejects = 0
        for ev in rnd.events:
            i, j = ev.worker, ev.partner
            shard = round_shards[i]
            ix = rng.integers(0, len(shard), (tau, cfg.batch_size))
            bx = jnp.asarray(data.x[shard[ix]])
            by = jnp.asarray(data.y[shard[ix]])
            delta = _adpsgd_delta(adapter, snapshots[i], bx, by,
                                  jnp.float32(rnd.lr), tau)
            if compress:
                stacked, err = _adpsgd_exchange_compressed(
                    stacked, err, delta, jnp.int32(i), jnp.int32(j),
                    skey, jnp.int32(ev_idx),
                    jnp.float32(cfg.sparse_gamma), kind=codec.kind,
                    k=k_abs, error_feedback=cfg.error_feedback)
            elif screened:
                stacked, hist_h, nrej = _adpsgd_exchange_screened(
                    stacked, hist_h, delta, jnp.int32(i), jnp.int32(j),
                    byz_j, jnp.float32(atk_scale), jnp.float32(screen_z),
                    kind=atk_kind, screen=screen)
                rnd_rejects += int(nrej)
            else:
                stacked = _adpsgd_average(stacked, delta, jnp.int32(i),
                                          jnp.int32(j))
            ev_idx += 1
            snapshots[i] = jax.tree.map(lambda l: l[i], stacked)
        alive = rnd.alive
        # attackers lie on the wire but train honestly; still, the paper
        # metrics describe the HONEST fleet, so measurements mask them
        # out exactly like the synchronous engines do
        meas = (alive & ~byz) if has_byz and (alive & ~byz).any() else alive
        mean_acc, mean_loss = _mean_accuracy(adapter, stacked, tx, ty, meas)
        flat = np.asarray(_flatten_workers(stacked))
        fa = flat[meas] if meas.any() else flat
        d_bar = float(np.linalg.norm(fa - fa.mean(0), axis=1).mean())
        hist.records.append(RoundRecord(
            round=len(hist.records), round_time=0.0,
            waiting_time=0.0,          # async: no synchronization barrier
            accuracy=mean_acc, loss=mean_loss, mean_tau=float(tau),
            num_links=schedule.num_links, consensus=d_bar,
            cumulative_time=rnd.clock, staleness=rnd.mean_staleness))
        if screen:
            hist.screen_rejects.append(rnd_rejects)
    hist.final_params = stacked
    return hist
