"""Zamba2-7B — hybrid: Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; unverified]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,             # mamba2 blocks
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,                # shared-attention-block FFN
    vocab_size=32000,
    ssm_state=64,
    ssm_every=6,               # shared attn block invoked every 6 mamba blocks
    act="silu",
    worker_axes=("pod", "data"),
    tp_axes=("model",),
    notes="long_500k RUNS: Mamba2 constant-size state decode (sub-quadratic).",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, ssm_state=16, ssm_every=2, dtype="float32")
