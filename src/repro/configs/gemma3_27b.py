"""Gemma3-27B — dense GQA, 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    head_dim=128,
    sliding_window=1024,
    global_every=6,            # 5 local : 1 global
    rope_theta=1_000_000.0,
    act="gelu",
    worker_axes=("pod", "data"),
    tp_axes=("model",),
    notes="long_500k RUNS: sliding-window majority; 1-in-6 global layers keep "
          "a seq-sharded 500k cache (DESIGN.md §4).",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16, sliding_window=32,
        global_every=3, dtype="float32")
