"""OLMoE-1B-7B — MoE, 64 experts top-8. [arXiv:2409.02060; hf]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,                 # per-expert hidden dim
    vocab_size=50304,
    num_experts=64,
    experts_per_token=8,
    rope_theta=10_000.0,
    act="silu",
    worker_axes=("pod", "data"),
    tp_axes=("model",),        # EP over model axis: 64e/16 = 4 per chip
    skip_shapes=("long_500k",),
    notes="long_500k skipped: pure full attention.",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=64, vocab_size=256, num_experts=8, experts_per_token=2,
        dtype="float32")
