"""Kimi K2 — trillion-param MoE, 384 experts top-8. [arXiv:2501.kimi2; unverified]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,                 # per-expert hidden dim
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
    num_shared_experts=1,
    rope_theta=50_000.0,
    act="silu",
    worker_axes=("pod",),      # ~1T params: one DFL worker per pod
    fsdp_axes=("data",),
    tp_axes=("model",),        # EP over model axis: 384e / 16 = 24/chip col
    skip_shapes=("long_500k",),
    notes="worker=pod; experts sharded over (data,model)=256 chips. DSGD is "
          "stateless => params-only state (2TB bf16) fits a 4TB pod. "
          "long_500k skipped: pure full attention.",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=64, vocab_size=256, num_experts=8, experts_per_token=2,
        num_shared_experts=1, dtype="float32",
        worker_axes=("pod", "data"), fsdp_axes=())
