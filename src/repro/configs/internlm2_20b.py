"""InternLM2-20B — dense GQA transformer. [arXiv:2403.17297; hf]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    rope_theta=1_000_000.0,
    act="silu",
    worker_axes=("pod", "data"),
    tp_axes=("model",),
    skip_shapes=("long_500k",),
    notes="GQA kv=8. long_500k skipped: pure full attention (DESIGN.md §4).",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, dtype="float32")
