"""xLSTM-1.3B — sLSTM + mLSTM blocks (d_ff=0: projection inside blocks).
[arXiv:2405.04517; unverified]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="xlstm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                    # no FFN: mLSTM blocks carry their own up-proj
    vocab_size=50304,
    slstm_every=7,             # xLSTM[7:1]: 1 sLSTM block per 7 blocks
    act="silu",
    worker_axes=("pod", "data"),
    tp_axes=("model",),
    notes="long_500k RUNS: recurrent matrix-memory state decode "
          "(sub-quadratic).",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=2, num_kv_heads=2,
        vocab_size=256, slstm_every=2, dtype="float32")
