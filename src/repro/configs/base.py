"""Config system: model architecture configs + input-shape table.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (the exact published config) and ``smoke_config()`` (a reduced
same-family config for CPU smoke tests). The registry in ``__init__`` maps
``--arch <id>`` strings to these modules.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any


# ---------------------------------------------------------------------------
# Input shapes (assigned; identical set for every LM-family arch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    """Architecture description shared by the whole model zoo.

    ``family`` selects the model implementation in ``repro.models.registry``:
      dense | moe | encdec | hybrid | xlstm | vlm
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0               # expert hidden dim (if != d_ff)
    num_shared_experts: int = 0
    # --- attention variants ---
    sliding_window: int = 0         # 0 -> full attention
    global_every: int = 0           # gemma3: 1 global layer every N (0 -> all global)
    rope_theta: float = 10_000.0
    mrope: bool = False             # qwen2-vl multimodal RoPE
    # --- activation ---
    act: str = "silu"               # silu | gelu | relu2 (squared relu)
    # --- SSM / recurrent ---
    ssm_state: int = 0              # mamba2 state dim
    ssm_every: int = 0              # hybrid: attn block every N mamba blocks
    slstm_every: int = 0            # xlstm: sLSTM block every N mLSTM blocks
    # --- enc-dec ---
    encoder_layers: int = 0
    decoder_layers: int = 0
    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # --- distribution (per-arch defaults; see DESIGN.md §4) ---
    worker_axes: tuple[str, ...] = ("pod", "data")   # mesh axes enumerating DFL workers
    fsdp_axes: tuple[str, ...] = ()                   # axes for FSDP param sharding within worker
    tp_axes: tuple[str, ...] = ("model",)             # tensor-parallel axes within worker
    within_worker: str = "tp"       # tp | dp: small archs whose head counts
    # don't divide the 16-way model axis replicate params within the worker
    # and split the worker's batch over it instead (DESIGN.md §4)
    # --- perf knobs (§Perf hillclimb; defaults = paper-faithful baseline) ---
    serve_seq_shard: bool = False   # sequence parallelism over "model" in
    # serving for within_worker="dp" archs (dedups 16x replicated compute)
    moe_shard_groups: int = 0       # shard-local MoE dispatch: route within
    # G token groups so the pack/unpack never gathers the global batch
    use_flash_kernel: bool = False  # Pallas flash attention for the
    # full-sequence paths (TPU target; interpret mode on CPU)
    remat: str = "block"            # none | block | full
    skip_shapes: tuple[str, ...] = ()                 # documented skips (DESIGN.md)
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * self.num_heads + 2 * d * hd * self.num_kv_heads \
            + hd * self.num_heads * d
        if self.family == "xlstm":
            # mLSTM blocks: qkv + gates + out + up/down proj factor ~ 8 d^2
            blocks = L * 8 * d * d
            return emb + blocks
        if self.num_experts:
            ff_exp = self.num_experts * 3 * d * (self.moe_d_ff or self.d_ff)
            router = d * self.num_experts
            shared = self.num_shared_experts * 3 * d * (self.moe_d_ff or self.d_ff)
            blocks = L * (attn + ff_exp + router + shared + 2 * d)
        else:
            n_ff = 3 if self.act in ("silu", "gelu") else 2  # gated vs plain
            blocks = L * (attn + n_ff * d * self.d_ff + 2 * d)
        if self.family == "hybrid":
            # mamba2 blocks: in_proj(2*d_in) + conv + dt/B/C + out_proj
            d_in = 2 * d
            blocks = L * (2 * d * d_in + d_in * (self.ssm_state * 2 + 4) + d_in * d)
            # plus shared attention block(s)
            blocks += 2 * (attn + 3 * d * self.d_ff)
        if self.family == "encdec":
            # encoder + decoder with cross attention
            enc = self.encoder_layers * (attn + 2 * d * self.d_ff + 2 * d)
            dec = self.decoder_layers * (2 * attn + 2 * d * self.d_ff + 3 * d)
            blocks = enc + dec
        return emb + blocks

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.num_experts:
            return self.param_count()
        dense_like = dataclasses.replace(
            self, num_experts=0, experts_per_token=0, num_shared_experts=0)
        d_ffe = self.moe_d_ff or self.d_ff
        act_ff = self.num_layers * (
            (self.experts_per_token + self.num_shared_experts) * 3 * self.d_model * d_ffe
            + self.d_model * self.num_experts)
        # dense_like.param_count() includes a dense FFN of d_ff; remove it
        base = dense_like.param_count() - self.num_layers * 3 * self.d_model * self.d_ff
        return base + act_ff

    def shape_list(self) -> list[InputShape]:
        return [s for k, s in SHAPES.items() if k not in self.skip_shapes]


# ---------------------------------------------------------------------------
# FedHP / training run config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FedHPConfig:
    """Controls the paper's technique (Alg. 1-3)."""

    num_workers: int = 30
    rounds: int = 200
    tau_max: int = 50                # cap on local updating frequency
    tau_init: int = 10
    lr: float = 0.1
    lr_decay: float = 0.98
    batch_size: int = 32
    beta1: float = 0.5               # EMA for consensus-distance estimates (Eq. 39)
    beta2: float = 0.1               # EMA for D_max threshold (Eq. 43)
    epsilon: float = 1.0             # waiting-time budget (Eq. 12)
    base_topology: str = "full"      # full | ring | erdos:<p>
    algorithm: str = "fedhp"         # fedhp | dpsgd | adpsgd | ldsgd | pens
    seed: int = 0
    # what each worker trains (core/modelspec.py): "mlp" is the paper's
    # synthetic classifier; "<family>[:key=val,...]" (dense / moe /
    # hybrid / xlstm) trains a tiny registry LM from models/registry.py
    # on the Markov token corpus — e.g. "dense:layers=2,d=32". The
    # engines build the matching ModelAdapter via modelspec.adapter_for.
    model: str = "mlp"
    # fused engine (core/fused.py): adaptive strategies replan every this
    # many rounds; 1 == reference behavior (replan each round), larger
    # segments freeze (A^h, tau^h) between replans for throughput.
    # Static-plan strategies always fuse the whole horizon.
    replan_every: int = 1
    # compressed gossip (core/compression.py): "none" sends raw f32 params,
    # "int8" sends per-tile-scaled int8 round trips (ChocoSGD-style,
    # ~3.5-4x fewer wire bits), "topk:<k>" / "randk:<k>" send k-coordinate
    # sparsified payloads (k a fraction of P when < 1, an absolute count
    # otherwise; top-k ships value+index pairs, rand-k values + a shared
    # mask seed). Eq. 10 charges comm time / the codec's wire ratio.
    compress: str = "none"    # "none" | "int8" | "topk:<k>" | "randk:<k>"
    # gossip representation: "dense" mixes through the [W, W] matrix
    # (O(W^2 P) per round — fine to ~hundreds of workers), "sparse"
    # mixes over the round topology's edge list (O(E P):
    # jax.ops.segment_sum in the reference engine, the
    # kernels/gossip_edges.py gather-mix-scatter kernel in the fused
    # engine). Same host-side control plane either way; device
    # trajectories agree to summation-order float drift (<= 1e-5).
    gossip: str = "dense"     # "dense" | "sparse"
    # sharded execution (runtime/shardexec.py): split the flat [W, P]
    # worker matrix row-wise over the worker axis of a device mesh
    # (launch/mesh.make_worker_mesh by default, or run_dfl(mesh=...)).
    # Local SGD and the join blend run per-slice under shard_map; gossip
    # always takes the edge-list form, routed cross-shard by one
    # lax.ppermute per distinct shard offset. Host control plane (and so
    # every host-side record field) is identical to the single-device
    # path; device trajectories agree to summation-order float drift.
    # Excludes: pens, cfg.byzantine/robust, leafmap codecs, AD-PSGD,
    # batched fused seeds.
    sharded: bool = False
    # error feedback: carry the per-worker compression residual into the
    # next round's payload (keeps compressed mixing unbiased); False ==
    # naive compressed mixing (stalls at the int8 step floor / freezes
    # never-shipped top-k coordinates — test only)
    error_feedback: bool = True
    # compression-aware planner (FedHP): solve tau* / topology (Alg. 3)
    # against the learned effective link times beta / wire_ratio instead
    # of the raw beta — the planner then trades the cheaper wire against
    # the consensus budget like the engines actually pay it (docs/
    # PLANNER.md). False reproduces the compression-blind PR 3/4 planner.
    planner_wire_aware: bool = True
    # replan-cadence sparsity feedback (FedHP + sparse codecs only):
    # halve the codec's k whenever the tracked consensus distance has
    # halved since the last tightening (controller.SparsityScheduler),
    # never below sparse_k_floor * the initial k
    tighten_k: bool = False
    sparse_k_floor: float = 0.125
    # consensus step size for x̂-tracked top-k gossip (ChocoSGD gamma):
    # innovations mix damped, x' = x + gamma (W x̂ - x̂) — stable well
    # below ~0.3 for keep fractions >= 0.05 (rand-k / int8 ignore it)
    sparse_gamma: float = 0.25
    # LD-SGD alternation (baseline)
    ldsgd_i1: int = 4
    ldsgd_i2: int = 1
    # PENS neighbor selection (baseline)
    pens_top_m: int = 3
    pens_sample: int = 6
    # dynamic membership (ChurnSchedule; 0.0 disables churn)
    churn_rate: float = 0.0          # fraction of the fleet that departs
    churn_seed: int = 101            # schedule generator seed
    churn_min_alive: int = 2         # never drop below this many workers
    crash_timeout: float = 2.0       # failure-detection timeout (s) charged
    # to the round when a worker crashes (graceful leaves cost nothing)
    straggle_factor: float = 4.0     # mu multiplier during a straggler spike
    straggle_duration: int = 5       # spike length in rounds
    # Byzantine scenario axis (core/robust.py): workers in ``byzantine``
    # gossip corrupted rows — their LOCAL training is honest, only the
    # transmitted copy lies on the wire (``byzantine_attack``:
    # "signflip[:scale]" sends -scale*x, "largenorm[:scale]" sends
    # scale*x). ``robust`` picks the aggregation countermeasure:
    # "trimmed:<b>" drops the b largest + b smallest values per
    # coordinate before averaging the closed neighborhood (b a fraction
    # of the neighborhood when < 1, an absolute count otherwise),
    # "median" takes the coordinate-wise median — both replace the
    # weighted Eq. 5 mix with an unweighted robust average, run in the
    # reference engine AND the fused scan (kernels/robust_gossip.py),
    # and are synchronous-only. AD-PSGD instead takes "screen:<z>":
    # per-event accept/reject of the incoming pairwise payload against
    # z times the EMA of the receiver's own delta norms (reject keeps
    # the self-model; counts land in History.screen_rejects). No robust
    # or byzantine axis composes with cfg.compress or cfg.sharded.
    byzantine: tuple[int, ...] = ()  # worker ids that attack the wire
    byzantine_attack: str = "signflip"
    robust: str = "none"  # "none" | "trimmed:<b>" | "median" | "screen:<z>"
    # time-varying non-IID drift (data/partition.DriftingPartition):
    # every drift_every rounds the p-skew class -> worker-group pinning
    # rotates one worker over the fleet, so each worker's local label
    # distribution slowly cycles while the global distribution stays
    # fixed. 0 disables drift (the paper's static partition).
    drift_every: int = 0


@dataclass(frozen=True)
class RunConfig:
    arch: str = "smollm-360m"
    shape: str = "train_4k"
    multi_pod: bool = False
    steps: int = 100
    log_every: int = 10
    checkpoint_dir: str = ""
    checkpoint_every: int = 50
    fedhp: FedHPConfig = field(default_factory=FedHPConfig)
    extra: dict[str, Any] = field(default_factory=dict)
