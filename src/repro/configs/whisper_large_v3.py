"""Whisper-large-v3 backbone — enc-dec transformer; conv frontend STUBBED
(input_specs supplies precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,             # 32 enc + 32 dec
    encoder_layers=32,
    decoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    act="gelu",
    worker_axes=("pod", "data"),
    tp_axes=("model",),
    within_worker="dp",
    skip_shapes=("long_500k",),
    notes="Enc-dec: seq_len = encoder frames; decoder length = seq_len//8. "
          "decode_* uses self-cache seq//8 + cross-attn over seq frames. "
          "long_500k skipped: pure full attention. Conv frontend is a stub.",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, encoder_layers=2, decoder_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
        dtype="float32")
