"""Qwen2-VL-2B backbone — M-RoPE, dynamic resolution; vision frontend STUBBED
(input_specs supplies precomputed patch embeddings). [arXiv:2409.12191; hf]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    rope_theta=1_000_000.0,
    mrope=True,
    act="silu",
    tie_embeddings=True,
    worker_axes=("pod", "data"),
    tp_axes=("model",),
    within_worker="dp",
    skip_shapes=("long_500k",),
    notes="M-RoPE (temporal/h/w section rotary). Vision patch embeds are a "
          "stub input. long_500k skipped: pure full attention.",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, dtype="float32")
