"""SmolLM-360M — llama-arch small dense GQA. [hf:HuggingFaceTB/SmolLM-135M; hf]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    rope_theta=10_000.0,
    act="silu",
    tie_embeddings=True,
    worker_axes=("pod", "data"),
    tp_axes=("model",),
    within_worker="dp",
    skip_shapes=("long_500k",),
    notes="long_500k skipped: pure full attention. head_dim=64; 15 heads "
          "pad to 16 for TP=16 (one padded head).",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, dtype="float32")
