"""Nemotron-4-340B — dense GQA, squared-ReLU MLP. [arXiv:2402.16819; unverified]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    rope_theta=10_000.0,
    act="relu2",               # squared ReLU, non-gated MLP
    worker_axes=("pod",),      # 341B params: one DFL worker per pod
    fsdp_axes=("data",),
    tp_axes=("model",),
    skip_shapes=("long_500k",),
    notes="341B: worker=pod, FSDP(data)xTP(model). long_500k skipped: pure "
          "full attention.",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=256, dtype="float32",
        worker_axes=("pod", "data"), fsdp_axes=())
