"""Architecture registry: ``--arch <id>`` -> (CONFIG, smoke_config)."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    SHAPES,
    FedHPConfig,
    InputShape,
    ModelConfig,
    RunConfig,
)

_ARCH_MODULES: dict[str, str] = {
    "internlm2-20b": "repro.configs.internlm2_20b",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "smollm-360m": "repro.configs.smollm_360m",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
}

ARCH_IDS: tuple[str, ...] = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).smoke_config()


def arch_shape_cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; skips filtered unless requested."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for name, shape in SHAPES.items():
            if not include_skipped and name in cfg.skip_shapes:
                continue
            cells.append((arch, name, shape))
    return cells
