"""DFL-aware checkpointing: sharded npz, atomic writes, elastic restore."""
from repro.checkpoint.store import (  # noqa: F401
    CheckpointManager,
    load_checkpoint,
    save_checkpoint,
)
