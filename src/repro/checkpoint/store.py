"""Checkpoint store.

Design (DESIGN.md §6):
- Atomic: write to <dir>/tmp.<step>, fsync, rename to <dir>/step_<n>.
- Sharded: each pytree leaf is one npz entry keyed by its tree path; a
  worker-replicated DFL state ([W, ...] leading dim) stores per-worker
  slices so restore can re-shard onto a different worker count.
- Elastic restore N -> N': worker replicas are re-seeded by cyclic
  assignment of survivor replicas (any DFL worker's model is a valid
  model; gossip re-mixes them within a few rounds).
- Dtype fidelity: npz only understands native numpy dtypes, so
  accelerator dtypes (bfloat16, float8_*, ... from ml_dtypes) are stored
  as same-width uint views with the true dtype names recorded in
  meta.json (`_leaf_dtypes`); load restores the view, so nested model
  pytrees round-trip bit-exactly with dtype AND shape preserved.
- Retention: keep the most recent `keep` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _is_native(dtype: np.dtype) -> bool:
    """True when npz can store this dtype as-is (bool/int/uint/float/
    complex); ml_dtypes extension types (bfloat16, float8_*) report
    kind 'V' and need the uint-view detour."""
    return np.dtype(dtype).kind in "biufc"


def _resolve_dtype(name: str) -> np.dtype:
    """Dtype from its recorded name, reaching into ml_dtypes for the
    accelerator types plain numpy does not know."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _unflatten_into(template, flat: dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        tshape = getattr(leaf, "shape", None)
        if tshape is not None:
            # elastic restore re-shards the leading (worker) dim, so
            # only the per-replica trailing shape must agree
            if (len(arr.shape) != len(tshape)
                    or tuple(arr.shape[1:]) != tuple(tshape[1:])):
                raise ValueError(
                    f"checkpoint leaf {key!r} has shape {arr.shape}, "
                    f"template expects {tuple(tshape)} "
                    "(trailing dims must match)")
        tdtype = getattr(leaf, "dtype", None)
        if tdtype is not None and np.dtype(tdtype) != arr.dtype:
            raise ValueError(
                f"checkpoint leaf {key!r} has dtype {arr.dtype}, "
                f"template expects {np.dtype(tdtype)}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(directory: str, step: int, state, *,
                    meta: dict | None = None) -> str:
    """Atomically write `state` (any pytree) at `step`. Returns final path."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    dtypes = {k: v.dtype.name for k, v in flat.items()}
    payload = {k: (v if _is_native(v.dtype)
                   else v.view(np.dtype(f"uint{8 * v.dtype.itemsize}")))
               for k, v in flat.items()}
    np.savez(os.path.join(tmp, "state.npz"), **payload)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "_leaf_dtypes": dtypes,
                   **(meta or {})}, f)
    with open(os.path.join(tmp, "meta.json")) as f:
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_checkpoint(directory: str, template, step: int | None = None):
    """Load newest (or given-step) checkpoint into `template`'s structure.

    Returns (state, meta)."""
    steps = list_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "state.npz")) as z:
        flat = {k: z[k] for k in z.files}
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    for key, name in meta.pop("_leaf_dtypes", {}).items():
        if key in flat and flat[key].dtype.name != name:
            flat[key] = flat[key].view(_resolve_dtype(name))
    return _unflatten_into(template, flat), meta


def list_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            out.append(int(name.split("_", 1)[1]))
    return sorted(out)


def elastic_reshard(worker_stacked, new_num_workers: int):
    """Re-seed a [W, ...] worker-replica stack onto W' workers.

    Survivor replicas are assigned cyclically; with W' <= W this is a
    truncation, with W' > W new workers start from existing replicas
    (valid under DFL semantics: any worker's model is a model)."""
    def reshard(leaf):
        w = leaf.shape[0]
        idx = np.arange(new_num_workers) % w
        return leaf[idx]
    return jax.tree.map(reshard, worker_stacked)


class CheckpointManager:
    """Retention + convenience wrapper used by the train drivers."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep

    def save(self, step: int, state, meta: dict | None = None) -> str:
        path = save_checkpoint(self.directory, step, state, meta=meta)
        steps = list_steps(self.directory)
        for old in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory,
                                       f"step_{old:08d}"),
                          ignore_errors=True)
        return path

    def restore(self, template, step: int | None = None):
        return load_checkpoint(self.directory, template, step)

    def latest_step(self) -> int | None:
        steps = list_steps(self.directory)
        return steps[-1] if steps else None
