"""Fused gossip aggregation kernel (paper Eq. 5): the DFL mixing hot-spot.

    y = x + sum_k w_k * (u_k - x)

over K stacked neighbor buffers. Unfused this is K+1 HBM round trips of
the full parameter vector; fused it is ONE read of x, one streamed read
of each u_k block, one write — memory-bound, so the fusion is the whole
win. Blocks are (8, 1024) f32 tiles (VPU-aligned: 8 sublanes x 128 lanes
x 8). Inputs whose shape is not a tile multiple are zero-padded to the
block grid internally and the output sliced back, so real model sizes
(P any value, not just multiples of 8192) go through the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 8
BLOCK_COLS = 1024


def _gossip_kernel(w_ref, x_ref, u_ref, o_ref, *, num_neighbors: int):
    x = x_ref[...].astype(jnp.float32)                    # [R, C]
    acc = x
    for kidx in range(num_neighbors):                     # K is small/static
        w = w_ref[kidx, 0]
        acc = acc + w * (u_ref[kidx].astype(jnp.float32) - x)
    o_ref[...] = acc.astype(o_ref.dtype)


def pad_to_blocks(r: int, c: int, block_rows: int = BLOCK_ROWS,
                  block_cols: int = BLOCK_COLS) -> tuple[int, int, int, int]:
    """Block shape + padded extent for an [R, C] operand: blocks never
    exceed the array, and the array is padded up to a whole block grid.
    Callers with their own tile constants pass them explicitly."""
    br, bc = min(block_rows, r), min(block_cols, c)
    rp = -(-r // br) * br
    cp = -(-c // bc) * bc
    return br, bc, rp, cp


def gossip_mix_2d(x, u, w, *, interpret: bool = False):
    """x: [R, C]; u: [K, R, C] neighbor buffers; w: [K] f32 weights.

    R and C need not be tile multiples: the padding shim zero-extends to
    the block grid and slices the result back (padding rows mix to zero,
    which is discarded)."""
    r, c = x.shape
    k = u.shape[0]
    br, bc, rp, cp = pad_to_blocks(r, c)
    if (rp, cp) != (r, c):
        x = jnp.pad(x, ((0, rp - r), (0, cp - c)))
        u = jnp.pad(u, ((0, 0), (0, rp - r), (0, cp - c)))
    kernel = functools.partial(_gossip_kernel, num_neighbors=k)
    out = pl.pallas_call(
        kernel,
        grid=(rp // br, cp // bc),
        in_specs=[
            pl.BlockSpec((k, 1), lambda i, j: (0, 0)),     # weights: whole
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((k, br, bc), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, cp), x.dtype),
        interpret=interpret,
    )(w.reshape(k, 1).astype(jnp.float32), x, u)
    if (rp, cp) != (r, c):
        out = out[:r, :c]
    return out
