"""Fused gossip aggregation kernel (paper Eq. 5): the DFL mixing hot-spot.

    y = x + sum_k w_k * (u_k - x)

over K stacked neighbor buffers. Unfused this is K+1 HBM round trips of
the full parameter vector; fused it is ONE read of x, one streamed read
of each u_k block, one write — memory-bound, so the fusion is the whole
win. Blocks are (8, 1024) f32 tiles (VPU-aligned: 8 sublanes x 128 lanes
x 8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 8
BLOCK_COLS = 1024


def _gossip_kernel(w_ref, x_ref, u_ref, o_ref, *, num_neighbors: int):
    x = x_ref[...].astype(jnp.float32)                    # [R, C]
    acc = x
    for kidx in range(num_neighbors):                     # K is small/static
        w = w_ref[kidx, 0]
        acc = acc + w * (u_ref[kidx].astype(jnp.float32) - x)
    o_ref[...] = acc.astype(o_ref.dtype)


def gossip_mix_2d(x, u, w, *, interpret: bool = False):
    """x: [R, C]; u: [K, R, C] neighbor buffers; w: [K] f32 weights."""
    r, c = x.shape
    k = u.shape[0]
    br, bc = min(BLOCK_ROWS, r), min(BLOCK_COLS, c)
    assert r % br == 0 and c % bc == 0, (r, c, br, bc)
    kernel = functools.partial(_gossip_kernel, num_neighbors=k)
    return pl.pallas_call(
        kernel,
        grid=(r // br, c // bc),
        in_specs=[
            pl.BlockSpec((k, 1), lambda i, j: (0, 0)),     # weights: whole
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((k, br, bc), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), x.dtype),
        interpret=interpret,
    )(w.reshape(k, 1).astype(jnp.float32), x, u)
