"""Mask-and-pack sparsification kernel for top-k / rand-k gossip.

Used by the sparse wire codecs (``cfg.compress == "topk:<k>"`` /
``"randk:<k>"``, ChocoSGD-style with error feedback):
``core/compression.py`` computes the per-worker keep threshold (the k-th
largest gate value — |z| for top-k, a seeded uniform score for rand-k)
and this kernel applies it on the engines' [rows, cols] wire layout in
one HBM pass: values at kept coordinates pass through untouched, the
rest are zeroed, and a per-tile survivor count is emitted (the "pack"
accounting the wire-bits model charges — k values plus explicit indices
for top-k, k values plus the shared mask seed for rand-k).

Because the kernel is a pure select (no rounding), its output is
bit-identical to the jnp oracle (``kernels/ref.sparsify_block_ref``) —
the fused engines route through the kernel, the reference engines
through the oracle, and the differential harness holds exactly as it
does for the int8 quantize kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gossip_mix import pad_to_blocks

BLOCK_ROWS = 8
BLOCK_COLS = 1024


def _sparsify_kernel(t_ref, x_ref, g_ref, y_ref, n_ref):
    x = x_ref[...]
    keep = g_ref[...].astype(jnp.float32) >= t_ref[0, 0]
    y_ref[...] = jnp.where(keep, x, jnp.zeros_like(x)).astype(y_ref.dtype)
    n_ref[0, 0] = jnp.sum(keep.astype(jnp.int32))


def sparsify_block_2d(x, gate, thresh, *, interpret: bool = False):
    """x, gate: [R, C]; thresh: scalar keep threshold on ``gate``.

    Returns (y [R, C], nnz i32 [ceil(R/BR), ceil(C/BC)]): y keeps x where
    ``gate >= thresh`` and is zero elsewhere; nnz counts the survivors
    per (8, 1024) tile. Non-tile-multiple shapes are padded to the block
    grid — x with zeros, gate with -1 so padding never survives the
    threshold (keeping the nnz accounting exact) — and y is sliced back.
    """
    r, c = x.shape
    assert gate.shape == (r, c), (x.shape, gate.shape)
    br, bc, rp, cp = pad_to_blocks(r, c, BLOCK_ROWS, BLOCK_COLS)
    if (rp, cp) != (r, c):
        x = jnp.pad(x, ((0, rp - r), (0, cp - c)))
        gate = jnp.pad(gate, ((0, rp - r), (0, cp - c)),
                       constant_values=-1.0)
    y, nnz = pl.pallas_call(
        _sparsify_kernel,
        grid=(rp // br, cp // bc),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),     # thresh: whole
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, cp), x.dtype),
            jax.ShapeDtypeStruct((rp // br, cp // bc), jnp.int32),
        ],
        interpret=interpret,
    )(jnp.asarray(thresh, jnp.float32).reshape(1, 1), x, gate)
    if (rp, cp) != (r, c):
        y = y[:r, :c]
    return y, nnz
