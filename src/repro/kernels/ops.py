"""Public jit'd wrappers over the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the body
runs in Python for correctness validation; TPU is the compile target.
Wrappers handle padding to block multiples and layout massaging so call
sites stay shape-agnostic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import consensus_dist as _cd
from repro.kernels import flash_attention as _fa
from repro.kernels import gossip_mix as _gm
from repro.kernels import quantize_block as _qb


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_pow2(s: int, block: int) -> int:
    return (s + block - 1) // block * block


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    interpret: bool | None = None):
    """q: [B, S, Hq, hd]; k, v: [B, Sk, Hkv, hd] (model layout).

    Returns [B, S, Hq, hd]. Differentiable: custom VJP — forward is the
    Pallas kernel, backward recomputes through the jnp reference (the
    flash-standard recompute; interpret-mode pallas_call has no reverse
    AD). Pads sequence dims to block multiples; padded keys are masked
    by the causal guard (padded positions > every real query)."""
    interp = _on_cpu() if interpret is None else interpret
    return _flash_vjp(q, k, v, causal, window, interp)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_vjp(q, k, v, causal, window, interp):
    return _flash_fwd_impl(q, k, v, causal, window, interp)


def _ref_model_layout(q, k, v, causal, window):
    from repro.models import layers as L
    mask = None
    if causal or window:
        mask = L.gqa_scores_mask(q.shape[1], k.shape[1], causal=causal,
                                 window=window)
    return L.gqa_attention_ref(q, k, v, mask)


def _flash_fwd(q, k, v, causal, window, interp):
    return _flash_vjp(q, k, v, causal, window, interp), (q, k, v)


def _flash_bwd(causal, window, interp, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda qq, kk, vv: _ref_model_layout(qq, kk, vv, causal, window),
        q, k, v)
    return vjp(g)


_flash_vjp.defvjp(_flash_fwd, _flash_bwd)


def _flash_fwd_impl(q, k, v, causal, window, interp):
    b, s, hq, hd = q.shape
    sk = k.shape[1]
    bq = min(_fa.DEFAULT_BLOCK_Q, _pad_pow2(s, 128))
    bk = min(_fa.DEFAULT_BLOCK_K, _pad_pow2(sk, 128))
    sp, skp = _pad_pow2(s, bq), _pad_pow2(sk, bk)
    qt = jnp.moveaxis(q, 2, 1)                          # [B, Hq, S, hd]
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    qt = jnp.pad(qt, ((0, 0), (0, 0), (0, sp - s), (0, 0)))
    kt = jnp.pad(kt, ((0, 0), (0, 0), (0, skp - sk), (0, 0)))
    vt = jnp.pad(vt, ((0, 0), (0, 0), (0, skp - sk), (0, 0)))
    # padded keys must never be attended: rely on causal mask when causal
    # (padded k positions > all real q positions); otherwise mask via big
    # negative bias using a window that excludes them is not available, so
    # non-causal callers must pass pre-padded inputs.
    o = _fa.flash_attention_fwd(qt, kt, vt, causal=causal or sk != skp,
                                window=window, block_q=bq, block_k=bk,
                                interpret=interp)
    return jnp.moveaxis(o[:, :, :s], 1, 2)


# ---------------------------------------------------------------------------
# gossip mix / consensus distance / quantize — operate on flat params
# ---------------------------------------------------------------------------

ROWS = _gm.BLOCK_ROWS
COLS = _gm.BLOCK_COLS
TILE = ROWS * COLS


def _to_2d(flat):
    n = flat.shape[0]
    npad = _pad_pow2(n, TILE)
    return jnp.pad(flat, (0, npad - n)).reshape(npad // COLS, COLS), n


@partial(jax.jit, static_argnames=("interpret",))
def gossip_mix(x_flat, u_flat, w, *, interpret: bool | None = None):
    """Fused Eq. 5 mixing. x: [L]; u: [K, L]; w: [K] -> [L]."""
    interp = _on_cpu() if interpret is None else interpret
    x2, n = _to_2d(x_flat)
    u2 = jax.vmap(lambda uu: _to_2d(uu)[0])(u_flat)
    y = _gm.gossip_mix_2d(x2, u2, w, interpret=interp)
    return y.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("interpret",))
def consensus_dist(x_flat, u_flat, *, interpret: bool | None = None):
    """Fused Eq. 7: [K] L2 distances ||x - u_k||."""
    interp = _on_cpu() if interpret is None else interpret
    x2, n = _to_2d(x_flat)
    u2 = jax.vmap(lambda uu: _to_2d(uu)[0])(u_flat)
    d2 = _cd.consensus_dist_2d(x2, u2, interpret=interp)
    return jnp.sqrt(d2)


@partial(jax.jit, static_argnames=("interpret",))
def quantize(x_flat, *, interpret: bool | None = None):
    """Per-tile int8 quantization of a flat vector.

    Returns (q int8 [Lp], scales f32 [Lp/TILE], orig_len)."""
    interp = _on_cpu() if interpret is None else interpret
    x2, n = _to_2d(x_flat)
    q, s = _qb.quantize_block_2d(x2, interpret=interp)
    return q.reshape(-1), s.reshape(-1), n


@partial(jax.jit, static_argnames=("n", "interpret"))
def dequantize(q_flat, scales, n: int, *, interpret: bool | None = None):
    interp = _on_cpu() if interpret is None else interpret
    rows = q_flat.shape[0] // COLS
    q2 = q_flat.reshape(rows, COLS)
    s2 = scales.reshape(rows // ROWS, 1)
    x = _qb.dequantize_block_2d(q2, s2, interpret=interp)
    return x.reshape(-1)[:n]
