"""Blocked online-softmax attention (flash attention) for TPU.

Forward kernel, GQA-aware, causal and sliding-window masking. Grid is
(batch, q_heads, q_blocks, kv_blocks) with the kv dimension marked
"arbitrary" (sequential-minor on TPU), so the running-max / denominator /
accumulator live in VMEM scratch carried across kv iterations — the
canonical TPU flash pattern. Block shapes are MXU-aligned (multiples of
128 on the sequence dims; head_dim is the lane dim).

HBM->VMEM traffic per q block: q once, k/v streamed once — O(S·hd) per
head instead of the O(S^2) score materialization of naive attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: int, block_q: int,
                 block_k: int, kv_blocks: int):
    qi = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # [BQ, hd]
    k = k_ref[0, 0].astype(jnp.float32)                  # [BK, hd]
    v = v_ref[0, 0].astype(jnp.float32)                  # [BK, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # [BQ, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                               # [BQ, BK]
    alpha = jnp.exp(m_prev - m_new)                      # [BQ, 1]
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == kv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = False):
    """q: [B, Hq, S, hd]; k, v: [B, Hkv, Sk, hd]. Returns [B, Hq, S, hd].

    Hq must be a multiple of Hkv (GQA); S, Sk multiples of the block sizes
    (ops.py pads). Mask conventions match ``layers.gqa_scores_mask``.
    """
    b, hq, s, hd = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, sk)
    assert s % block_q == 0 and sk % block_k == 0, (s, sk, block_q, block_k)
    q_blocks, kv_blocks = s // block_q, sk // block_k
    scale = hd ** -0.5

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_blocks=kv_blocks)

    return pl.pallas_call(
        kernel,
        grid=(b, hq, q_blocks, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bi, h, qi, kb: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, h, qi, kb, g=g: (bi, h // g, kb, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, h, qi, kb, g=g: (bi, h // g, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bi, h, qi, kb: (bi, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
