"""Per-block int8 quantize/dequantize kernel with f32 scales.

Used by ``runtime/compression.py`` for gossip-delta compression (beyond-
paper optimization, ChocoSGD/DeepSqueeze-style): the model delta sent to
each neighbor shrinks 4x (f32) / 2x (bf16) on the wire, with error
feedback keeping the bias compensated. Scales are per (8, 1024) tile —
fine enough to track gossip-delta dynamic range, coarse enough that the
scale side-channel is 0.01% of payload.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 8
BLOCK_COLS = 1024
QMAX = 127.0


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / QMAX, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -QMAX, QMAX)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[0, 0] = scale


def quantize_block_2d(x, *, interpret: bool = False):
    """x: [R, C] -> (q int8 [R, C], scales f32 [R/BR, C/BC])."""
    r, c = x.shape
    br, bc = min(BLOCK_ROWS, r), min(BLOCK_COLS, c)
    assert r % br == 0 and c % bc == 0, (r, c, br, bc)
    return pl.pallas_call(
        _quant_kernel,
        grid=(r // br, c // bc),
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), jnp.int8),
            jax.ShapeDtypeStruct((r // br, c // bc), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[0, 0]) \
        .astype(x_ref.dtype)


def dequantize_block_2d(q, scales, dtype=jnp.float32, *,
                        interpret: bool = False):
    """Inverse of ``quantize_block_2d``."""
    r, c = q.shape
    nr, nc = scales.shape
    br, bc = r // nr, c // nc
    return pl.pallas_call(
        _dequant_kernel,
        grid=(nr, nc),
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), dtype),
        interpret=interpret,
    )(q, scales)
