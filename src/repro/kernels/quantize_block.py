"""Per-block int8 quantize/dequantize kernel with f32 scales.

Used by the compressed-gossip path (beyond-paper optimization, ChocoSGD/
DeepSqueeze-style): ``core/compression.py`` defines the wire format and
the error-feedback compensated update, ``core/fused.py`` runs these
kernels on the flattened [W, P] parameter matrix inside its round scan
(``cfg.compress == "int8"``), and ``runtime/collectives.
gossip_compressed_fn`` ships the same format over ``lax.ppermute``. The
payload each neighbor receives shrinks ~4x (f32) on the wire, with error
feedback keeping the mixing bias compensated. Scales are per (8, 1024)
tile — fine enough to track gossip dynamic range, coarse enough that the
scale side-channel is ~0.05% of payload.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gossip_mix import pad_to_blocks

BLOCK_ROWS = 8
BLOCK_COLS = 1024
QMAX = 127.0


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / QMAX, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -QMAX, QMAX)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[0, 0] = scale


def quantize_block_2d(x, *, interpret: bool = False):
    """x: [R, C] -> (q int8 [R, C], scales f32 [ceil(R/BR), ceil(C/BC)]).

    Non-tile-multiple shapes are zero-padded to the block grid (zeros
    never raise a tile's amax, so scales are unaffected) and q is sliced
    back to the input shape."""
    r, c = x.shape
    br, bc, rp, cp = pad_to_blocks(r, c, BLOCK_ROWS, BLOCK_COLS)
    if (rp, cp) != (r, c):
        x = jnp.pad(x, ((0, rp - r), (0, cp - c)))
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(rp // br, cp // bc),
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, cp), jnp.int8),
            jax.ShapeDtypeStruct((rp // br, cp // bc), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    if (rp, cp) != (r, c):
        q = q[:r, :c]
    return q, s


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[0, 0]) \
        .astype(x_ref.dtype)


def dequantize_block_2d(q, scales, dtype=jnp.float32, *,
                        interpret: bool = False):
    """Inverse of ``quantize_block_2d`` (same padding shim: recomputes the
    block shape ``quantize_block_2d`` used from q's shape)."""
    r, c = q.shape
    nr, nc = scales.shape
    br, bc, rp, cp = pad_to_blocks(r, c, BLOCK_ROWS, BLOCK_COLS)
    assert (nr, nc) == (rp // br, cp // bc), (q.shape, scales.shape)
    if (rp, cp) != (r, c):
        q = jnp.pad(q, ((0, rp - r), (0, cp - c)))
    x = pl.pallas_call(
        _dequant_kernel,
        grid=(nr, nc),
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, cp), dtype),
        interpret=interpret,
    )(q, scales)
    if (rp, cp) != (r, c):
        x = x[:r, :c]
    return x
