"""Fused consensus-distance kernel (paper Eq. 7): per-neighbor squared L2

    d_k = sum ( x - u_k )^2

as a blocked partial-sum reduction — never materializes the (K, L)
difference tensor in HBM. Feeds the coordinator every round (Alg. 1
line 9). The output block maps every grid step to the same (K, 1)
accumulator; TPU grids iterate sequentially, so read-modify-write
accumulation is safe (same pattern as the flash-attention scratch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 8
BLOCK_COLS = 1024


def _consensus_kernel(x_ref, u_ref, o_ref, *, num_neighbors: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                     # [R, C]
    for kidx in range(num_neighbors):
        d = u_ref[kidx].astype(jnp.float32) - x
        o_ref[kidx, 0] += jnp.sum(d * d)


def consensus_dist_2d(x, u, *, interpret: bool = False):
    """x: [R, C]; u: [K, R, C]. Returns [K] f32 squared distances."""
    r, c = x.shape
    k = u.shape[0]
    br, bc = min(BLOCK_ROWS, r), min(BLOCK_COLS, c)
    assert r % br == 0 and c % bc == 0, (r, c, br, bc)
    kernel = functools.partial(_consensus_kernel, num_neighbors=k)
    out = pl.pallas_call(
        kernel,
        grid=(r // br, c // bc),
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((k, br, bc), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((k, 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, 1), jnp.float32),
        interpret=interpret,
    )(x, u)
    return out[:, 0]
