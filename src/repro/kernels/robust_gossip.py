"""Byzantine-robust gossip kernel: gather-sort-trim on [W, C].

For each worker i the kernel robust-averages the closed neighborhood
``{x_i} ∪ {t_j : j ∈ N(i)}`` coordinate-wise — own honest row plus the
TRANSMITTED neighbor rows — replacing the weighted Eq. 5 mix when
``cfg.robust`` is ``trimmed:<b>`` or ``median``. The neighborhood
arrives as a host-built max-degree padded index table (``nbr [W, D]``,
``deg [W]``), which makes the whole sort/trim window shape-static and
therefore scannable inside the fused round loop.

Grid: one program per column tile (the ``gossip_edges`` layout — all
padded W rows of the tile stay resident). Each program walks the
workers with a ``fori_loop``; per worker it gathers the own row plus up
to D transmitted rows via dynamic row slices (``pl.ds``) into a
``[D + 1, BC]`` window, masks padding slots (index >= deg) to +inf,
sorts the window rows with an odd-even transposition network (D + 1
static compare-exchange passes of elementwise min/max — no
data-dependent control flow, so it lowers the same everywhere), and
reduces the sorted window:

- ``trimmed``: average of positions ``[b_i, cnt - b_i)`` where
  ``cnt = deg + 1`` and ``b_i`` is the per-worker clamped trim count;
- ``median``: mean of the two middle order statistics.

Workers with no neighbors (including padded rows) keep their row
exactly, so row padding is a no-op like the zero-weight padding edges
of ``gossip_edges``. Oracle: ``ref.robust_gossip_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_COLS = 256        # all W rows stay resident per program: keep tiles lean


def _sort_rows(win):
    """Odd-even transposition sort of the window rows (ascending), one
    independent network per column. n static passes of vectorized
    compare-exchange — +inf padding rows sink to the bottom."""
    n = win.shape[0]
    idx = jnp.arange(n)[:, None]
    for p in range(n):
        q = p % 2
        up = jnp.roll(win, -1, axis=0)      # row r sees row r+1's value
        down = jnp.roll(win, 1, axis=0)     # row r sees row r-1's value
        is_lo = ((idx - q) % 2 == 0) & (idx + 1 < n)
        is_hi = ((idx - q) % 2 == 1) & (idx >= 1)
        win = jnp.where(is_lo, jnp.minimum(win, up),
                        jnp.where(is_hi, jnp.maximum(win, down), win))
    return win


def _robust_kernel(nbr_ref, deg_ref, x_ref, t_ref, o_ref, *,
                   num_workers: int, d_pad: int, b: float, mode: str):
    """Per-column-tile program: gather-sort-trim every worker's window."""
    bc = x_ref.shape[1]
    inf = jnp.float32(jnp.inf)

    def worker(i, carry):
        d = deg_ref[0, i]
        own = x_ref[pl.ds(i, 1), :].astype(jnp.float32)          # [1, bc]
        win = jnp.full((d_pad + 1, bc), inf, jnp.float32)
        win = jax.lax.dynamic_update_slice(win, own, (0, 0))

        def gather(k, win):
            j = nbr_ref[0, i * d_pad + k]
            row = t_ref[pl.ds(j, 1), :].astype(jnp.float32)
            row = jnp.where(k < d, row, inf)
            return jax.lax.dynamic_update_slice(win, row, (k + 1, 0))

        win = jax.lax.fori_loop(0, d_pad, gather, win)
        win = _sort_rows(win)
        cnt = d + 1
        if mode == "trimmed":
            if b < 1.0:
                bi = jnp.floor(b * cnt.astype(jnp.float32)).astype(jnp.int32)
            else:
                bi = jnp.int32(int(b))
            bi = jnp.minimum(bi, (cnt - 1) // 2)
            pos = jnp.arange(d_pad + 1)[:, None]
            inside = (pos >= bi) & (pos < cnt - bi)
            y = jnp.where(inside & jnp.isfinite(win), win, 0.0)
            y = y.sum(axis=0, keepdims=True) / (cnt - 2 * bi)
        else:                                                    # median
            lo = (cnt - 1) // 2
            hi = cnt // 2
            vlo = jax.lax.dynamic_slice(win, (lo, 0), (1, bc))
            vhi = jax.lax.dynamic_slice(win, (hi, 0), (1, bc))
            y = 0.5 * (vlo + vhi)
        y = jnp.where(d > 0, y, own)
        o_ref[pl.ds(i, 1), :] = y.astype(o_ref.dtype)
        return carry

    jax.lax.fori_loop(0, num_workers, worker, 0)


def robust_gossip(x, t, nbr, deg, *, b: float, mode: str,
                  interpret: bool = False):
    """x, t: [W, C]; nbr: [W, D] int32 padded neighbor table; deg: [W].

    Returns the robust-aggregated [W, C] matrix (f32): per worker the
    ``mode`` statistic ("trimmed" with trim knob ``b``, or "median") of
    its own row in ``x`` plus the transmitted rows ``t[nbr[i, :deg[i]]]``.
    W and C need not be tile multiples — rows pad to a multiple of 8
    with degree-0 (keep-own-row) workers, columns to the tile size."""
    r, c = x.shape
    d_pad = max(nbr.shape[1], 1)
    rp = -(-r // 8) * 8
    bc = min(BLOCK_COLS, c)
    cp = -(-c // bc) * bc
    x = x.astype(jnp.float32)
    t = t.astype(jnp.float32)
    nbr = jnp.asarray(nbr, jnp.int32).reshape(r, -1)
    deg = jnp.asarray(deg, jnp.int32)
    if (rp, cp) != (r, c):
        x = jnp.pad(x, ((0, rp - r), (0, cp - c)))
        t = jnp.pad(t, ((0, rp - r), (0, cp - c)))
    if rp != r:
        nbr = jnp.pad(nbr, ((0, rp - r), (0, 0)))
        deg = jnp.pad(deg, (0, rp - r))
    kernel = functools.partial(_robust_kernel, num_workers=rp,
                               d_pad=d_pad, b=b, mode=mode)
    out = pl.pallas_call(
        kernel,
        grid=(cp // bc,),
        in_specs=[
            pl.BlockSpec((1, rp * d_pad), lambda j: (0, 0)),
            pl.BlockSpec((1, rp), lambda j: (0, 0)),
            pl.BlockSpec((rp, bc), lambda j: (0, j)),
            pl.BlockSpec((rp, bc), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((rp, bc), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((rp, cp), jnp.float32),
        interpret=interpret,
    )(nbr.reshape(1, rp * d_pad), deg.reshape(1, rp), x, t)
    if (rp, cp) != (r, c):
        out = out[:r, :c]
    return out
