"""Sparse edge-list gossip kernel: gather-mix-scatter on [W, C].

    y = x;  for each directed edge e:  y[dst_e] += w_e * (x[src_e] - x[dst_e])

the O(E·C) sparse form of the dense mixing ``W @ X`` (Eq. 5) — the dense
form is O(W²·C) compute and needs a [W, W] matrix per round, which is
the wall this kernel removes. Every delta reads the PRE-mix ``x`` for
both endpoints, so the result is exactly ``x + Σ_e w_e (x_src - x_dst)``
scattered onto rows, i.e. the off-diagonal part of the row-stochastic
mixing matrix; self-weights are implicit.

Grid: one program per column tile — each program keeps all (padded) W
rows of its tile resident and walks the whole edge list with a
``fori_loop`` of dynamic row gathers/scatters (``pl.ds``). Rows pad to
a multiple of 8 (sublane), edges to a multiple of 8 with zero-weight
self-loops at vertex 0, which contribute ``0 * (x_0 - x_0) = 0``
exactly — this is what lets callers pad per-round edge arrays to a
static E_max inside ``lax.scan``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_COLS = 256        # all W rows stay resident per program: keep tiles lean
_EDGE_PAD = 8


def _edges_kernel(src_ref, dst_ref, w_ref, x_ref, o_ref, *, num_edges: int):
    o_ref[...] = x_ref[...]

    def body(e, carry):
        s = src_ref[0, e]
        d = dst_ref[0, e]
        we = w_ref[0, e]
        xs = x_ref[pl.ds(s, 1), :].astype(jnp.float32)
        xd = x_ref[pl.ds(d, 1), :].astype(jnp.float32)
        cur = o_ref[pl.ds(d, 1), :].astype(jnp.float32)
        o_ref[pl.ds(d, 1), :] = (cur + we * (xs - xd)).astype(o_ref.dtype)
        return carry

    jax.lax.fori_loop(0, num_edges, body, 0)


def pad_edges(src, dst, w, e_max: int | None = None):
    """Pad directed edge arrays to ``e_max`` (>= len, rounded up to a
    multiple of 8, min 8) with zero-weight self-loops at vertex 0 —
    exact no-ops under the kernel, so padded and unpadded calls agree
    bit-for-bit. Returns (src, dst, w) int32/int32/f32."""
    src = jnp.asarray(src, jnp.int32).reshape(-1)
    dst = jnp.asarray(dst, jnp.int32).reshape(-1)
    w = jnp.asarray(w, jnp.float32).reshape(-1)
    e = src.shape[0]
    target = e if e_max is None else max(e_max, e)
    ep = max(_EDGE_PAD, -(-target // _EDGE_PAD) * _EDGE_PAD)
    if ep != e:
        src = jnp.pad(src, (0, ep - e))
        dst = jnp.pad(dst, (0, ep - e))
        w = jnp.pad(w, (0, ep - e))
    return src, dst, w


def gossip_edges(x, src, dst, w, *, interpret: bool = False):
    """x: [W, C]; src, dst: [E] int32 directed edges; w: [E] f32.

    Returns ``y`` with ``y[i] = x[i] + Σ_{e: dst_e=i} w_e (x[src_e] - x[i])``.
    W and C need not be tile multiples (zero-padded internally; padded
    rows are never addressed by edges and are sliced away)."""
    r, c = x.shape
    src, dst, w = pad_edges(src, dst, w)
    ep = src.shape[0]
    rp = -(-r // 8) * 8
    bc = min(BLOCK_COLS, c)
    cp = -(-c // bc) * bc
    if (rp, cp) != (r, c):
        x = jnp.pad(x, ((0, rp - r), (0, cp - c)))
    kernel = functools.partial(_edges_kernel, num_edges=ep)
    out = pl.pallas_call(
        kernel,
        grid=(cp // bc,),
        in_specs=[
            pl.BlockSpec((1, ep), lambda j: (0, 0)),
            pl.BlockSpec((1, ep), lambda j: (0, 0)),
            pl.BlockSpec((1, ep), lambda j: (0, 0)),
            pl.BlockSpec((rp, bc), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((rp, bc), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((rp, cp), x.dtype),
        interpret=interpret,
    )(src.reshape(1, ep), dst.reshape(1, ep),
      w.reshape(1, ep).astype(jnp.float32), x)
    if (rp, cp) != (r, c):
        out = out[:r, :c]
    return out
