"""Pure-jnp oracles for every kernel — the ground truth the Pallas
implementations are swept against (tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [B, Hq, S, hd]; k, v: [B, Hkv, Sk, hd] -> [B, Hq, S, hd]."""
    b, hq, s, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    qr = q.reshape(b, hkv, g, s, hd).astype(jnp.float32)
    scores = jnp.einsum("bhgsd,bhtd->bhgst", qr,
                        k.astype(jnp.float32)) * (hd ** -0.5)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(sk)[None, :]
    mask = jnp.ones((s, sk), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgst,bhtd->bhgsd", w, v.astype(jnp.float32))
    return o.reshape(b, hq, s, hd).astype(q.dtype)


def gossip_mix_ref(x, u, w):
    """x: [R, C]; u: [K, R, C]; w: [K]. y = x + sum_k w_k (u_k - x)."""
    xf = x.astype(jnp.float32)
    diff = u.astype(jnp.float32) - xf[None]
    y = xf + jnp.tensordot(w.astype(jnp.float32), diff, axes=1)
    return y.astype(x.dtype)


def gossip_edges_ref(x, src, dst, w):
    """x: [W, C]; src, dst: [E] directed edges; w: [E].
    y[i] = x[i] + sum_{e: dst_e=i} w_e (x[src_e] - x[i]) via segment_sum
    — the jnp oracle for ``kernels/gossip_edges.py``."""
    xf = x.astype(jnp.float32)
    delta = w.astype(jnp.float32)[:, None] * (xf[src] - xf[dst])
    y = xf + jax.ops.segment_sum(delta, dst, num_segments=x.shape[0])
    return y.astype(x.dtype)


def robust_gossip_ref(x, t, nbr, deg, *, b: float, mode: str):
    """x, t: [W, C]; nbr: [W, D] int32 padded neighbor table; deg: [W].

    Coordinate-wise robust aggregation over each worker's closed
    neighborhood — own honest row ``x[i]`` plus the TRANSMITTED rows
    ``t[j]`` of its neighbors — the jnp oracle for
    ``kernels/robust_gossip.py``. Padding slots (index >= deg) are
    masked to +inf so they sink past the sorted window. ``mode`` is
    ``"trimmed"`` (drop the ``b_i`` extremes per side, average the
    rest; fractional ``b`` scales with the neighborhood, clamped so the
    window never empties) or ``"median"`` (average of the two middle
    order statistics). Workers with no neighbors keep their row."""
    d_pad = nbr.shape[1]
    gathered = t.astype(jnp.float32)[nbr]              # [W, D, C]
    mask = jnp.arange(d_pad)[None, :] < deg[:, None]
    vals = jnp.concatenate(
        [x.astype(jnp.float32)[:, None, :],
         jnp.where(mask[:, :, None], gathered, jnp.inf)], axis=1)
    cnt = deg + 1
    sv = jnp.sort(vals, axis=1)
    pos = jnp.arange(d_pad + 1)[None, :, None]
    if mode == "trimmed":
        if b < 1.0:
            bi = jnp.floor(b * cnt.astype(jnp.float32)).astype(jnp.int32)
        else:
            bi = jnp.full_like(cnt, jnp.int32(int(b)))
        bi = jnp.minimum(bi, (cnt - 1) // 2)[:, None, None]
        win = (pos >= bi) & (pos < (cnt[:, None, None] - bi))
        y = jnp.where(win & jnp.isfinite(sv), sv, 0.0)
        y = y.sum(axis=1) / (cnt[:, None] - 2 * bi[:, :, 0])
    elif mode == "median":
        lo = ((cnt - 1) // 2)[:, None, None]
        hi = (cnt // 2)[:, None, None]
        vlo = jnp.take_along_axis(sv, lo, axis=1)[:, 0, :]
        vhi = jnp.take_along_axis(sv, hi, axis=1)[:, 0, :]
        y = 0.5 * (vlo + vhi)
    else:
        raise ValueError(f"unknown robust mode {mode!r}")
    return jnp.where((deg > 0)[:, None], y, x.astype(jnp.float32))


def consensus_dist_ref(x, u):
    """x: [R, C]; u: [K, R, C] -> [K] squared L2 distances."""
    d = u.astype(jnp.float32) - x.astype(jnp.float32)[None]
    return jnp.sum(d * d, axis=(1, 2))


def quantize_block_ref(x, block_rows: int, block_cols: int):
    """Per-(block_rows, block_cols)-tile int8 quantization."""
    r, c = x.shape
    nr, nc = r // block_rows, c // block_cols
    t = x.astype(jnp.float32).reshape(nr, block_rows, nc, block_cols)
    t = t.transpose(0, 2, 1, 3)                       # [nr, nc, br, bc]
    amax = jnp.max(jnp.abs(t), axis=(2, 3))
    scales = jnp.maximum(amax / 127.0, 1e-30)
    q = jnp.clip(jnp.round(t / scales[..., None, None]), -127, 127)
    q = q.transpose(0, 2, 1, 3).reshape(r, c).astype(jnp.int8)
    return q, scales


def sparsify_block_ref(x, gate, thresh):
    """Oracle twin of ``sparsify_block.sparsify_block_2d`` (tile-multiple
    shapes): y keeps x where gate >= thresh, nnz counts survivors per
    (8, 1024) tile."""
    r, c = x.shape
    br, bc = min(8, r), min(1024, c)
    keep = gate.astype(jnp.float32) >= jnp.asarray(thresh, jnp.float32)
    y = jnp.where(keep, x, jnp.zeros_like(x))
    t = keep.astype(jnp.int32).reshape(r // br, br, c // bc, bc)
    nnz = t.transpose(0, 2, 1, 3).sum(axis=(2, 3))
    return y, nnz


def dequantize_block_ref(q, scales, dtype=jnp.float32):
    r, c = q.shape
    nr, nc = scales.shape
    br, bc = r // nr, c // nc
    t = q.astype(jnp.float32).reshape(nr, br, nc, bc).transpose(0, 2, 1, 3)
    x = t * scales[..., None, None]
    return x.transpose(0, 2, 1, 3).reshape(r, c).astype(dtype)
