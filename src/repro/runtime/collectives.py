"""Gossip collectives: the paper's P2P model exchange (Eq. 5) as TPU-native
`shard_map` + `lax.ppermute`.

The round topology A^h is edge-colored into matchings
(``topology.matching_decomposition``); each matching is ONE
collective-permute over the worker axes (an involution), so a sparse
topology costs (#matchings) x |params| wire bytes instead of the
2(N-1)/N x |params| of an all-reduce — the paper's adaptive-topology knob
becomes a measurable collective-bytes term in the roofline.

Also provides the fused consensus-distance measurement (Alg. 1 line 9) in
the same data pass, and compressed gossip (beyond-paper;
DeepSqueeze/ChocoSGD-style) sharing ``core/compression``'s codecs —
int8 + error feedback, x̂-tracked top-k, shared-mask rand-k.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core import compression
from repro.core import topology as topo

if hasattr(jax, "shard_map"):                           # jax >= 0.6
    def _shard_map(body, mesh, in_specs, out_specs):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:                                                   # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def _shard_map(body, mesh, in_specs, out_specs):
        return _exp_shard_map(body, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)


def matchings_as_pairs(adj: np.ndarray) -> list[list[tuple[int, int]]]:
    """Topology -> list of ppermute pair-lists (each an involution, with
    identity pairs for unmatched workers so every destination is written)."""
    n = adj.shape[0]
    matchings = topo.matching_decomposition(adj)
    perms = topo.matchings_to_perms(matchings, n)          # [M, N]
    out = []
    for row in perms:
        out.append([(int(i), int(row[i])) for i in range(n)])
    return out


def matching_weight_tables(adj: np.ndarray, mix: np.ndarray) -> np.ndarray:
    """[M, N] per-worker mixing weight for its partner in matching m
    (0 where unmatched — identity pairs contribute w*(x-x)=0 anyway, but a
    zero weight also guards non-involution edge cases)."""
    n = adj.shape[0]
    matchings = topo.matching_decomposition(adj)
    w = np.zeros((len(matchings), n), np.float32)
    for m, match in enumerate(matchings):
        for (i, j) in match:
            w[m, i] = mix[i, j]
            w[m, j] = mix[j, i]
    return w


def gossip_fn(mesh: Mesh, worker_axes: tuple[str, ...],
              pairs: list[list[tuple[int, int]]],
              weight_table: np.ndarray, param_specs,
              *, measure_distances: bool = False):
    """Build a jit-able gossip(params) -> mixed (or (mixed, dists [M]))."""
    wt = jnp.asarray(weight_table)                        # [M, N]
    tp_axes = tuple(a for a in mesh.axis_names if a not in worker_axes)

    def body(x):
        me = jax.lax.axis_index(worker_axes)
        acc = x
        dists = []
        for m, perm in enumerate(pairs):
            y = jax.tree.map(
                lambda l: jax.lax.ppermute(l, axis_name=worker_axes,
                                           perm=perm), x)
            w_m = wt[m, me]
            acc = jax.tree.map(
                lambda a, yy, xx: a + (w_m * (yy.astype(jnp.float32)
                                              - xx.astype(jnp.float32))
                                       ).astype(a.dtype),
                acc, y, x)
            if measure_distances:
                d2 = sum(jnp.sum(jnp.square(yy.astype(jnp.float32)
                                            - xx.astype(jnp.float32)))
                         for yy, xx in zip(jax.tree.leaves(y),
                                           jax.tree.leaves(x)))
                # partial over the within-worker (TP/FSDP) shards -> full
                if tp_axes:
                    d2 = jax.lax.psum(d2, tp_axes)
                dists.append(jnp.sqrt(d2))
        if measure_distances:
            return acc, jnp.stack(dists) if dists else jnp.zeros((0,))
        return acc

    out_specs = (param_specs, P(None)) if measure_distances else param_specs
    return _shard_map(body, mesh, (param_specs,), out_specs)


def gossip_compressed_fn(mesh: Mesh, worker_axes: tuple[str, ...],
                         pairs: list[list[tuple[int, int]]],
                         weight_table: np.ndarray, param_specs,
                         *, mode: str = "int8", seed: int = 0,
                         gamma: float = 0.25):
    """Compressed gossip with the core codecs (beyond-paper).

    The updates are the ones ``core/compression.py`` defines (and the
    core engines implement), applied per leaf shard:

    - ``mode="int8"``: each worker sends the int8 round trip of
      z = x + e instead of x, the residual e <- z - dequant(quant(z))
      carries to the next round, and quantization uses the shared wire
      format — the flattened leaf shard laid out per ``flat_tile_shape``
      with one f32 scale per (8, 1024) tile, exactly what
      ``kernels/quantize_block.py`` produces. Wire bytes per matching
      drop ~4x (f32), plus the scale side-channel.
    - ``mode="topk:<k>"``: ChocoSGD x̂-tracking — the err buffer holds
      the public copy, the wire carries the top-k innovation (k resolved
      per leaf shard), and the mix runs damped (``gamma``) on the
      advanced copies.
    - ``mode="randk:<k>"``: the shared seeded mask (``seed``, the
      caller-supplied per-round ``step`` and the leaf index pick the
      draw — identical on every worker, so sender and receiver agree
      without shipping indices) ships k coordinates exactly; no state
      evolves.

    Returns gossip(params, err, step) -> (mixed, new_err) — ``step`` is
    a traced i32 round counter the caller advances every call (a reused
    rand-k mask would freeze the un-drawn coordinates forever; int8 and
    top-k ignore it). For topk pass the initial params as the initial
    ``err`` (``compression.state_init``).
    """
    codec = compression.parse_mode(mode)
    if codec.kind == "none":
        raise ValueError("use gossip_fn for uncompressed exchange")
    wt = jnp.asarray(weight_table)
    skey = compression.sparsify_base_key(seed)

    def sparse_payload(leaf, e, idx, step):
        """(payload ŷ or innovation q, new state) for one leaf shard."""
        zf = leaf.astype(jnp.float32).reshape(-1)
        kk = codec.resolve_k(zf.size)
        if codec.kind == "topk":
            q = compression.sparsify_rows((zf - e.reshape(-1))[None],
                                          "topk", kk)[0]
            xhat = e.reshape(-1) + q
            return xhat.reshape(leaf.shape), xhat.reshape(leaf.shape)
        kst = jax.random.fold_in(skey, idx)
        y = compression.sparsify_rows(zf[None], "randk", kk, key=kst,
                                      step=step)[0]
        return y.reshape(leaf.shape), e

    def body(x, err, step):
        me = jax.lax.axis_index(worker_axes)

        if codec.kind == "int8":
            def q8(leaf, e):
                z = leaf.astype(jnp.float32) + e
                n = int(np.prod(z.shape))
                q, scale = compression.quantize_flat(z.reshape(-1))
                deq = compression.dequantize_flat(q, scale,
                                                  n).reshape(leaf.shape)
                return q, scale, z - deq, deq

            packed = jax.tree.map(
                q8, x, err, is_leaf=lambda l: isinstance(l, jnp.ndarray))
            qs = jax.tree.map(lambda t: t[0], packed,
                              is_leaf=lambda t: isinstance(t, tuple))
            scales = jax.tree.map(lambda t: t[1], packed,
                                  is_leaf=lambda t: isinstance(t, tuple))
            new_err = jax.tree.map(lambda t: t[2], packed,
                                   is_leaf=lambda t: isinstance(t, tuple))
            deq_self = jax.tree.map(lambda t: t[3], packed,
                                    is_leaf=lambda t: isinstance(t, tuple))

            acc = x
            for m, perm in enumerate(pairs):
                pq = jax.tree.map(
                    lambda l: jax.lax.ppermute(l, worker_axes, perm=perm),
                    qs)
                ps = jax.tree.map(
                    lambda l: jax.lax.ppermute(l, worker_axes, perm=perm),
                    scales)
                w_m = wt[m, me]

                def mix(a, qn, sn, ds):
                    yn = compression.dequantize_flat(
                        qn, sn, int(np.prod(a.shape))).reshape(a.shape)
                    return a + (w_m * (yn - ds)).astype(a.dtype)

                acc = jax.tree.map(mix, acc, pq, ps, deq_self)
            return acc, new_err

        # sparse codecs: the masked payload rides ppermute dense (the
        # simulated wire cost is codec.wire_bits); mixing matches the
        # core compensated update on ŷ (rand-k) / x̂ (top-k, damped)
        xl, treedef = jax.tree.flatten(x)
        el = jax.tree.leaves(err)
        ys, news = [], []
        for idx, (leaf, e) in enumerate(zip(xl, el)):
            y, ne = sparse_payload(leaf, e, idx, step)
            ys.append(y)
            news.append(ne)
        ys = jax.tree.unflatten(treedef, ys)
        new_err = jax.tree.unflatten(treedef, news)
        g = gamma if codec.kind == "topk" else 1.0
        acc = x
        for m, perm in enumerate(pairs):
            yn = jax.tree.map(
                lambda l: jax.lax.ppermute(l, worker_axes, perm=perm), ys)
            w_m = wt[m, me]
            acc = jax.tree.map(
                lambda a, ynn, ysf: a + (g * w_m * (
                    ynn.astype(jnp.float32) - ysf.astype(jnp.float32))
                    ).astype(a.dtype),
                acc, yn, ys)
        return acc, new_err

    return _shard_map(body, mesh, (param_specs, param_specs, P()),
                      (param_specs, param_specs))


def worker_shard_extent(mesh: Mesh, worker_axes: tuple[str, ...]) -> int:
    """Number of row-shards the worker dim splits into over ``worker_axes``."""
    n = 1
    for a in worker_axes:
        n *= mesh.shape[a]
    return n


def edge_shard_tables(src, dst, w, num_workers: int, n_shards: int, *,
                      offsets: tuple[int, ...] | None = None,
                      width: int | None = None):
    """Group a directed edge list by shard-offset delta, host-side.

    Edges are grouped by ``delta = shard(dst) - shard(src) mod n_shards``
    (contiguous row sharding, ``rows = W / n_shards`` per shard); within
    a group they are bucketed by destination shard and zero-weight padded
    to a common width so every shard runs the same static shapes (padding
    rows land on local row 0 with weight 0 and add exactly nothing).

    Returns ``(offsets, sl, dl, wl)``: the sorted tuple of distinct
    deltas, and ``[D, n_shards, width]`` tables of local source rows,
    local destination rows and edge weights. Pass ``offsets``/``width``
    to force a shape shared across rounds (the fused scan stacks one
    table per round); a delta outside the forced ``offsets`` raises.
    """
    if num_workers % n_shards != 0:
        raise ValueError(f"W={num_workers} not divisible by "
                         f"worker-shard extent {n_shards}")
    rows = num_workers // n_shards
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    w = np.asarray(w, np.float32)
    deltas = (dst // rows - src // rows) % n_shards
    present = sorted(set(deltas.tolist()))
    if offsets is None:
        offsets = tuple(int(d) for d in present)
    else:
        extra = set(present) - set(offsets)
        if extra:
            raise ValueError(f"edge deltas {sorted(extra)} not in the "
                             f"forced offsets {offsets}")
    need = 1
    for delta in offsets:
        sel = deltas == delta
        if sel.any():
            need = max(need, int(np.bincount(dst[sel] // rows,
                                             minlength=n_shards).max()))
    if width is None:
        width = need
    elif width < need:
        raise ValueError(f"forced width {width} < required {need}")
    sl = np.zeros((len(offsets), n_shards, width), np.int32)
    dl = np.zeros((len(offsets), n_shards, width), np.int32)
    wl = np.zeros((len(offsets), n_shards, width), np.float32)
    for gi, delta in enumerate(offsets):
        sel = deltas == delta
        es, ed, ew = src[sel], dst[sel], w[sel]
        dshard = ed // rows
        for k in range(n_shards):
            m = dshard == k
            c = int(m.sum())
            sl[gi, k, :c] = es[m] % rows
            dl[gi, k, :c] = ed[m] % rows
            wl[gi, k, :c] = ew[m]
    return offsets, sl, dl, wl


def routed_mix_delta(v, sl, dl, wl, offsets: tuple[int, ...],
                     worker_axes: tuple[str, ...], n_shards: int):
    """The per-shard slice of ``compression.edge_mix_delta``: one
    ``lax.ppermute`` of the local ``[rows, P]`` block per distinct shard
    offset, then a local ``segment_sum`` over the group's edge table.
    Runs inside ``shard_map``; ``sl/dl/wl`` are the LOCAL ``[D, 1, width]``
    slices of :func:`edge_shard_tables` output."""
    acc = jnp.zeros(v.shape, jnp.float32)
    rows = v.shape[0]
    for gi, delta in enumerate(offsets):
        if delta == 0:
            recv = v
        else:
            perm = [(s, (s + delta) % n_shards) for s in range(n_shards)]
            recv = jax.lax.ppermute(v, worker_axes, perm=perm)
        contrib = wl[gi, 0][:, None] * (recv[sl[gi, 0]] - v[dl[gi, 0]])
        acc = acc + jax.ops.segment_sum(contrib, dl[gi, 0],
                                        num_segments=rows)
    return acc


def _edge_table_specs(worker_axes):
    spec = P(None, worker_axes, None)           # [D, n_shards, width]
    return (spec, spec, spec)


def gossip_edges_sharded_fn(mesh: Mesh, worker_axes: tuple[str, ...],
                            src: np.ndarray, dst: np.ndarray,
                            w: np.ndarray, num_workers: int):
    """Sparse edge-list gossip over a worker-sharded [W, P] stack.

    The dense path above pays one ppermute per *matching* (O(degree) of
    them). Here the directed edge list (``topology.directed_edges``) is
    grouped host-side by shard offset delta = shard(dst) - shard(src)
    mod n_shards (``edge_shard_tables``); each distinct delta costs
    exactly ONE ppermute of the local [W/n_shards, P] block, and every
    edge in the group lands via a per-shard segment_sum on local row
    indices — so wire cost scales with the number of distinct shard
    offsets the topology touches, not E.

    Returns a jit-able f(x: [W, P]) -> mixed [W, P] with
    y_i = x_i + sum_{e: dst_e=i} w_e (x_{src_e} - x_i); x is sharded
    P(worker_axes, None). Requires W divisible by the worker-axes extent.
    """
    n_shards = worker_shard_extent(mesh, worker_axes)
    offsets, sl, dl, wl = edge_shard_tables(src, dst, w, num_workers,
                                            n_shards)
    tables = (jnp.asarray(sl), jnp.asarray(dl), jnp.asarray(wl))

    def body(x, sl, dl, wl):
        xf = x.astype(jnp.float32)
        delta = routed_mix_delta(xf, sl, dl, wl, offsets, worker_axes,
                                 n_shards)
        return (xf + delta).astype(x.dtype)

    spec = P(worker_axes, None)
    mapped = _shard_map(body, mesh, (spec,) + _edge_table_specs(worker_axes),
                        spec)
    return lambda x: mapped(x, *tables)


def gossip_edges_compressed_sharded_fn(mesh: Mesh,
                                       worker_axes: tuple[str, ...],
                                       src: np.ndarray, dst: np.ndarray,
                                       w: np.ndarray, num_workers: int, *,
                                       kind: str = "int8", k: int = 0,
                                       error_feedback: bool = True,
                                       seed: int = 0, gamma: float = 1.0):
    """Compressed edge-list gossip over a worker-sharded [W, P] stack.

    Every codec payload is row-local — int8 quantizes per row on the
    shared wire layout, top-k thresholds per row, rand-k recomputes the
    one shared mask from ``(seed, step)`` on every shard — so each shard
    compresses its own rows and only the mixing delta crosses shards,
    via the same ppermute-by-offset routing as
    :func:`gossip_edges_sharded_fn`. The payload/state/update formulas
    are ``compression.compressed_gossip_ref`` itself with the routed
    delta injected (``mix_delta_fn``), so the sharded trajectory matches
    the single-device engines to summation-order tolerance.

    Returns f(x [W, P], err [W, P], step) -> (mixed, new_err); ``err``
    follows ``compression.state_init`` / ``carries_state`` semantics
    (top-k+EF tracks x̂, int8 the residual, rand-k nothing).
    """
    codec = compression.parse_mode(kind) if ":" in kind else None
    if codec is not None:
        kind, k = codec.kind, 0     # resolved below against P
    n_shards = worker_shard_extent(mesh, worker_axes)
    offsets, sl, dl, wl = edge_shard_tables(src, dst, w, num_workers,
                                            n_shards)
    tables = (jnp.asarray(sl), jnp.asarray(dl), jnp.asarray(wl))
    skey = compression.sparsify_base_key(seed)

    def body(x, err, step, sl, dl, wl):
        kk = codec.resolve_k(x.shape[1]) if codec is not None else k
        route = lambda v: routed_mix_delta(v, sl, dl, wl, offsets,   # noqa: E731
                                           worker_axes, n_shards)
        return compression.compressed_gossip_ref(
            x.astype(jnp.float32), err, None,
            error_feedback=error_feedback, kind=kind, k=kk, key=skey,
            step=step, gamma=gamma, use_kernel=False, mix_delta_fn=route)

    spec = P(worker_axes, None)
    mapped = _shard_map(
        body, mesh,
        (spec, spec, P()) + _edge_table_specs(worker_axes), (spec, spec))
    return lambda x, err, step: mapped(x, err, step, *tables)


def ring_allreduce_mean_fn(mesh: Mesh, worker_axes: tuple[str, ...],
                           param_specs):
    """Dense baseline: full model averaging over all workers (what a
    PS/all-reduce system does) — for collective-bytes comparisons."""
    def body(x):
        return jax.tree.map(
            lambda l: (jax.lax.pmean(l.astype(jnp.float32), worker_axes)
                       ).astype(l.dtype), x)

    return _shard_map(body, mesh, (param_specs,), param_specs)
