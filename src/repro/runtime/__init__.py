"""Distributed runtime: sharding rules, gossip collectives, DFL train/serve
steps, gradient compression, fault tolerance (DESIGN.md §3, §6)."""
