"""Sharded execution of the flat ``[W, P]`` worker matrix (cfg.sharded).

The DFL engines keep every worker's replica as one row block of a
worker-stacked pytree (flattened to ``[W, P]`` for gossip). Past a few
thousand workers that matrix no longer fits one device — FedHP's actual
regime is thousands-to-millions of edge devices. This module splits the
worker dim over the worker axis of a mesh (``launch/mesh.make_worker_mesh``
or any mesh whose axes the caller names):

- local SGD and the join re-init blend run per-slice under ``shard_map``
  (the blend's fleet average is a ``psum`` of per-shard partial sums);
- gossip always takes the edge-list form, routed cross-shard by
  ``runtime/collectives``' ppermute-by-shard-offset tables
  (``edge_shard_tables`` / ``routed_mix_delta``);
- compressed gossip reuses ``compression.compressed_gossip_ref``
  verbatim with the routed delta injected (codec payloads are row-local,
  so each shard compresses its own rows);
- when W does not divide the shard count, the fleet is padded with inert
  rows (zero params, tau 0, no edges, zero metric weight) that provably
  contribute nothing, and sliced off before anything reaches the host.

``WorkerShardPlan`` is the per-run handle ``core/engine.run_dfl`` (and
``core/fused.run_dfl_fused``) build when a mesh is passed; it caches the
jitted shard_map callables per (shape, codec, topology-table) key.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core import compression
from repro.runtime import sharding
from repro.runtime.collectives import (_shard_map, edge_shard_tables,
                                       routed_mix_delta, worker_shard_extent)


def default_worker_mesh() -> Mesh:
    """The mesh ``cfg.sharded=True`` uses when no mesh is passed: one
    ``workers`` axis over every local device (a single-device host still
    runs the full shard_map machinery with one shard)."""
    from repro.launch.mesh import make_worker_mesh
    return make_worker_mesh()


class WorkerShardPlan:
    """Per-run sharding plan: mesh + worker axes + padding + fn caches.

    ``num_workers`` is the REAL fleet size W; internally every device
    array carries ``w_pad = ceil(W / n_shards) * n_shards`` rows so each
    shard holds the same ``rows = w_pad / n_shards`` block.
    """

    def __init__(self, mesh: Mesh, num_workers: int, axes=None):
        self.mesh = mesh
        self.axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
        self.n_shards = worker_shard_extent(mesh, self.axes)
        self.num_workers = num_workers
        self.w_pad = -(-num_workers // self.n_shards) * self.n_shards
        self.pad = self.w_pad - num_workers
        self.rows = self.w_pad // self.n_shards
        self._cache: dict = {}

    # -- layout helpers ----------------------------------------------------

    def spec(self, ndim: int) -> P:
        """P(worker_axes, None, ...) for one worker-stacked array."""
        return sharding.worker_stack_spec(ndim, self.axes)

    def table_spec(self) -> P:
        """Spec for a [D, n_shards, width] edge table (middle dim over
        the worker axes)."""
        lead = self.axes if len(self.axes) > 1 else self.axes[0]
        return P(None, lead, None)

    def pad_host(self, a, fill=0):
        """Pad a host array's leading (worker) dim from W to w_pad."""
        a = np.asarray(a)
        if self.pad == 0:
            return a
        widths = [(0, self.pad)] + [(0, 0)] * (a.ndim - 1)
        return np.pad(a, widths, constant_values=fill)

    def put_stacked(self, tree):
        """Pad a [W, ...] pytree to [w_pad, ...] (zero rows) and commit it
        to the mesh with the worker-stacked sharding."""
        if self.pad:
            tree = jax.tree.map(
                lambda l: jnp.concatenate(
                    [l, jnp.zeros((self.pad,) + l.shape[1:], l.dtype)]),
                tree)
        return jax.device_put(
            tree, sharding.worker_stack_shardings(self.mesh, tree,
                                                  self.axes))

    def unpad(self, tree):
        """Slice padded device arrays back to the real W rows (identity —
        preserving the sharded arrays — when no padding was needed)."""
        if self.pad == 0:
            return tree
        return jax.tree.map(lambda l: l[:self.num_workers], tree)

    # -- sharded round ops -------------------------------------------------

    def local_train(self, adapter, stacked, bx, by, taus, lr, tau_cap: int):
        """shard_map(vmap(_sgd_worker)): each shard trains its own row
        block. ``bx``/``by``/``taus`` must already be padded to w_pad
        (tau 0 makes the padding rows' SGD an exact no-op)."""
        from repro.core.engine import _sgd_worker
        key = ("train", adapter, tau_cap, bx.shape[1:],
               jax.tree.structure(stacked))
        fn = self._cache.get(key)
        if fn is None:
            s_specs = sharding.worker_stack_pspecs(stacked, self.axes)

            def body(st, bx, by, taus, lr):
                return jax.vmap(
                    lambda p, x, y, t: _sgd_worker(adapter, p, x, y, t, lr,
                                                   tau_cap))(st, bx, by,
                                                             taus)

            fn = jax.jit(_shard_map(
                body, self.mesh,
                (s_specs, self.spec(np.ndim(bx)), self.spec(np.ndim(by)),
                 self.spec(1), P()), s_specs))
            self._cache[key] = fn
        return fn(stacked, bx, by, taus, lr)

    def reinit_joined(self, stacked, joined, donors):
        """``engine._reinit_joined`` with the fleet average as a psum of
        per-shard partial tensordots. ``joined``/``donors`` are host
        [W] masks (padded here)."""
        w = donors.astype(np.float32)
        w = w / max(w.sum(), 1.0)
        keep = jnp.asarray(self.pad_host(joined, False))
        rw = jnp.asarray(self.pad_host(w, 0.0))
        key = ("blend", jax.tree.structure(stacked))
        fn = self._cache.get(key)
        if fn is None:
            s_specs = sharding.worker_stack_pspecs(stacked, self.axes)

            def body(st, keep, rw):
                def leaf(l):
                    part = jnp.tensordot(rw, l.astype(jnp.float32), axes=1)
                    mean = jax.lax.psum(part, self.axes)
                    kk = keep.reshape((-1,) + (1,) * (l.ndim - 1))
                    return jnp.where(kk, mean[None].astype(l.dtype), l)
                return jax.tree.map(leaf, st)

            fn = jax.jit(_shard_map(
                body, self.mesh, (s_specs, self.spec(1), self.spec(1)),
                s_specs))
            self._cache[key] = fn
        return fn(stacked, keep, rw)

    def _tables(self, src, dst, w):
        offsets, sl, dl, wl = edge_shard_tables(src, dst, w, self.w_pad,
                                                self.n_shards)
        return offsets, jnp.asarray(sl), jnp.asarray(dl), jnp.asarray(wl)

    def gossip_edges(self, flat, src, dst, w):
        """Sparse Eq. 5 on the sharded [w_pad, P] matrix — the per-shard
        twin of ``kernels/ref.gossip_edges_ref`` (one ppermute per
        distinct shard offset)."""
        offsets, sl, dl, wl = self._tables(src, dst, w)
        key = ("ge", offsets, sl.shape)
        fn = self._cache.get(key)
        if fn is None:
            spec, tspec = self.spec(2), self.table_spec()

            def body(x, sl, dl, wl):
                xf = x.astype(jnp.float32)
                delta = routed_mix_delta(xf, sl, dl, wl, offsets, self.axes,
                                         self.n_shards)
                return (xf + delta).astype(x.dtype)

            fn = jax.jit(_shard_map(body, self.mesh,
                                    (spec, tspec, tspec, tspec), spec))
            self._cache[key] = fn
        return fn(flat, sl, dl, wl)

    def gossip_compressed_edges(self, flat, err, src, dst, w, skey, step,
                                gamma, *, kind: str, k: int,
                                error_feedback: bool):
        """Compressed sparse Eq. 5: ``compression.compressed_gossip_ref``
        per shard with the routed mixing delta injected — codec payloads
        are row-local, so only the delta crosses shards."""
        offsets, sl, dl, wl = self._tables(src, dst, w)
        key = ("gce", offsets, sl.shape, kind, k, error_feedback)
        fn = self._cache.get(key)
        if fn is None:
            spec, tspec = self.spec(2), self.table_spec()

            def body(x, e, skey, step, gamma, sl, dl, wl):
                route = lambda v: routed_mix_delta(   # noqa: E731
                    v, sl, dl, wl, offsets, self.axes, self.n_shards)
                return compression.compressed_gossip_ref(
                    x, e, None, error_feedback=error_feedback, kind=kind,
                    k=k, key=skey, step=step, gamma=gamma,
                    use_kernel=False, mix_delta_fn=route)

            fn = jax.jit(_shard_map(
                body, self.mesh,
                (spec, spec, P(None), P(), P(), tspec, tspec, tspec),
                (spec, spec)))
            self._cache[key] = fn
        return fn(flat, err, skey, step, gamma, sl, dl, wl)
