"""DFL train / serve step builders for the production mesh.

``make_train_step`` is the paper's round engine in SPMD form: each worker
(a mesh slice; sharding.py) holds its own replica ([W, ...] stacking),
runs tau_i masked local SGD steps (Eq. 3 — masked `fori`-style scan, the
SPMD rendering of heterogeneous trip counts, DESIGN.md §3), then gossips
along the round topology's matchings (Eq. 5, collectives.py). tau and the
topology are round-static arguments — each distinct (topology, tau_max)
compiles once and is cached.

``make_prefill_step`` / ``make_decode_step`` are single-replica serving
steps for the inference shapes.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import registry
from repro.runtime import collectives, sharding


@dataclass
class StepBundle:
    """Everything dryrun/train need for one (arch, shape, mesh) cell."""
    fn: Callable                      # the jit-able step function
    in_shardings: Any
    out_shardings: Any
    abstract_args: tuple              # ShapeDtypeStructs to lower with
    donate_argnums: tuple = ()


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------

def _split_batch_for_workers(batch_shapes: dict, w: int) -> dict:
    out = {}
    for k, s in batch_shapes.items():
        b = s.shape[0]
        assert b % w == 0 or w == 1, (k, b, w)
        out[k] = jax.ShapeDtypeStruct((w, b // w) + s.shape[1:], s.dtype)
    return out


def make_train_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape, *,
                    adj: np.ndarray | None = None, tau_max: int = 1,
                    mixing: str = "uniform", compressed: bool = False,
                    measure_distances: bool = False) -> StepBundle:
    """Build the FedHP round step for this cell.

    adj: round topology over the cell's workers (default: ring; the
    controller swaps in its own topology each round).
    tau_max: local steps per round (batch carries a leading tau dim when
    > 1; per-worker taus mask the extra steps).
    """
    w = sharding.num_workers(cfg, mesh)
    worker_axes = sharding.worker_axes_in_mesh(cfg, mesh)
    if adj is None:
        adj = _default_adj(w)
    from repro.core import topology as topo
    mixfn = (topo.mixing_matrix_metropolis if mixing == "metropolis"
             else topo.mixing_matrix_uniform)
    mix = mixfn(adj) if w > 1 else np.ones((1, 1))
    pairs = collectives.matchings_as_pairs(adj) if w > 1 else []
    wt = (collectives.matching_weight_tables(adj, mix) if w > 1
          else np.zeros((0, 1), np.float32))

    # --- abstract shapes -------------------------------------------------
    rng = jax.random.PRNGKey(0)
    p1 = jax.eval_shape(lambda: registry.init_params(cfg, rng))
    params_sds = sharding.stack_worker_dim(p1, w)
    bs = registry.batch_shapes(cfg, shape)
    batch_sds = split_sds = _split_batch_for_workers(bs, w)
    if tau_max > 1:
        batch_sds = {k: jax.ShapeDtypeStruct(
            (s.shape[0], tau_max) + s.shape[1:], s.dtype)
            for k, s in batch_sds.items()}
    taus_sds = jax.ShapeDtypeStruct((w,), jnp.int32)
    lr_sds = jax.ShapeDtypeStruct((), jnp.float32)

    # --- shardings --------------------------------------------------------
    pspecs = sharding.param_pspecs(cfg, mesh, params_sds, worker_dim=True)
    pshard = sharding.param_shardings(cfg, mesh, params_sds, worker_dim=True)
    bshard = {}
    for k, s in batch_sds.items():
        base = sharding.train_batch_spec(cfg, mesh, k, split_sds[k].shape)
        if tau_max > 1:                   # [W, tau, b_w, ...]: tau unsharded
            base = P(base[0], None, *tuple(base)[1:])
        bshard[k] = NamedSharding(mesh, base)
    gossip = (collectives.gossip_fn(mesh, worker_axes, pairs, wt, pspecs,
                                    measure_distances=measure_distances)
              if w > 1 and pairs else None)
    gossip_c = (collectives.gossip_compressed_fn(mesh, worker_axes, pairs,
                                                 wt, pspecs)
                if compressed and w > 1 and pairs else None)

    def one_worker_loss(p, b):
        loss, _ = registry.loss_fn(cfg, p, b)
        return loss

    grad_one = jax.value_and_grad(one_worker_loss)

    def local_steps(params, batch, taus, lr):
        if tau_max == 1:
            loss, grads = jax.vmap(grad_one)(params, batch)
            mask = (taus > 0).astype(jnp.float32)
            params = jax.tree.map(
                lambda p, g: p - (lr * mask.reshape(
                    (w,) + (1,) * (g.ndim - 1)) * g.astype(jnp.float32)
                ).astype(p.dtype), params, grads)
            return params, loss.mean()

        def step(carry, k):
            prm, acc = carry
            bk = jax.tree.map(lambda x: x[:, k], batch)
            loss, grads = jax.vmap(grad_one)(prm, bk)
            mask = (k < taus).astype(jnp.float32)        # Eq. 3, masked
            prm = jax.tree.map(
                lambda p, g: p - (lr * mask.reshape(
                    (w,) + (1,) * (g.ndim - 1)) * g.astype(jnp.float32)
                ).astype(p.dtype), prm, grads)
            return (prm, acc + loss.mean()), None

        (params, tot), _ = jax.lax.scan(
            step, (params, jnp.float32(0.0)), jnp.arange(tau_max))
        return params, tot / tau_max

    def train_step(params, batch, taus, lr):
        params, loss = local_steps(params, batch, taus, lr)
        aux = {}
        if gossip_c is not None:
            err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                               params)
            params, _ = gossip_c(params, err)
        elif gossip is not None:
            if measure_distances:
                params, dists = gossip(params)
                aux["neighbor_dists"] = dists
            else:
                params = gossip(params)
        return params, loss, aux

    out_shardings = (pshard, NamedSharding(mesh, P()),
                     {"neighbor_dists": NamedSharding(mesh, P())}
                     if measure_distances and gossip is not None else {})
    in_shardings = (pshard, bshard, NamedSharding(mesh, P()),
                    NamedSharding(mesh, P()))
    return StepBundle(
        fn=train_step,
        in_shardings=in_shardings,
        out_shardings=out_shardings,
        abstract_args=(params_sds, batch_sds, taus_sds, lr_sds),
        donate_argnums=(0,))


def _default_adj(w: int) -> np.ndarray:
    from repro.core import topology as topo
    return topo.ring_topology(w) if w > 1 else np.zeros((1, 1), np.int8)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, mesh: Mesh,
                      shape: InputShape) -> StepBundle:
    rng = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(lambda: registry.init_params(cfg, rng))
    pshard = sharding.param_shardings(cfg, mesh, params_sds,
                                      worker_dim=False)
    bs = registry.batch_shapes(cfg, shape)
    bs = {k: v for k, v in bs.items() if k != "labels"}
    bshard = {k: NamedSharding(mesh,
                               sharding.serve_batch_spec(cfg, mesh, v.shape))
              for k, v in bs.items()}

    def prefill_step(params, batch):
        logits, cache = registry.run_prefill(cfg, params, batch)
        return logits

    return StepBundle(
        fn=prefill_step,
        in_shardings=(pshard, bshard),
        out_shardings=NamedSharding(
            mesh, sharding.serve_batch_spec(
                cfg, mesh, (shape.global_batch, cfg.vocab_size))),
        abstract_args=(params_sds, bs))


def make_decode_step(cfg: ModelConfig, mesh: Mesh,
                     shape: InputShape) -> StepBundle:
    """serve_step: ONE new token against a seq_len KV cache (decode_*)."""
    rng = jax.random.PRNGKey(0)
    b = shape.global_batch
    params_sds = jax.eval_shape(lambda: registry.init_params(cfg, rng))
    pshard = sharding.param_shardings(cfg, mesh, params_sds,
                                      worker_dim=False)
    cache_sds = jax.eval_shape(
        lambda: registry.make_decode_cache(cfg, b, shape.seq_len))
    cshard = sharding.cache_shardings(cfg, mesh, cache_sds, b)
    tok_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tshard = NamedSharding(mesh, sharding.serve_batch_spec(cfg, mesh,
                                                           (b, 1)))

    def decode_step(params, cache, tokens):
        logits, cache = registry.decode_step(cfg, params, cache, tokens)
        return logits, cache

    return StepBundle(
        fn=decode_step,
        in_shardings=(pshard, cshard, tshard),
        out_shardings=(
            NamedSharding(mesh, sharding.serve_batch_spec(
                cfg, mesh, (b, cfg.vocab_size))),
            cshard),
        abstract_args=(params_sds, cache_sds, tok_sds),
        donate_argnums=(1,))


def make_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
              **train_kw) -> StepBundle:
    if shape.kind == "train":
        return make_train_step(cfg, mesh, shape, **train_kw)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, mesh, shape)
    return make_decode_step(cfg, mesh, shape)
