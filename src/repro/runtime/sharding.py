"""Sharding rules: map every param/batch/cache leaf to a PartitionSpec.

Layout (DESIGN.md §3-4):
- DFL worker-replica stacking: training state carries a leading worker dim
  W; sharding it over the arch's ``worker_axes`` gives each mesh slice its
  own model replica — DFL on TPU. Within a worker: TP over ``model``
  (column/row-parallel matmuls, EP for MoE experts) and, for the 340B/1T
  archs, FSDP over ``data``.
- Serving state has no worker dim: one replica sharded over the whole
  mesh; decode caches shard batch over (pod, data) and sequence over
  ``model`` (contraction-dim psum), long-context batch-1 caches shard
  sequence over (data, model).
"""
from __future__ import annotations

import re

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig

# ---------------------------------------------------------------------------
# Param rules
# ---------------------------------------------------------------------------

# name pattern -> (trailing_rank, spec builder(tp, fsdp))
# spec applies to the trailing `trailing_rank` dims; leading dims replicate.
_COL = lambda tp, fsdp: (fsdp, tp)           # noqa: E731  [in, out] column-parallel
_ROW = lambda tp, fsdp: (tp, fsdp)           # noqa: E731  [in, out] row-parallel

_RULES: list[tuple[re.Pattern, int, object]] = [
    # embeddings / heads
    (re.compile(r"embed$"), 2, lambda tp, f: (tp, None)),
    (re.compile(r"lm_head$"), 2, _COL),
    # MoE expert banks: experts over TP axis (EP); within-expert over FSDP
    (re.compile(r"moe/w_(gate|up)$"), 3, lambda tp, f: (tp, f, None)),
    (re.compile(r"moe/w_down$"), 3, lambda tp, f: (tp, None, f)),
    (re.compile(r"moe/router$"), 2, lambda tp, f: (f, None)),
    (re.compile(r"moe/shared/w_(gate|up)$"), 2, _COL),
    (re.compile(r"moe/shared/w_down$"), 2, _ROW),
    # attention
    (re.compile(r"attn/w[qkv]$"), 2, _COL),
    (re.compile(r"attn/wo$"), 2, _ROW),
    # dense MLP
    (re.compile(r"mlp/w_(up|gate)$"), 2, _COL),
    (re.compile(r"mlp/w_down$"), 2, _ROW),
    # mamba2
    (re.compile(r"mamba/in_proj$"), 2, _COL),
    (re.compile(r"mamba/out_proj$"), 2, _ROW),
    (re.compile(r"mamba/conv_w$"), 2, lambda tp, f: (None, tp)),
    # xlstm mLSTM
    (re.compile(r"w_up$"), 2, _COL),
    (re.compile(r"w(q|k|v)$"), 2, _COL),
    (re.compile(r"w_down$"), 2, _ROW),
    (re.compile(r"w_(i|f)gate$"), 2, lambda tp, f: (f, None)),
    # xlstm sLSTM
    (re.compile(r"w_in$"), 2, _COL),
    (re.compile(r"(^|/)r$"), 3, lambda tp, f: (tp, None, None)),
    (re.compile(r"w_ffn_(gate|up)$"), 2, _COL),
    (re.compile(r"w_ffn_down$"), 2, _ROW),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def _dims_divisible(shape, spec, mesh: Mesh) -> tuple:
    """Drop shardings that don't divide the dim (e.g. 15 heads on 16-way)."""
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(ax if dim % size == 0 else None)
    return tuple(out)


def param_spec(path, leaf_shape, cfg: ModelConfig, mesh: Mesh,
               *, worker_dim: bool) -> P:
    """PartitionSpec for one param leaf (with/without worker stacking).

    within_worker == "dp": params replicate inside the worker (tp=None);
    the worker's batch splits over the idle model axis instead.
    GQA with kv_heads < TP width: wk/wv stay REPLICATED (kv heads are
    tiny; replicating them keeps the head reshape shardable — the
    standard fix for kv < tp)."""
    name = _path_str(path)
    tp = _present(cfg.tp_axes, mesh) if cfg.within_worker == "tp" else None
    fsdp = _present(cfg.fsdp_axes, mesh)
    shape = leaf_shape[1:] if worker_dim else leaf_shape
    trailing = ()
    for pat, rank, builder in _RULES:
        if pat.search(name) and len(shape) >= rank:
            trailing = builder(tp, fsdp)
            break
    if tp is not None and re.search(r"attn/w[kv]$", name) \
            and cfg.num_kv_heads % mesh.shape[tp] != 0:
        trailing = (fsdp, None)                  # replicate kv heads
    lead = (None,) * (len(shape) - len(trailing))
    spec = lead + tuple(trailing)
    spec = _dims_divisible(shape, spec, mesh)
    if worker_dim:
        w = worker_axes_in_mesh(cfg, mesh)
        spec = ((w if w else None),) + spec
    return P(*spec)


def _present(axes, mesh: Mesh):
    """First axis of `axes` present in the mesh (or None)."""
    for a in axes:
        if a in mesh.shape:
            return a
    return None


def worker_axes_in_mesh(cfg: ModelConfig, mesh: Mesh) -> tuple[str, ...]:
    """The subset of cfg.worker_axes actually present in the mesh."""
    return tuple(a for a in cfg.worker_axes if a in mesh.shape)


def num_workers(cfg: ModelConfig, mesh: Mesh) -> int:
    """DFL worker count = product of the mesh's worker-axis sizes."""
    n = 1
    for a in worker_axes_in_mesh(cfg, mesh):
        n *= mesh.shape[a]
    return max(n, 1)


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape,
                    *, worker_dim: bool = True):
    """Pytree of NamedSharding matching `params_shape` (a ShapeDtypeStruct
    tree, e.g. from jax.eval_shape)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path, leaf.shape, cfg, mesh,
                             worker_dim=worker_dim)),
        params_shape)


def param_pspecs(cfg: ModelConfig, mesh: Mesh, params_shape,
                 *, worker_dim: bool = True):
    """Same as param_shardings but raw PartitionSpecs (for shard_map)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf.shape, cfg, mesh,
                                      worker_dim=worker_dim),
        params_shape)


# ---------------------------------------------------------------------------
# Batch / cache rules
# ---------------------------------------------------------------------------

def train_batch_spec(cfg: ModelConfig, mesh: Mesh, name: str,
                     leaf_shape) -> P:
    """Train batches are worker-stacked: [W, b_w, ...]. The within-worker
    batch dim splits over whatever axes the params leave idle: "data" for
    FSDP archs (worker = pod), "model" for within-worker-DP archs."""
    w = worker_axes_in_mesh(cfg, mesh)
    avail = [a for a in ("data", "model") if a in mesh.shape
             and a not in w]
    if cfg.within_worker != "dp":
        avail = [a for a in avail if a != "model"]
    chosen: list[str] = []
    size = 1
    for a in avail:                       # greedy product divisibility
        if len(leaf_shape) > 1 and leaf_shape[1] % (size * mesh.shape[a]) == 0:
            chosen.append(a)
            size *= mesh.shape[a]
    spec = [w or None, tuple(chosen) if chosen else None] \
        + [None] * (len(leaf_shape) - 2)
    # batch too small to use "model"? fall back to sequence parallelism
    seq_dim = 3 if name == "mrope_positions" else 2   # [W,b,3,S] vs [W,b,S,..]
    if cfg.within_worker == "dp" and "model" not in chosen \
            and len(leaf_shape) > seq_dim \
            and leaf_shape[seq_dim] % mesh.shape["model"] == 0:
        spec[seq_dim] = "model"
    return P(*spec)


def train_batch_shardings(cfg: ModelConfig, mesh: Mesh, batch_shapes: dict):
    """NamedSharding per batch field, from train_batch_spec's rules."""
    out = {}
    for name, sds in batch_shapes.items():
        out[name] = NamedSharding(mesh,
                                  train_batch_spec(cfg, mesh, name, sds.shape))
    return out


def serve_batch_spec(cfg: ModelConfig, mesh: Mesh, leaf_shape) -> P:
    """Serving batches: [B, ...] batch over (pod, data) when divisible.

    cfg.serve_seq_shard (§Perf): within-worker-DP archs replicate params
    over "model" — without sequence parallelism every model-chip computes
    the full forward redundantly. Sharding dim 1 (sequence) over "model"
    dedups that 16x at the cost of per-layer K/V all-gathers."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    b = leaf_shape[0]
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    first = axes if (axes and b % size == 0) else None
    spec = [first] + [None] * (len(leaf_shape) - 1)
    if cfg.serve_seq_shard and cfg.within_worker == "dp" \
            and "model" in mesh.shape and len(leaf_shape) > 1 \
            and leaf_shape[1] % mesh.shape["model"] == 0:
        spec[1] = "model"
    return P(*spec)


def cache_spec(cfg: ModelConfig, mesh: Mesh, path, leaf_shape,
               batch: int) -> P:
    """Decode-cache leaves: KV caches [..., B, S, hkv, hd], SSM states.

    batch > 1: batch over (pod, data), sequence over model (psum'd
    contraction). batch == 1 (long-context): sequence over (data, model).
    """
    name = _path_str(path)
    tp = _present(cfg.tp_axes, mesh)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dims = list(leaf_shape)
    spec: list = [None] * len(dims)
    # find the batch dim: first dim equal to `batch` (after stack dims)
    try:
        b_idx = dims.index(batch)
    except ValueError:
        b_idx = None
    if re.search(r"(^|/)(k|v|xk|xv|attn_k|attn_v|local_k|local_v|"
                 r"global_k|global_v|tail_k|tail_v)$", name):
        s_idx = b_idx + 1 if b_idx is not None else len(dims) - 3
        if batch > 1:
            size = 1
            for a in dp_axes:
                size *= mesh.shape[a]
            if b_idx is not None and batch % size == 0 and dp_axes:
                spec[b_idx] = dp_axes
            if tp and dims[s_idx] % mesh.shape[tp] == 0:
                spec[s_idx] = tp
        else:
            axes = tuple(a for a in (*dp_axes, tp) if a)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if axes and dims[s_idx] % size == 0:
                spec[s_idx] = axes
    else:
        # SSM / mLSTM / conv states: shard heads or channels over model
        if tp:
            for i in range(len(dims) - 1, -1, -1):
                if dims[i] % mesh.shape[tp] == 0 and dims[i] >= mesh.shape[tp]:
                    spec[i] = tp
                    break
        if batch > 1 and b_idx is not None:
            size = 1
            for a in dp_axes:
                size *= mesh.shape[a]
            if batch % size == 0 and dp_axes:
                spec[b_idx] = dp_axes
    return P(*spec)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_shapes, batch: int):
    """NamedSharding tree for a serving KV cache (cache_spec per leaf)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_spec(cfg, mesh, path, leaf.shape, batch)
            if leaf.ndim else P()),
        cache_shapes)


def stack_worker_dim(shapes_tree, w: int):
    """Add a leading worker dim to every ShapeDtypeStruct leaf."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((w,) + s.shape, s.dtype), shapes_tree)


# ---------------------------------------------------------------------------
# Flat DFL worker sharding (core/engine + core/fused sharded path)
# ---------------------------------------------------------------------------

def worker_stack_spec(ndim: int, axes) -> P:
    """Spec for one worker-stacked leaf: leading dim over ``axes``, rest
    replicated. The flat DFL engines keep every within-worker dim dense
    (the whole replica lives on its worker's shard), so this is the only
    spec shape the sharded path needs."""
    axes = tuple(axes)
    lead = axes if len(axes) > 1 else axes[0]
    return P(lead, *([None] * (ndim - 1)))


def worker_stack_pspecs(tree, axes):
    """Pytree of :func:`worker_stack_spec` specs matching ``tree``."""
    return jax.tree.map(lambda l: worker_stack_spec(l.ndim, axes), tree)


def worker_stack_shardings(mesh: Mesh, tree, axes):
    """Pytree of NamedSharding for worker-stacked arrays (device_put)."""
    return jax.tree.map(
        lambda l: NamedSharding(mesh, worker_stack_spec(l.ndim, axes)), tree)


def worker_shard_extent(mesh: Mesh, axes) -> int:
    """Number of row-shards the worker dim is split into over ``axes``."""
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
