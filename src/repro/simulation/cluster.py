"""Heterogeneous edge-cluster model (paper Sec. V-C1).

- Computing: each worker draws per-round per-iteration computing time from a
  Gaussian whose (mean, std) comes from a commercial-device profile
  (laptop / Jetson TX2 / Xavier NX / RPi-class), randomly assigned —
  "tenfold difference in computing capabilities".
- Communication: per-worker bandwidth fluctuates in [1, 10] Mb/s; link time
  beta_ij = model_bits / min(bw_i, bw_j) (the slower endpoint gates the
  P2P transfer).
- Failure injection: workers die/recover at configured rounds (fault-
  tolerance tests; DESIGN.md §6).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

# (mean, std) seconds per local iteration — relative scales from the paper's
# cited commercial devices; a ~10x spread between fastest and slowest.
DEVICE_PROFILES: dict[str, tuple[float, float]] = {
    "workstation": (0.05, 0.005),
    "laptop": (0.10, 0.01),
    "xavier_nx": (0.20, 0.03),
    "jetson_tx2": (0.35, 0.05),
    "rpi4": (0.55, 0.10),
}

BW_LOW_MBPS = 1.0
BW_HIGH_MBPS = 10.0


@dataclass
class SimCluster:
    num_workers: int
    model_bits: float                    # per-transfer payload (bits)
    seed: int = 0
    heterogeneous: bool = True
    fail_at: dict[int, list[int]] = field(default_factory=dict)
    # round -> worker ids that die at that round
    recover_at: dict[int, list[int]] = field(default_factory=dict)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        profiles = list(DEVICE_PROFILES.values())
        if self.heterogeneous:
            pick = rng.integers(0, len(profiles), self.num_workers)
        else:
            pick = np.full(self.num_workers, 1)          # all "laptop"
        self.mu_mean = np.array([profiles[i][0] for i in pick])
        self.mu_std = np.array([profiles[i][1] for i in pick])
        self._rng = rng
        self.alive = np.ones(self.num_workers, bool)

    # -- per-round draws ----------------------------------------------------
    def sample_mu(self) -> np.ndarray:
        """(N,) per-iteration computing time for this round."""
        mu = self._rng.normal(self.mu_mean, self.mu_std)
        return np.maximum(mu, 1e-3)

    def sample_bandwidth(self) -> np.ndarray:
        """(N,) worker uplink bandwidth in bit/s, fluctuating 1-10 Mb/s."""
        mbps = self._rng.uniform(BW_LOW_MBPS, BW_HIGH_MBPS, self.num_workers)
        return mbps * 1e6

    def sample_beta(self) -> np.ndarray:
        """(N,N) pairwise link time (s) for one model transfer."""
        bw = self.sample_bandwidth()
        pair_bw = np.minimum(bw[:, None], bw[None, :])
        beta = self.model_bits / pair_bw
        np.fill_diagonal(beta, 0.0)
        return beta

    # -- failures -----------------------------------------------------------
    def advance_round(self, h: int) -> np.ndarray:
        """Apply scheduled failures/recoveries; returns alive mask."""
        for w in self.fail_at.get(h, []):
            self.alive[w] = False
        for w in self.recover_at.get(h, []):
            self.alive[w] = True
        return self.alive.copy()
