"""Heterogeneous edge-cluster model (paper Sec. V-C1) + dynamic membership.

- Computing: each worker draws per-round per-iteration computing time from a
  Gaussian whose (mean, std) comes from a commercial-device profile
  (laptop / Jetson TX2 / Xavier NX / RPi-class), randomly assigned —
  "tenfold difference in computing capabilities".
- Communication: per-worker bandwidth fluctuates in [1, 10] Mb/s; link time
  beta_ij = model_bits / min(bw_i, bw_j) (the slower endpoint gates the
  P2P transfer).
- Churn: a declarative, seeded ``ChurnSchedule`` of join / leave / crash /
  straggler-spike events drives dynamic membership — the scenario axis the
  paper's fixed worker set never exercises (DySTop-style dynamics). The
  legacy ``fail_at``/``recover_at`` hooks remain as a thin special case.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

# (mean, std) seconds per local iteration — relative scales from the paper's
# cited commercial devices; a ~10x spread between fastest and slowest.
DEVICE_PROFILES: dict[str, tuple[float, float]] = {
    "workstation": (0.05, 0.005),
    "laptop": (0.10, 0.01),
    "xavier_nx": (0.20, 0.03),
    "jetson_tx2": (0.35, 0.05),
    "rpi4": (0.55, 0.10),
}

BW_LOW_MBPS = 1.0
BW_HIGH_MBPS = 10.0

CHURN_KINDS = ("leave", "crash", "join", "straggle")


@dataclass(frozen=True)
class ChurnEvent:
    """One membership/performance event at the start of round ``round``.

    kind:
      leave    — graceful departure (worker announces and drops out)
      crash    — abrupt failure (survivors also pay a detection timeout)
      join     — (re-)admission; the engine re-initializes the model row
      straggle — compute slows by ``factor`` for ``duration`` rounds

    ``group`` carries a correlated-failure payload: when non-empty the
    event applies to every worker in it at once (a rack/region outage
    from ``generate_correlated``) and ``worker`` is just the group's
    representative. Single-worker events leave it empty.
    """
    round: int
    kind: str
    worker: int
    factor: float = 4.0
    duration: int = 5
    group: tuple[int, ...] = ()

    def __post_init__(self):
        if self.kind not in CHURN_KINDS:
            raise ValueError(f"unknown churn kind {self.kind!r}")

    @property
    def workers(self) -> tuple[int, ...]:
        """Every worker the event applies to: the correlated ``group``
        when present, else the single ``worker``."""
        return self.group if self.group else (self.worker,)


def _alive_replay(events: list[ChurnEvent], num_workers: int):
    """Closure over a schedule-in-progress: ``alive_at(r)`` replays the
    membership events scheduled so far up to round ``r`` — the ground
    truth the generators' ``min_alive`` guards hold against (a rejoin
    only restores its workers from its `back` round on). Group events
    apply to every member."""
    def alive_at(r: int) -> np.ndarray:
        a = np.ones(num_workers, bool)
        for e in sorted(events, key=lambda e: e.round):
            if e.round > r:
                break
            if e.kind in ("leave", "crash"):
                a[list(e.workers)] = False
            elif e.kind == "join":
                a[list(e.workers)] = True
        return a
    return alive_at


@dataclass(frozen=True)
class ChurnSchedule:
    """Declarative, immutable event list; index by round via events_at()."""

    events: tuple[ChurnEvent, ...] = ()

    def events_at(self, h: int) -> list[ChurnEvent]:
        """Every event scheduled for the start of round ``h``."""
        return [e for e in self.events if e.round == h]

    @property
    def departure_rounds(self) -> list[int]:
        """Sorted rounds at which any leave/crash event fires."""
        return sorted(e.round for e in self.events
                      if e.kind in ("leave", "crash"))

    @classmethod
    def generate(cls, num_workers: int, rounds: int, *, rate: float,
                 seed: int = 0, kinds: tuple[str, ...] = CHURN_KINDS,
                 min_alive: int = 2, rejoin_p: float = 0.5,
                 straggle_factor: float = 4.0,
                 straggle_duration: int = 5) -> "ChurnSchedule":
        """Seeded generator: ~``rate`` of the fleet departs over the run
        (split between leave and crash), departed workers rejoin with
        probability ``rejoin_p``, and an equal number of straggler spikes
        hits random survivors. Never schedules a departure that would take
        the alive set below ``min_alive``.
        """
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0,1], got {rate}")
        rng = np.random.default_rng(seed)
        n_depart = int(round(rate * num_workers))
        events: list[ChurnEvent] = []
        # spread departures over the middle of the run so there is a
        # before/after on both sides
        lo, hi = max(1, rounds // 10), max(2, rounds - rounds // 10)
        depart_rounds = np.sort(rng.integers(lo, hi, n_depart))
        alive_at = _alive_replay(events, num_workers)
        # sample each departure's kind from the allowed subset — a fixed
        # leave/crash coin that `continue`d on disallowed kinds silently
        # halved the delivered rate for kinds=("crash",) and dropped the
        # paired rejoin with it
        dep_kinds = tuple(k for k in ("leave", "crash") if k in kinds)

        for r in depart_rounds if dep_kinds else ():
            a = alive_at(int(r))
            # the departure must keep min_alive from round r until the
            # departed worker's own rejoin (if any) — check the minimum
            # alive count over the remaining rounds after removing w
            if a.sum() <= min_alive:
                continue
            w = int(rng.choice(np.nonzero(a)[0]))
            kind = str(rng.choice(dep_kinds))
            events.append(ChurnEvent(int(r), kind, w))
            if any(alive_at(rr).sum() < min_alive
                   for rr in range(int(r), rounds)):
                events.pop()                       # would starve the fleet
                continue
            if "join" in kinds and rng.random() < rejoin_p:
                back = int(rng.integers(r + 2, max(r + 3, rounds)))
                if back < rounds:
                    events.append(ChurnEvent(back, "join", w))
        if "straggle" in kinds:
            for _ in range(n_depart):
                r = int(rng.integers(lo, hi))
                # spikes must hit survivors: draw from the alive set at
                # the spike round (a spike on a departed worker is a
                # silent no-op that under-delivers the scenario)
                a = alive_at(r)
                if not a.any():
                    continue
                w = int(rng.choice(np.nonzero(a)[0]))
                events.append(ChurnEvent(r, "straggle", w,
                                         factor=straggle_factor,
                                         duration=straggle_duration))
        events.sort(key=lambda e: (e.round, e.worker))
        return cls(tuple(events))

    @classmethod
    def generate_correlated(cls, num_workers: int, rounds: int, *,
                            racks: int, outages: int, seed: int = 0,
                            min_alive: int = 2, rejoin_p: float = 0.5,
                            outage_len: int = 5,
                            kind: str = "crash") -> "ChurnSchedule":
        """Seeded correlated-failure generator: ``outages`` rack/region
        outage events, each taking out one whole rack (the same
        contiguous ``topology.rack_assignment`` blocks the ``geo:<racks>``
        topology uses, so an outage removes exactly one dense
        neighborhood). Each outage is a single grouped ``kind`` event;
        with probability ``rejoin_p`` the rack comes back as a grouped
        join after ``outage_len`` rounds. Racks are trimmed (and outages
        skipped) as needed so the alive count never drops below
        ``min_alive``.
        """
        from repro.core.topology import rack_assignment
        if kind not in ("leave", "crash"):
            raise ValueError(f"outage kind must be leave|crash, got {kind!r}")
        rng = np.random.default_rng(seed)
        assign = rack_assignment(num_workers, racks)
        events: list[ChurnEvent] = []
        lo, hi = max(1, rounds // 10), max(2, rounds - rounds // 10)
        alive_at = _alive_replay(events, num_workers)
        for r in np.sort(rng.integers(lo, hi, outages)):
            rack = int(rng.integers(0, racks))
            a = alive_at(int(r))
            members = np.nonzero((assign == rack) & a)[0]
            # trim the group so the fleet keeps min_alive survivors
            take = min(members.size, int(a.sum()) - min_alive)
            if take <= 0:
                continue
            group = tuple(int(w) for w in members[:take])
            events.append(ChurnEvent(int(r), kind, group[0], group=group))
            if any(alive_at(rr).sum() < min_alive
                   for rr in range(int(r), rounds)):
                events.pop()                       # would starve the fleet
                continue
            back = int(r) + max(outage_len, 1)
            if rng.random() < rejoin_p and back < rounds:
                events.append(ChurnEvent(back, "join", group[0],
                                         group=group))
        events.sort(key=lambda e: (e.round, e.worker))
        return cls(tuple(events))


@dataclass
class SimCluster:
    """The simulated heterogeneous fleet: seeded per-round compute/link
    time draws (device profiles + fluctuating bandwidth) plus dynamic
    membership — ``advance_round`` replays the ``ChurnSchedule`` (and the
    legacy ``fail_at``/``recover_at`` hooks) into the alive mask the
    engines consume.

    ``model_bits`` is the uncompressed per-transfer payload in bits —
    32 x the model's TRUE parameter count, taken from the run's
    ``ModelAdapter.model_bits`` (core/modelspec.py) by
    ``experiment.setup_experiment``; Eq. 10 comm times (``sample_beta``)
    follow whatever model actually trains, not a hard-coded constant."""

    num_workers: int
    model_bits: float                    # per-transfer payload (bits)
    seed: int = 0
    heterogeneous: bool = True
    fail_at: dict[int, list[int]] = field(default_factory=dict)
    # round -> worker ids that die at that round
    recover_at: dict[int, list[int]] = field(default_factory=dict)
    churn: ChurnSchedule | None = None

    def __post_init__(self):
        if self.churn is not None:
            for e in self.churn.events:
                for w in e.workers:
                    if not 0 <= w < self.num_workers:
                        raise ValueError(
                            f"churn event {e} targets worker {w}; "
                            f"cluster has {self.num_workers} workers")
        rng = np.random.default_rng(self.seed)
        profiles = list(DEVICE_PROFILES.values())
        if self.heterogeneous:
            pick = rng.integers(0, len(profiles), self.num_workers)
        else:
            pick = np.full(self.num_workers, 1)          # all "laptop"
        self.mu_mean = np.array([profiles[i][0] for i in pick])
        self.mu_std = np.array([profiles[i][1] for i in pick])
        self._rng = rng
        self.alive = np.ones(self.num_workers, bool)
        # churn bookkeeping, refreshed by advance_round
        self._straggle_factor = np.ones(self.num_workers)
        self._straggle_until = np.full(self.num_workers, -1)
        self.last_joined = np.zeros(self.num_workers, bool)
        self.last_crashed = np.zeros(self.num_workers, bool)

    # -- per-round draws ----------------------------------------------------
    def sample_mu(self) -> np.ndarray:
        """(N,) per-iteration computing time for this round (straggler
        spikes multiply the base draw)."""
        mu = self._rng.normal(self.mu_mean, self.mu_std)
        return np.maximum(mu, 1e-3) * self._straggle_factor

    def sample_bandwidth(self) -> np.ndarray:
        """(N,) worker uplink bandwidth in bit/s, fluctuating 1-10 Mb/s."""
        mbps = self._rng.uniform(BW_LOW_MBPS, BW_HIGH_MBPS, self.num_workers)
        return mbps * 1e6

    def sample_beta(self) -> np.ndarray:
        """(N,N) pairwise link time (s) for one model transfer."""
        bw = self.sample_bandwidth()
        pair_bw = np.minimum(bw[:, None], bw[None, :])
        beta = self.model_bits / pair_bw
        np.fill_diagonal(beta, 0.0)
        return beta

    # -- membership ---------------------------------------------------------
    def advance_round(self, h: int) -> np.ndarray:
        """Apply round-h churn + legacy failures/recoveries; returns the
        alive mask. ``last_joined``/``last_crashed`` flag this round's
        admissions and abrupt failures for the engine."""
        self.last_joined[:] = False
        self.last_crashed[:] = False
        expired = self._straggle_until <= h
        self._straggle_factor[expired] = 1.0
        for w in self.fail_at.get(h, []):
            self.alive[w] = False
        for w in self.recover_at.get(h, []):
            if not self.alive[w]:
                self.alive[w] = True
                self.last_joined[w] = True
        if self.churn is not None:
            for ev in self.churn.events_at(h):
                # grouped events (correlated rack outages) apply the same
                # transition to every member in one round
                for w in ev.workers:
                    if ev.kind in ("leave", "crash") and self.alive[w]:
                        self.alive[w] = False
                        if ev.kind == "crash":
                            self.last_crashed[w] = True
                    elif ev.kind == "join" and not self.alive[w]:
                        self.alive[w] = True
                        self.last_joined[w] = True
                    elif ev.kind == "straggle":
                        # active for rounds h .. h+duration-1 (exactly
                        # duration rounds)
                        self._straggle_factor[w] = max(ev.factor, 1.0)
                        self._straggle_until[w] = h + max(ev.duration, 1)
        return self.alive.copy()
