"""Heterogeneous EC-cluster simulation substrate (paper Sec. V setup)."""
from repro.simulation.cluster import (  # noqa: F401
    CHURN_KINDS,
    ChurnEvent,
    ChurnSchedule,
    DEVICE_PROFILES,
    SimCluster,
)
from repro.simulation.model import (  # noqa: F401
    accuracy,
    init_classifier,
    classifier_loss,
)
