"""Small classifier trained by the DFL simulation (stands in for the
paper's CNN/AlexNet/VGG on an offline container; DESIGN.md §8)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_classifier(rng, dim: int, hidden: int, num_classes: int):
    k1, k2, k3 = jax.random.split(rng, 3)
    s1 = 1.0 / jnp.sqrt(dim)
    s2 = 1.0 / jnp.sqrt(hidden)
    return {
        "w1": jax.random.normal(k1, (dim, hidden)) * s1,
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, hidden)) * s2,
        "b2": jnp.zeros((hidden,)),
        "w3": jax.random.normal(k3, (hidden, num_classes)) * s2,
        "b3": jnp.zeros((num_classes,)),
    }


def _logits(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    return h @ params["w3"] + params["b3"]


def classifier_loss(params, batch):
    logits = _logits(params, batch["x"])
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
    return (logz - gold).mean()


def accuracy(params, x, y) -> jnp.ndarray:
    return (jnp.argmax(_logits(params, x), -1) == y).mean()
