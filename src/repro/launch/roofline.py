"""Roofline-term derivation from compiled dry-run artifacts (spec:
ROOFLINE ANALYSIS).

    compute term    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
    memory term     = HLO_bytes / (chips x 819e9 B/s HBM)
    collective term = collective_bytes / (chips x 50e9 B/s ICI per link)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``; collective
bytes are parsed from the optimized HLO text by summing operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops (operand types are inlined in HLO text).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# v5e hardware constants (per chip / per link)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g. bf16[2,16,128]{2,1,0} or f32[] — captures dtype + dims
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int] = field(default_factory=dict)
    count_by_kind: dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*?\)|[\w\[\],{}/ ]+?)\s+"
    r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _type_bytes(type_str: str) -> int:
    """Bytes of an HLO result type (handles tuples)."""
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(type_str))


def parse_collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective op in (optimized) HLO text.

    Optimized HLO names operands without inline types:
      %ag = bf16[32,6144]{...} all-gather(%x), replica_groups=...
    so we build a symbol table (op name -> result bytes) in a first pass,
    then look up each collective's operands. Counts the `-start` variant
    of async collectives; `-done` carries no new data.

    NOTE: while-loop bodies appear once in the text, so collectives inside
    scans are counted once — the dry-run unrolls layer scans
    (``layers.scan_unroll``) so every instance is visible.
    """
    # pass 1: symbol table
    table: dict[str, int] = {}
    defs: list[tuple[str, str, str]] = []   # (name, op, line)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rtype, op = m.groups()
        table[name] = _type_bytes(rtype)
        defs.append((name, op, line))
    # pass 2: collectives
    stats = CollectiveStats()
    for name, op, line in defs:
        kind = next((c for c in _COLLECTIVES
                     if op == c or op == c + "-start"), None)
        if kind is None:
            continue
        # operand names inside the call parens only
        call = line[line.index(op + "(") + len(op):]
        depth, end = 0, len(call)
        for i, ch in enumerate(call):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = call[1:end]
        nbytes = sum(table.get(nm, 0)
                     for nm in _OPERAND_RE.findall(operands))
        if nbytes == 0:
            # fall back to inline types if present (unoptimized dumps)
            nbytes = _type_bytes(operands)
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    chips: int
    collectives: CollectiveStats | None = None

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time lower bound (terms overlap perfectly)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def useful_fraction(self, model_flops: float) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return model_flops / max(self.flops, 1.0)

    def mfu(self, model_flops: float) -> float:
        """Roofline-bound MFU: useful FLOPs over peak at the bound step
        time (the score: fraction of roofline achieved)."""
        t = self.step_time_s
        return model_flops / (self.chips * PEAK_FLOPS * max(t, 1e-30))


def roofline_from_compiled(compiled, chips: int, *,
                           hlo_text: str | None = None) -> Roofline:
    """The compiled module is the per-device SPMD program: cost_analysis
    FLOPs/bytes and parsed collective operand bytes are per device. We
    store GLOBAL quantities (x chips) so the spec's /(chips x bw) formulas
    give per-device seconds."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):           # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0)) * chips
    nbytes = float(cost.get("bytes accessed", 0.0)) * chips
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collective_bytes(text)
    return Roofline(flops=flops, hbm_bytes=nbytes,
                    collective_bytes=float(coll.total_bytes) * chips,
                    chips=chips, collectives=coll)


def model_flops_train(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) per step."""
    n = cfg.active_param_count()
    d = shape.seq_len * shape.global_batch
    return 6.0 * n * d


def model_flops_decode(cfg, shape) -> float:
    """Decode: 2·N_active per generated token (fwd only) x batch."""
    n = cfg.active_param_count()
    return 2.0 * n * shape.global_batch


def model_flops_prefill(cfg, shape) -> float:
    n = cfg.active_param_count()
    return 2.0 * n * shape.seq_len * shape.global_batch


def model_flops(cfg, shape) -> float:
    if shape.kind == "train":
        return model_flops_train(cfg, shape)
    if shape.kind == "prefill":
        return model_flops_prefill(cfg, shape)
    return model_flops_decode(cfg, shape)
