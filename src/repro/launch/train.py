"""End-to-end DFL training driver: FedHP controller + TPU runtime.

Each round:
  1. the coordinator (host process) decides the topology A^h and per-worker
     taus from last round's measurements (Alg. 3),
  2. the SPMD train step runs tau_i masked local updates + matching-wise
     gossip (runtime/steps.py) and reports neighbor consensus distances,
  3. measurements feed the ConsensusTracker / controller for round h+1,
  4. periodic checkpoints (atomic, elastic-restorable).

On this CPU container run it at smoke scale::

    REPRO_DEVICES=8 PYTHONPATH=src python -m repro.launch.train \
        --arch smollm-360m --smoke --steps 8 --workers 4

On a pod, drop REPRO_DEVICES and pass --production [--multi-pod].
Wall-clock heterogeneity on homogeneous hosts is synthesized by the
SimCluster profile (DESIGN.md §3: straggler model); on a real fleet the
per-worker step times replace it.
"""
import os
if os.environ.get("REPRO_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DEVICES"])

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.checkpoint.store import elastic_reshard
from repro.configs import SHAPES, get_config, get_smoke_config
from repro.configs.base import FedHPConfig, InputShape
from repro.core.consensus import ConsensusTracker
from repro.core.controller import AdaptiveController
from repro.core.topology import make_base_topology
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.runtime import sharding, steps
from repro.simulation.cluster import SimCluster


def build_mesh(args):
    if args.production:
        return make_production_mesh(multi_pod=args.multi_pod)
    n = jax.device_count()
    model = 1
    while (n // model) > args.workers and model < n:
        model *= 2
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny batch/seq (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--tau-max", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--production", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=5)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compressed", action="store_true",
                    help="int8 error-feedback gossip")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = SHAPES[args.shape]
    if args.smoke:
        shape = InputShape(shape.name, seq_len=64,
                           global_batch=2 * args.workers, kind="train")
    mesh = build_mesh(args)
    w = sharding.num_workers(cfg, mesh)
    print(f"mesh {dict(mesh.shape)} -> {w} DFL workers; arch={cfg.name} "
          f"seq={shape.seq_len} batch={shape.global_batch}")

    fcfg = FedHPConfig(num_workers=w, rounds=args.steps,
                       tau_max=args.tau_max, tau_init=args.tau_max,
                       lr=args.lr, seed=args.seed)
    base = make_base_topology(w, "full" if w <= 8 else "erdos:0.3",
                              args.seed)
    controller = AdaptiveController(base, tau_max=fcfg.tau_max) \
        if w > 1 else None
    tracker = ConsensusTracker(w, fcfg.beta1, fcfg.beta2)
    cluster = SimCluster(w, model_bits=32.0 * cfg.param_count(),
                         seed=args.seed)

    # --- init state -------------------------------------------------------
    rng = jax.random.PRNGKey(args.seed)
    p1 = registry.init_params(cfg, rng)
    params = jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (w,) + l.shape), p1)
    ckpt = CheckpointManager(args.checkpoint_dir) if args.checkpoint_dir \
        else None
    start_round = 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        template = jax.tree.map(np.asarray, params)
        state, meta = ckpt.restore(template)
        params = jax.tree.map(jnp.asarray, elastic_reshard(state, w))
        start_round = int(meta["step"]) + 1
        print(f"resumed from step {meta['step']} "
              f"(elastic reshard -> {w} workers)")

    adj = base
    taus = np.full(w, fcfg.tau_init, np.int64)
    mu, beta = cluster.sample_mu(), cluster.sample_beta()
    compiled_cache: dict = {}
    data_rng = jax.random.PRNGKey(args.seed + 1)

    for h in range(start_round, args.steps):
        lr = jnp.float32(args.lr * (fcfg.lr_decay ** h))
        tau_cap = int(max(taus.max(), 1))
        key = (tuple(map(tuple, adj)), tau_cap)
        if key not in compiled_cache:
            bundle = steps.make_train_step(
                cfg, mesh, shape, adj=adj, tau_max=tau_cap,
                compressed=args.compressed,
                measure_distances=not args.compressed and w > 1)
            compiled_cache[key] = (bundle, jax.jit(
                bundle.fn, in_shardings=bundle.in_shardings,
                out_shardings=bundle.out_shardings))
        bundle, step_fn = compiled_cache[key]

        data_rng, k = jax.random.split(data_rng)
        batch = registry.make_batch(cfg, shape, k)
        batch = jax.tree.map(
            lambda x: x.reshape((w, x.shape[0] // w) + x.shape[1:]), batch)
        if tau_cap > 1:
            batch = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[:, None], (w, tau_cap) + x.shape[1:]), batch)

        t0 = time.time()
        params, loss, aux = step_fn(params, batch, jnp.asarray(taus,
                                                               jnp.int32), lr)
        loss = float(loss)
        dt = time.time() - t0

        # --- coordinator: measurements -> next round's (adj, taus) -------
        mu, beta = cluster.sample_mu(), cluster.sample_beta()
        if controller is not None:
            if "neighbor_dists" in aux:
                d = np.asarray(aux["neighbor_dists"])
                # distances are per matching; approximate the edge matrix
                pair = np.zeros((w, w))
                from repro.core.topology import matching_decomposition
                for m, match in enumerate(matching_decomposition(adj)):
                    for (i, j) in match:
                        pair[i, j] = pair[j, i] = d[m]
                tracker.update(adj, pair, mean_update_norm=float(d.mean()))
            decision = controller.decide(
                mu, beta, tracker, f1=loss, smooth_l=1.0, sigma=1.0,
                eta=float(lr), rounds=args.steps)
            adj, taus = decision.adj, decision.taus
        print(f"round {h}: loss={loss:.4f} tau_max={tau_cap} "
              f"links={int(adj.sum()) // 2} wall={dt:.1f}s")

        if ckpt and (h + 1) % args.checkpoint_every == 0:
            ckpt.save(h, jax.tree.map(np.asarray, params),
                      meta={"arch": cfg.name, "loss": loss})
    if ckpt:
        ckpt.save(args.steps - 1, jax.tree.map(np.asarray, params),
                  meta={"arch": cfg.name, "loss": loss})
    print("done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
