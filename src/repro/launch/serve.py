"""Serving driver: batched prefill + decode loop for any assigned arch.

Smoke scale on CPU::

    REPRO_DEVICES=8 PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen2-vl-2b --smoke --batch 2 --prompt-len 32 --gen 8

On a pod: --production [--multi-pod] with the full config.
"""
import os
if os.environ.get("REPRO_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DEVICES"])

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.models.encdec import dec_len


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--production", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.production:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        n = jax.device_count()
        mesh = jax.make_mesh((1, n), ("data", "model"))

    rng = jax.random.PRNGKey(args.seed)
    params = registry.init_params(cfg, rng)
    b, t = args.batch, args.prompt_len
    max_len = t + args.gen

    if cfg.family == "encdec":
        frames = jax.random.normal(rng, (b, t * 8, cfg.d_model),
                                   jnp.float32).astype(params["embed"].dtype)
        prompt = jax.random.randint(rng, (b, max(t // 8, 1)), 0,
                                    cfg.vocab_size, jnp.int32)
        batch = {"frames": frames, "tokens": prompt}
        cap = dec_len(t * 8) + args.gen
    else:
        prompt = jax.random.randint(rng, (b, t), 0, cfg.vocab_size,
                                    jnp.int32)
        batch = {"tokens": prompt}
        cap = max_len

    prefill = jax.jit(lambda p, bt: registry.run_prefill(cfg, p, bt,
                                                         max_len=cap))
    decode = jax.jit(lambda p, c, tk: registry.decode_step(cfg, p, c, tk))

    with mesh:
        t0 = time.time()
        logits, cache = prefill(params, batch)
        logits.block_until_ready()
        t_prefill = time.time() - t0
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out = [tok]
        t0 = time.time()
        for _ in range(args.gen - 1):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            out.append(tok)
        tok.block_until_ready()
        t_decode = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={b} prompt={t} gen={args.gen}")
    print(f"prefill: {t_prefill:.2f}s; decode: {t_decode:.2f}s "
          f"({(args.gen - 1) * b / max(t_decode, 1e-9):.1f} tok/s)")
    print("generated ids (first row):", gen[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
