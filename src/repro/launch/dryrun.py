import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (spec: MULTI-POD DRY-RUN).

For every (architecture x input shape) cell: build the step (train_step
for train shapes, serve_step for decode; prefill for prefill shapes),
lower + compile against the production mesh, print memory_analysis (fits)
and cost_analysis (FLOPs/bytes for §Roofline), and parse collective
bytes from the compiled HLO.

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
        --shape train_4k [--multi-pod] [--all] [--json out.json]

The XLA_FLAGS line above MUST precede any jax import (device count locks
on first init); smoke tests / benches never import this module.
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as rl
from repro.runtime import steps as steps_mod


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             train_kw: dict | None = None, verbose: bool = True,
             unroll: bool = True, f32_traffic: bool = True,
             cfg_overrides: dict | None = None) -> dict:
    """f32_traffic: compile with dtype=f32 and scale byte terms x0.5 to
    bf16-equivalent. The CPU backend emulates bf16 by inserting f32
    converts of full params/caches per use — phantom HBM traffic that
    does not exist on TPU (native bf16) and would otherwise dominate the
    memory term ~100x. FLOP counts are dtype-independent."""
    import dataclasses
    from repro.models import layers as L
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    byte_scale = 1.0
    if f32_traffic and cfg.dtype == "bfloat16":
        cfg = dataclasses.replace(cfg, dtype="float32")
        byte_scale = 0.5
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    bundle = steps_mod.make_step(cfg, mesh, shape, **(train_kw or {}))
    # unroll layer scans so cost_analysis counts every trip (roofline.py);
    # scan mode (unroll=False) for fast compile-success-only passes
    with mesh, L.scan_unroll(unroll):
        jitted = jax.jit(bundle.fn,
                         in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings,
                         donate_argnums=bundle.donate_argnums)
        lowered = jitted.lower(*bundle.abstract_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    roof = rl.roofline_from_compiled(compiled, chips)
    roof.hbm_bytes *= byte_scale            # f32-compiled -> bf16 traffic
    roof.collective_bytes *= byte_scale
    mf = rl.model_flops(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "ok": True,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "hlo_flops": roof.flops,
        "hlo_bytes": roof.hbm_bytes,
        "collective_bytes": roof.collective_bytes,
        "collectives": dict(roof.collectives.count_by_kind),
        "collective_bytes_by_kind": dict(roof.collectives.bytes_by_kind),
        "compute_s": roof.compute_s,
        "memory_s": roof.memory_s,
        "collective_s": roof.collective_s,
        "dominant": roof.dominant,
        "model_flops": mf,
        "useful_fraction": roof.useful_fraction(mf),
        "mfu_bound": roof.mfu(mf),
        "bytes_per_device": (getattr(mem, "argument_size_in_bytes", 0)
                             + getattr(mem, "output_size_in_bytes", 0))
        * byte_scale,
        "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", 0)
        * byte_scale,
        "peak_bytes_per_device": (getattr(mem, "peak_memory_in_bytes",
                                          None) or (
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0))) * byte_scale,
    }
    if verbose:
        print(f"[{rec['mesh']}] {arch} x {shape_name}: OK "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"  memory_analysis: args+out={rec['bytes_per_device']/1e9:.2f}"
              f" GB/dev, temp={rec['temp_bytes_per_device']/1e9:.2f} GB/dev")
        print(f"  cost_analysis: {roof.flops:.3e} FLOPs, "
              f"{roof.hbm_bytes:.3e} HBM bytes, "
              f"{roof.collective_bytes:.3e} collective bytes "
              f"{rec['collectives']}")
        print(f"  roofline: compute={roof.compute_s*1e3:.2f}ms "
              f"memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms "
              f"-> {roof.dominant}-bound; "
              f"useful={rec['useful_fraction']:.2f} "
              f"MFU_bound={rec['mfu_bound']:.3f}")
    return rec


def cells(multi_pod: bool):
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for name in SHAPES:
            if name in cfg.skip_shapes:
                continue
            yield arch, name


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--json", default=None, help="append records here")
    ap.add_argument("--tau-max", type=int, default=1)
    ap.add_argument("--no-unroll", action="store_true",
                    help="scan mode: fast compile-success pass (costs of "
                         "scanned bodies counted once; not for §Roofline)")
    ap.add_argument("--shapes", default=None,
                    help="comma-separated shape filter for --all")
    ap.add_argument("--arches", default=None,
                    help="comma-separated arch filter for --all")
    args = ap.parse_args(argv)

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    shape_f = args.shapes.split(",") if args.shapes else None
    arch_f = args.arches.split(",") if args.arches else None
    todo = []
    for mp in meshes:
        if args.all:
            todo += [(a, s, mp) for a, s in cells(mp)
                     if (not shape_f or s in shape_f)
                     and (not arch_f or a in arch_f)]
        else:
            assert args.arch and args.shape, "--arch/--shape or --all"
            todo.append((args.arch, args.shape, mp))

    records, failures = [], 0
    for arch, shape, mp in todo:
        try:
            rec = run_cell(arch, shape, multi_pod=mp,
                           train_kw={"tau_max": args.tau_max}
                           if SHAPES[shape].kind == "train" else None,
                           unroll=not args.no_unroll)
        except Exception as e:  # noqa: BLE001 — report, keep going
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16", "ok": False,
                   "error": f"{type(e).__name__}: {e}"}
            failures += 1
        records.append(rec)
        if args.json:
            with open(args.json, "a") as f:
                f.write(json.dumps(rec) + "\n")
    print(f"\n{len(records) - failures}/{len(records)} cells compiled OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
