"""Production mesh definitions (spec: MULTI-POD DRY-RUN step 1).

Functions, not module-level constants — importing this module never
touches jax device state.
"""
from __future__ import annotations

import math
import warnings

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16x16 = 256 chips single-pod; 2x16x16 multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model"), *,
                    shrink: bool = False):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count
    set before jax init).

    The host must expose at least ``prod(shape)`` devices: ``jax.make_mesh``
    would otherwise silently build a mesh over however many devices exist,
    and every shard_map downstream would compute with the wrong worker
    extent. With ``shrink=False`` (default) a too-small host raises
    ``ValueError``; with ``shrink=True`` axis sizes are halved
    deterministically (leftmost even axis first, then forced to 1) until
    the mesh fits, with a ``UserWarning`` naming the final shape.
    """
    ndev = len(jax.devices())
    need = math.prod(shape)
    if need > ndev:
        if not shrink:
            raise ValueError(
                f"make_debug_mesh{tuple(shape)} needs {need} devices but the "
                f"host exposes {ndev}; set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
                f"before importing jax, or pass shrink=True")
        sizes = list(shape)
        while math.prod(sizes) > ndev:
            for i, s in enumerate(sizes):
                if s > 1 and s % 2 == 0:
                    sizes[i] = s // 2
                    break
            else:
                for i, s in enumerate(sizes):
                    if s > 1:
                        sizes[i] = 1
                        break
        shape = tuple(sizes)
        warnings.warn(
            f"make_debug_mesh: host has {ndev} devices; shrank mesh to "
            f"{shape} over axes {tuple(axes)}", UserWarning, stacklevel=2)
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_worker_mesh(num_shards: int | None = None, axis: str = "workers"):
    """1-D mesh over ``num_shards`` local devices (default: all of them).

    This is the mesh the sharded DFL path expects: a single named axis
    along which the flat ``[W, P]`` worker matrix is split row-wise
    (``core/engine.run_dfl(mesh=...)`` / ``cfg.sharded``).
    """
    ndev = len(jax.devices())
    if num_shards is None:
        num_shards = ndev
    if num_shards < 1 or num_shards > ndev:
        raise ValueError(
            f"make_worker_mesh: num_shards={num_shards} out of range for a "
            f"host with {ndev} devices")
    return jax.sharding.Mesh(jax.devices()[:num_shards], (axis,))
