"""Production mesh definitions (spec: MULTI-POD DRY-RUN step 1).

Functions, not module-level constants — importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16x16 = 256 chips single-pod; 2x16x16 multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count
    set before jax init)."""
    return jax.make_mesh(shape, axes)
